#!/usr/bin/env python3
"""Unit tests for the bench_diff.py regression gate.

Drives the script as a subprocess (the same way CI invokes it) and
asserts on exit codes + output text, covering the three behaviors the
gate promises:

  * a populated row losing more than --fail-pct of its prior value
    FAILS (exit 1),
  * rows that are null on either side only WARN (exit 0), so a cold
    artifact chain from a toolchain-less builder cannot break CI,
  * a missing input file is a hard error (nonzero exit), never a
    silent pass.

Run with:  python3 -m unittest discover -s scripts -p 'test_*.py' -v
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "bench_diff.py")


def doc(rows):
    return {"results": rows}


def row(component, **metrics):
    r = {"component": component}
    r.update(metrics)
    return r


class BenchDiffTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def write(self, name, payload):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def run_gate(self, prior, current, *extra):
        return subprocess.run(
            [sys.executable, SCRIPT, prior, current, *extra],
            capture_output=True, text=True)

    def test_regression_beyond_threshold_fails(self):
        prior = self.write("prior.json", doc([
            row("hll_fold", rate_per_s=1000.0),
            row("intersect", speedup=4.0),
        ]))
        current = self.write("current.json", doc([
            row("hll_fold", rate_per_s=700.0),   # -30% < -20%: fail
            row("intersect", speedup=3.9),       # -2.5%: fine
        ]))
        res = self.run_gate(prior, current, "--fail-pct", "20")
        self.assertEqual(res.returncode, 1, res.stdout + res.stderr)
        self.assertIn("FAIL: rate_per_s regressed", res.stdout)
        self.assertIn("1 regression(s) beyond 20%", res.stdout)
        self.assertNotIn("bench gate: OK", res.stdout)

    def test_regression_within_threshold_passes(self):
        prior = self.write("prior.json", doc([row("k", rate_per_s=1000.0)]))
        current = self.write("current.json", doc([row("k", rate_per_s=850.0)]))
        res = self.run_gate(prior, current, "--fail-pct", "20")
        self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
        self.assertIn("bench gate: OK", res.stdout)

    def test_null_rows_warn_but_pass(self):
        # the toolchain-less authoring container ships null metrics; the
        # gate must warn, not fail
        prior = self.write("prior.json", doc([
            row("hll_fold", rate_per_s=1000.0),
            row("cold", rate_per_s=None),
        ]))
        current = self.write("current.json", doc([
            row("hll_fold", rate_per_s=None),
            row("cold", rate_per_s=None),
        ]))
        res = self.run_gate(prior, current)
        self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
        self.assertIn("WARN: unpopulated", res.stdout)
        self.assertIn("2 row(s) unpopulated or missing", res.stdout)
        self.assertIn("bench gate: OK", res.stdout)

    def test_new_and_dropped_rows(self):
        prior = self.write("prior.json", doc([
            row("kept", rate_per_s=100.0),
            row("gone", rate_per_s=50.0),
        ]))
        current = self.write("current.json", doc([
            row("kept", rate_per_s=100.0),
            row("fresh", rate_per_s=9.0),
        ]))
        res = self.run_gate(prior, current)
        # a new row has no baseline, a dropped row warns; neither fails
        self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
        self.assertIn("(no baseline)", res.stdout)
        self.assertIn("WARN: row vanished", res.stdout)
        self.assertIn("bench gate: OK", res.stdout)

    def test_missing_file_is_a_hard_error(self):
        current = self.write("current.json", doc([]))
        res = self.run_gate(os.path.join(self.dir.name, "nope.json"),
                            current)
        self.assertNotEqual(res.returncode, 0)
        self.assertNotIn("bench gate: OK", res.stdout)

    def test_malformed_json_is_a_hard_error(self):
        prior = self.write("prior.json", doc([]))
        bad = os.path.join(self.dir.name, "bad.json")
        with open(bad, "w") as f:
            f.write("{not json")
        res = self.run_gate(prior, bad)
        self.assertNotEqual(res.returncode, 0)
        self.assertNotIn("bench gate: OK", res.stdout)

    def test_improvement_never_fails(self):
        prior = self.write("prior.json", doc([row("k", speedup=2.0)]))
        current = self.write("current.json", doc([row("k", speedup=9.0)]))
        res = self.run_gate(prior, current, "--fail-pct", "1")
        self.assertEqual(res.returncode, 0, res.stdout + res.stderr)
        self.assertIn("bench gate: OK", res.stdout)


if __name__ == "__main__":
    unittest.main()
