#!/usr/bin/env python3
"""Diff two BENCH_microbench.json files and gate on regressions.

Usage: bench_diff.py PRIOR.json CURRENT.json [--fail-pct 20]

Rows are matched by their "component" name. Timed rows compare
`rate_per_s` (higher is better); ratio rows compare `speedup` (higher is
better). A populated row that loses more than --fail-pct percent of its
prior value fails the gate; rows that are null on either side (the bench
never ran, e.g. toolchain-less authoring containers) only warn, so a
cold artifact chain cannot break CI.
"""

import argparse
import json
import sys


def metric_of(row):
    """(metric_name, value) for one results[] row; value may be None."""
    if "rate_per_s" in row:
        return "rate_per_s", row["rate_per_s"]
    if "speedup" in row:
        return "speedup", row["speedup"]
    return None, None


def index(doc):
    out = {}
    for row in doc.get("results", []):
        name = row.get("component")
        if name:
            out[name] = row
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("prior")
    ap.add_argument("current")
    ap.add_argument("--fail-pct", type=float, default=20.0)
    args = ap.parse_args()

    with open(args.prior) as f:
        prior = index(json.load(f))
    with open(args.current) as f:
        current = index(json.load(f))

    width = max((len(n) for n in current | prior), default=9)
    print(f"{'component':<{width}}  {'prior':>14}  {'current':>14}  delta")
    print("-" * (width + 44))

    regressions = []
    warnings = 0
    for name in sorted(current | prior):
        p_row, c_row = prior.get(name), current.get(name)
        if p_row is None:
            print(f"{name:<{width}}  {'--':>14}  {'new row':>14}  (no baseline)")
            continue
        if c_row is None:
            print(f"{name:<{width}}  {'dropped':>14}  {'--':>14}  WARN: row vanished")
            warnings += 1
            continue
        _, p = metric_of(p_row)
        kind, c = metric_of(c_row)
        if p is None or c is None:
            print(f"{name:<{width}}  {fmt(p):>14}  {fmt(c):>14}  WARN: unpopulated")
            warnings += 1
            continue
        delta_pct = (c - p) / p * 100.0 if p else 0.0
        flag = ""
        if delta_pct < -args.fail_pct:
            flag = f"  FAIL: {kind} regressed beyond -{args.fail_pct:g}%"
            regressions.append((name, delta_pct))
        print(f"{name:<{width}}  {fmt(p):>14}  {fmt(c):>14}  {delta_pct:+7.1f}%{flag}")

    print()
    if warnings:
        print(f"{warnings} row(s) unpopulated or missing (warned, not failed)")
    if regressions:
        print(f"{len(regressions)} regression(s) beyond {args.fail_pct:g}%:")
        for name, pct in regressions:
            print(f"  {name}: {pct:+.1f}%")
        sys.exit(1)
    print("bench gate: OK")


def fmt(v):
    if v is None:
        return "null"
    if isinstance(v, float) and (v >= 1000 or v == int(v)):
        return f"{v:,.0f}"
    return f"{v:.3g}"


if __name__ == "__main__":
    main()
