//! Web-graph / spam-detection scenario (paper §1: local triangle counts
//! are useful in spam detection — Becchetti et al. 2010): find the
//! triangle heavy-hitter pages and edges of a power-law RMAT web crawl,
//! flag low-density hubs (link farms have high degree but low triangle
//! density), and compare against exact counts.
//!
//! Run: `cargo run --release --example web_triangles`

use std::collections::HashMap;
use std::sync::Arc;

use degreesketch::comm::Backend;
use degreesketch::coordinator::sketch::{
    accumulate_stream, AccumulateOptions,
};
use degreesketch::coordinator::{
    edge_triangle_heavy_hitters, vertex_triangle_heavy_hitters,
    TriangleOptions,
};
use degreesketch::graph::csr::Csr;
use degreesketch::graph::exact;
use degreesketch::graph::gen::GraphSpec;
use degreesketch::graph::stream::{EdgeStream, MemoryStream};
use degreesketch::hll::HllConfig;

fn main() -> anyhow::Result<()> {
    // 65k-page crawl with hubs (RMAT 0.57/0.19/0.19).
    let edges = GraphSpec::parse("rmat:16:12").unwrap().generate(7);
    let csr = Csr::from_edges(&edges);
    println!(
        "web crawl: {} pages, {} links",
        csr.num_vertices(),
        csr.num_edges()
    );

    let stream = MemoryStream::new(edges);
    let ranks = 8;
    let ds = Arc::new(accumulate_stream(
        &stream,
        ranks,
        HllConfig::new(12, 0x3EB),
        AccumulateOptions {
            backend: Backend::Threaded,
            ..Default::default()
        },
    ));
    let shards = stream.shard(ranks);
    let opts = TriangleOptions {
        backend: Backend::Threaded,
        k: 10,
        ..Default::default()
    };

    // Algorithm 5: vertex-local heavy hitters — community cores.
    let vres = vertex_triangle_heavy_hitters(&ds, &shards, &opts);
    let truth_v = exact::vertex_triangles(&csr);
    println!(
        "\nglobal triangles: estimated {:.2e}, exact {:.2e}  ({:.3}s, {} sketch pairs)",
        vres.global_estimate,
        exact::global_triangles(&csr) as f64,
        vres.seconds,
        vres.pairs_estimated
    );
    println!("top-10 triangle-heavy pages (est vs exact):");
    for (est, v) in &vres.heavy_hitters {
        let cv = csr.compact_id(*v).unwrap();
        println!(
            "  page {v:>6}  est ≈ {est:>9.1}  exact = {:>7}  degree = {}",
            truth_v[cv as usize],
            csr.degree(cv)
        );
    }

    // Algorithm 4: edge-local heavy hitters — the strongest co-citation
    // relationships.
    let eres = edge_triangle_heavy_hitters(&ds, &shards, &opts);
    let truth_e: HashMap<(u64, u64), usize> = exact::edge_triangles(&csr)
        .into_iter()
        .map(|(u, v, c)| {
            let (a, b) = (csr.original_id(u), csr.original_id(v));
            ((a.min(b), a.max(b)), c)
        })
        .collect();
    println!("\ntop-10 co-citation edges (est vs exact):");
    for (est, e) in &eres.heavy_hitters {
        println!(
            "  ({:>6},{:>6})  est ≈ {est:>8.1}  exact = {}",
            e.0, e.1, truth_e[e]
        );
    }

    // Spam heuristic: high-degree pages whose triangle density (Jaccard of
    // their top edge) is near zero look like link farms.
    println!("\nlink-farm screen (degree vs triangles):");
    let mut by_degree: Vec<u32> = (0..csr.num_vertices() as u32).collect();
    by_degree.sort_unstable_by_key(|&v| std::cmp::Reverse(csr.degree(v)));
    for &v in by_degree.iter().take(5) {
        let id = csr.original_id(v);
        let tri = truth_v[v as usize];
        let deg = csr.degree(v);
        let density = tri as f64 / (deg * (deg - 1) / 2).max(1) as f64;
        let verdict = if density < 0.001 { "SUSPECT" } else { "ok" };
        println!(
            "  page {id:>6}  degree {deg:>5}  triangles {tri:>7}  \
             clustering {density:.5}  {verdict}"
        );
    }
    Ok(())
}
