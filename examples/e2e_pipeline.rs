//! End-to-end driver: the full DegreeSketch system on a real small
//! workload, proving all three layers compose (EXPERIMENTS.md §E2E).
//!
//! Pipeline (a data-pipeline paper's analogue of "train a model end to
//! end"):
//!   1. build a ground-truthable Kronecker graph (karate ⊗ karate — paper
//!      Appendix C) and a power-law RMAT graph;
//!   2. Algorithm 1: accumulate DegreeSketch on 8 threaded ranks;
//!   3. Algorithm 2: t ≤ 5 neighborhood estimation → MRE vs exact BFS;
//!   4. Algorithms 4/5: triangle heavy hitters → precision/recall vs
//!      exact, with BOTH the native MLE backend and the PJRT backend
//!      (JAX/Pallas AOT artifact through Layer 3) when artifacts exist;
//!   5. report the paper's headline metrics: wall time linear in m,
//!      estimation MRE ≈ HLL standard error, heavy-hitter P/R.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Instant;

use degreesketch::comm::Backend;
use degreesketch::coordinator::anf::{neighborhood_approximation, AnfOptions};
use degreesketch::coordinator::sketch::{
    accumulate_stream, AccumulateOptions,
};
use degreesketch::coordinator::{
    edge_triangle_heavy_hitters, vertex_triangle_heavy_hitters,
    IntersectBackend, TriangleOptions,
};
use degreesketch::graph::csr::Csr;
use degreesketch::graph::exact;
use degreesketch::graph::gen::GraphSpec;
use degreesketch::graph::stream::{EdgeStream, MemoryStream};
use degreesketch::graph::Edge;
use degreesketch::hll::HllConfig;
use degreesketch::runtime::{default_artifacts_dir, PjrtService};
use degreesketch::util::stats::{mean_relative_error, precision_recall};

const RANKS: usize = 8;

fn main() -> anyhow::Result<()> {
    println!("=== DegreeSketch end-to-end pipeline ===\n");
    let mut total_edges = 0usize;
    let mut total_secs = 0.0f64;
    for spec in ["kron-karate:2", "rmat:14:16"] {
        let (m, s) = run_graph(spec)?;
        total_edges += m;
        total_secs += s;
    }
    println!(
        "=== pipeline complete: {total_edges} edges processed in \
         {total_secs:.2}s ({:.2e} edges/s end-to-end) ===",
        total_edges as f64 / total_secs
    );
    Ok(())
}

fn run_graph(spec_str: &str) -> anyhow::Result<(usize, f64)> {
    let wall = Instant::now();
    let spec = GraphSpec::parse(spec_str).unwrap();
    let edges = spec.generate(11);
    let csr = Csr::from_edges(&edges);
    println!(
        "--- {spec_str} ({}): |V|={} |E|={}",
        spec.type_name(),
        csr.num_vertices(),
        csr.num_edges()
    );

    // ---- Algorithm 1: accumulation --------------------------------
    let stream = MemoryStream::new(edges.clone());
    let t0 = Instant::now();
    let ds = accumulate_stream(
        &stream,
        RANKS,
        HllConfig::new(8, 0xE2E),
        AccumulateOptions {
            backend: Backend::Threaded,
            ..Default::default()
        },
    );
    let accum_s = t0.elapsed().as_secs_f64();
    println!(
        "accumulate: {:.3}s ({:.2e} edges/s, {} messages, {:.1} KiB sketches)",
        accum_s,
        edges.len() as f64 / accum_s,
        ds.accumulation_stats.messages,
        ds.memory_bytes() as f64 / 1024.0
    );

    // ---- distributed-memory leg: the same epoch on forked worker
    // processes over Unix-socket frames must agree sketch-for-sketch
    let t0 = Instant::now();
    let ds_proc = accumulate_stream(
        &stream,
        RANKS,
        HllConfig::new(8, 0xE2E),
        AccumulateOptions {
            backend: Backend::Process,
            ..Default::default()
        },
    );
    let proc_s = t0.elapsed().as_secs_f64();
    let mismatches = ds
        .iter()
        .filter(|&(v, h)| ds_proc.sketch(v) != Some(h))
        .count();
    assert_eq!(mismatches, 0, "process backend must match threaded exactly");
    println!(
        "accumulate (process backend, {RANKS} workers): {:.3}s, \
         {} wire frames / {:.1} KiB shipped, sketches bit-identical",
        proc_s,
        ds_proc.accumulation_stats.flushes,
        ds_proc.accumulation_stats.bytes as f64 / 1024.0
    );

    // ---- multi-host leg: the same epoch over a rendezvous'd TCP
    // fabric. Workers here are threads for a self-contained example; in
    // production each is a `degreesketch worker` process on its own
    // host. All actor inputs ship via seed_state codecs — no shared
    // memory of any kind.
    {
        use degreesketch::comm::tcp;
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let registrar = listener.local_addr()?.to_string();
        tcp::configure_driver(
            listener,
            vec!["127.0.0.1:0".to_string(); RANKS],
        );
        let workers: Vec<_> = (0..RANKS)
            .map(|rank| {
                let registrar = registrar.clone();
                std::thread::spawn(move || {
                    tcp::run_worker(
                        degreesketch::coordinator::worker_dispatch(),
                        &registrar,
                        rank,
                        std::time::Duration::from_secs(60),
                    )
                })
            })
            .collect();
        let t0 = Instant::now();
        let ds_tcp = accumulate_stream(
            &stream,
            RANKS,
            HllConfig::new(8, 0xE2E),
            AccumulateOptions {
                backend: Backend::Tcp,
                ..Default::default()
            },
        );
        let tcp_s = t0.elapsed().as_secs_f64();
        tcp::shutdown_driver();
        for w in workers {
            w.join()
                .expect("worker thread")
                .map_err(anyhow::Error::msg)?;
        }
        let mismatches = ds
            .iter()
            .filter(|&(v, h)| ds_tcp.sketch(v) != Some(h))
            .count();
        assert_eq!(mismatches, 0, "tcp backend must match threaded exactly");
        println!(
            "accumulate (tcp fabric, {RANKS} workers over localhost): \
             {:.3}s, {} wire frames / {:.1} KiB shipped, \
             sketches bit-identical",
            tcp_s,
            ds_tcp.accumulation_stats.flushes,
            ds_tcp.accumulation_stats.bytes as f64 / 1024.0
        );
    }

    // ---- Algorithm 2: neighborhoods vs exact BFS -------------------
    let shards = stream.shard(RANKS);
    let max_t = 5;
    let t0 = Instant::now();
    let anf = neighborhood_approximation(
        &ds,
        &shards,
        AnfOptions {
            backend: Backend::Threaded,
            max_t,
            ..Default::default()
        },
    );
    let anf_s = t0.elapsed().as_secs_f64();
    let truth = exact::neighborhood_sizes(&csr, max_t);
    print!("anf ({anf_s:.3}s): MRE per t:");
    for t in 1..=max_t {
        let pairs: Vec<(f64, f64)> = (0..csr.num_vertices() as u32)
            .map(|v| {
                let tr = if t == 1 {
                    csr.degree(v) as f64
                } else {
                    truth[v as usize][t - 1] as f64
                };
                (tr, anf.per_vertex[&csr.original_id(v)][t - 1])
            })
            .collect();
        print!(" t{t}={:.3}", mean_relative_error(&pairs));
    }
    println!("  (HLL standard error at p=8 is 0.065)");

    // ---- Algorithms 4/5: triangle heavy hitters --------------------
    // ground truth top-k sets
    let k = 100;
    let ds = Arc::new(ds);
    let edge_truth = exact::edge_triangles(&csr);
    let mut ranked: Vec<(usize, Edge)> = edge_truth
        .iter()
        .map(|&(u, v, c)| {
            let (a, b) = (csr.original_id(u), csr.original_id(v));
            (c, (a.min(b), a.max(b)))
        })
        .collect();
    ranked.sort_unstable_by(|a, b| b.cmp(a));
    let true_topk: HashSet<Edge> =
        ranked.iter().take(k).map(|&(_, e)| e).collect();

    let t0 = Instant::now();
    let eres = edge_triangle_heavy_hitters(
        &ds,
        &shards,
        &TriangleOptions {
            backend: Backend::Threaded,
            k,
            ..Default::default()
        },
    );
    let tri_s = t0.elapsed().as_secs_f64();
    let predicted: HashSet<Edge> =
        eres.heavy_hitters.iter().map(|&(_, e)| e).collect();
    let (prec, rec) = precision_recall(&true_topk, &predicted);
    let exact_t = exact::global_triangles(&csr) as f64;
    println!(
        "edge-HH (native MLE, {tri_s:.3}s, {:.2e} pairs/s): \
         precision={prec:.2} recall={rec:.2}  T est {:.3e} vs exact {:.3e}",
        eres.pairs_estimated as f64 / tri_s,
        eres.global_estimate,
        exact_t
    );

    // vertex heavy hitters
    let vres = vertex_triangle_heavy_hitters(
        &ds,
        &shards,
        &TriangleOptions {
            backend: Backend::Threaded,
            k,
            ..Default::default()
        },
    );
    let vt = exact::vertex_triangles(&csr);
    let mut vranked: Vec<(usize, u64)> = vt
        .iter()
        .enumerate()
        .map(|(v, &c)| (c, csr.original_id(v as u32)))
        .collect();
    vranked.sort_unstable_by(|a, b| b.cmp(a));
    let vtrue: HashSet<u64> = vranked.iter().take(k).map(|&(_, v)| v).collect();
    let vpred: HashSet<u64> =
        vres.heavy_hitters.iter().map(|&(_, v)| v).collect();
    let (vprec, vrec) = precision_recall(&vtrue, &vpred);
    println!(
        "vertex-HH: precision={vprec:.2} recall={vrec:.2}  T est {:.3e}",
        vres.global_estimate
    );

    // ---- PJRT leg: the L1/L2 artifact on the L3 hot path -----------
    // (interpret-mode Pallas on CPU is far slower than the native solver,
    // so the composition proof runs on the smaller workload only)
    let artifacts = default_artifacts_dir();
    if artifacts.join("manifest.txt").exists() && edges.len() < 50_000 {
        let service = PjrtService::start(&artifacts)?;
        let t0 = Instant::now();
        let pres = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                backend: Backend::Sequential,
                k,
                intersect: IntersectBackend::Batched {
                    batch: 256,
                    exec: Arc::new(service.handle()),
                },
                ..Default::default()
            },
        );
        let pjrt_s = t0.elapsed().as_secs_f64();
        let ppred: HashSet<Edge> =
            pres.heavy_hitters.iter().map(|&(_, e)| e).collect();
        let (pprec, prec2) = precision_recall(&true_topk, &ppred);
        println!(
            "edge-HH (PJRT artifact, {pjrt_s:.3}s): precision={pprec:.2} \
             recall={prec2:.2}  T est {:.3e}",
            pres.global_estimate
        );
    } else if edges.len() >= 50_000 {
        println!("(PJRT leg skipped on large workload: interpret-mode Pallas)");
    } else {
        println!("(PJRT leg skipped: run `make artifacts`)");
    }

    let total = wall.elapsed().as_secs_f64();
    println!("--- {spec_str} done in {total:.2}s\n");
    Ok((edges.len(), total))
}
