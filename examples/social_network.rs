//! Social-network scenario (paper §1's motivating query): "how many
//! friends-of-friends-of-friends does a profile have?" — i.e. local
//! 3-neighborhood sizes on a heavy-tailed preferential-attachment graph —
//! plus "who to follow"-style reachability growth curves.
//!
//! Run: `cargo run --release --example social_network`

use degreesketch::comm::Backend;
use degreesketch::coordinator::anf::{neighborhood_approximation, AnfOptions};
use degreesketch::coordinator::sketch::{
    accumulate_stream, AccumulateOptions,
};
use degreesketch::graph::csr::Csr;
use degreesketch::graph::exact;
use degreesketch::graph::gen::GraphSpec;
use degreesketch::graph::stream::{EdgeStream, MemoryStream};
use degreesketch::hll::HllConfig;
use degreesketch::util::stats::mean_relative_error;

fn main() -> anyhow::Result<()> {
    // A 20k-profile social graph (Barabási–Albert, mean degree ~8).
    let spec = GraphSpec::parse("ba:20000:4").unwrap();
    let edges = spec.generate(2026);
    let csr = Csr::from_edges(&edges);
    println!(
        "social graph: {} profiles, {} friendships",
        csr.num_vertices(),
        csr.num_edges()
    );

    let stream = MemoryStream::new(edges);
    let ranks = 8;
    let max_t = 4;
    let ds = accumulate_stream(
        &stream,
        ranks,
        HllConfig::new(8, 0x50C1A1),
        AccumulateOptions {
            backend: Backend::Threaded,
            ..Default::default()
        },
    );
    let shards = stream.shard(ranks);
    let anf = neighborhood_approximation(
        &ds,
        &shards,
        AnfOptions {
            backend: Backend::Threaded,
            max_t,
            ..Default::default()
        },
    );

    // The cost predictor from the paper's intro: the size of the
    // friends-of-friends-of-friends set for the most-followed profiles.
    let mut by_degree: Vec<(usize, u32)> = (0..csr.num_vertices() as u32)
        .map(|v| (csr.degree(v), v))
        .collect();
    by_degree.sort_unstable_by(|a, b| b.cmp(a));
    let truth = exact::neighborhood_sizes(&csr, max_t);
    println!("\ntop profiles: reach estimates (t=1 is degree)");
    println!("profile  degree  est.N2  est.N3  est.N4  exact.N3");
    for &(deg, v) in by_degree.iter().take(5) {
        let id = csr.original_id(v);
        let est = &anf.per_vertex[&id];
        println!(
            "{id:>7}  {deg:>6}  {:>6.0}  {:>6.0}  {:>6.0}  {:>8}",
            est[1], est[2], est[3], truth[v as usize][2]
        );
    }

    // Estimation quality across ALL profiles (the paper's Figure 1 metric).
    for t in 2..=max_t {
        let pairs: Vec<(f64, f64)> = (0..csr.num_vertices() as u32)
            .map(|v| {
                (
                    truth[v as usize][t - 1] as f64,
                    anf.per_vertex[&csr.original_id(v)][t - 1],
                )
            })
            .collect();
        println!(
            "t={t}: MRE over all profiles = {:.4}",
            mean_relative_error(&pairs)
        );
    }

    // Global reach curve Ñ(t) — how fast the network saturates.
    println!("\nglobal neighborhood function:");
    for (t, g) in anf.global.iter().enumerate() {
        println!(
            "  t={}  N(t) = {:.2e}  (avg ball {:.1} profiles)",
            t + 1,
            g,
            g / csr.num_vertices() as f64
        );
    }

    // Distributed-memory cross-check: the same accumulation on forked
    // worker processes (messages ride Unix-socket frames instead of
    // in-memory channels) must produce bit-identical sketches.
    let ds_proc = accumulate_stream(
        &stream,
        ranks,
        HllConfig::new(8, 0x50C1A1),
        AccumulateOptions {
            backend: Backend::Process,
            ..Default::default()
        },
    );
    let mismatches = ds
        .iter()
        .filter(|&(v, h)| ds_proc.sketch(v) != Some(h))
        .count();
    println!(
        "\nprocess backend ({} worker processes): {} profiles, \
         {} sketch mismatches vs threaded, {} frames / {} bytes on the wire",
        ranks,
        ds_proc.num_vertices(),
        mismatches,
        ds_proc.accumulation_stats.flushes,
        ds_proc.accumulation_stats.bytes
    );
    assert_eq!(mismatches, 0, "backends must agree exactly");
    Ok(())
}
