//! Quickstart: accumulate a DegreeSketch over the (real) Zachary karate
//! club, query degrees, neighborhoods and triangle counts, and compare
//! against exact ground truth.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use degreesketch::coordinator::anf::{neighborhood_approximation, AnfOptions};
use degreesketch::coordinator::sketch::{
    accumulate_stream, AccumulateOptions,
};
use degreesketch::coordinator::{
    edge_triangle_heavy_hitters, QueryEngine, TriangleOptions,
};
use degreesketch::graph::csr::Csr;
use degreesketch::graph::exact;
use degreesketch::graph::gen::karate;
use degreesketch::graph::stream::{EdgeStream, MemoryStream};
use degreesketch::hll::HllConfig;

fn main() -> anyhow::Result<()> {
    // 1. The graph arrives as an edge stream, sharded across 4 logical
    //    processors (the paper's σ and P).
    let edges = karate::edges();
    let stream = MemoryStream::new(edges.clone());
    let ranks = 4;

    // 2. Algorithm 1: one pass accumulates a per-vertex HLL sketch shard
    //    on each processor.
    let ds = accumulate_stream(
        &stream,
        ranks,
        HllConfig::new(12, 0xD5),
        AccumulateOptions::default(),
    );
    println!(
        "accumulated {} sketches ({} bytes, {} messages)",
        ds.num_vertices(),
        ds.memory_bytes(),
        ds.accumulation_stats.messages
    );

    // 3. Degree queries straight off the sketch.
    let csr = Csr::from_edges(&edges);
    println!("\nvertex  est.degree  true.degree");
    for v in [0u64, 33, 5] {
        let truth = csr.degree(csr.compact_id(v).unwrap());
        println!("{v:>6}  {:>10.2}  {truth:>11}", ds.degree_estimate(v));
    }

    // 4. Algorithm 2: t-neighborhood sizes (distributed HyperANF).
    let shards = stream.shard(ranks);
    let anf = neighborhood_approximation(
        &ds,
        &shards,
        AnfOptions {
            max_t: 3,
            ..Default::default()
        },
    );
    let truth = exact::neighborhood_sizes(&csr, 3);
    println!("\nvertex  est.N(x,3)  N(x,3)");
    for v in [0u64, 33, 16] {
        let cid = csr.compact_id(v).unwrap() as usize;
        println!(
            "{v:>6}  {:>10.1}  {:>6}",
            anf.per_vertex[&v][2], truth[cid][2]
        );
    }

    // 5. Algorithm 4: edge-local triangle heavy hitters.
    let ds = Arc::new(ds);
    let res = edge_triangle_heavy_hitters(
        &ds,
        &shards,
        &TriangleOptions {
            k: 5,
            ..Default::default()
        },
    );
    println!(
        "\nglobal triangles: estimated {:.1}, exact {}",
        res.global_estimate,
        exact::global_triangles(&csr)
    );
    println!("top-5 edge heavy hitters (est vs exact):");
    for (est, (u, v)) in &res.heavy_hitters {
        let (cu, cv) =
            (csr.compact_id(*u).unwrap(), csr.compact_id(*v).unwrap());
        println!(
            "  ({u},{v})  est ≈ {est:.1}   exact = {}",
            csr.common_neighbors(cu, cv)
        );
    }

    // 6. The leave-behind property: persist and re-load as a query engine.
    let dir = std::env::temp_dir().join("degreesketch_quickstart");
    QueryEngine::new(Arc::try_unwrap(ds).unwrap()).save(&dir)?;
    let engine = QueryEngine::load(&dir)?;
    println!(
        "\nreloaded engine: deg(33) ≈ {:.2}, |adj(0) ∪ adj(33)| ≈ {:.2}",
        engine.degree(33).unwrap(),
        engine.union_cardinality(&[0, 33]).unwrap()
    );
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
