"""AOT lowering: JAX/Pallas computations → HLO text artifacts for rust/PJRT.

Interchange format is HLO **text**, NOT ``lowered.compile().serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py.

Emits, per (p, batch) configuration:

* ``estimate_p{p}_b{batch}.hlo.txt``   — regs[B,R] i32 → est[B] f32
* ``intersect_p{p}_b{batch}.hlo.txt``  — a,b[B,R] i32 → [B,4] f32
                                          (λa, λb, λx, |A∪B|)
* ``union_p{p}_b{batch}.hlo.txt``      — a,b[B,R] i32 → est[B] f32

plus ``manifest.txt``: one line per artifact
``name kind p q r batch file``  consumed by ``rust/src/runtime``.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import functools
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# (p, batch) configurations to compile. p=8 matches the paper's
# neighborhood/scaling experiments, p=12 its heavy-hitter experiments.
CONFIGS = [
    (8, 256),
    (12, 64),
]

WORD_BITS = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(p: int, batch: int) -> dict[str, str]:
    """Lower the three computations for one (p, batch) config."""
    q = WORD_BITS - p
    r = 1 << p
    spec = jax.ShapeDtypeStruct((batch, r), jnp.int32)

    est = jax.jit(functools.partial(model.batched_estimate, q=q))
    inter = jax.jit(functools.partial(model.batched_intersect, q=q))
    union = jax.jit(functools.partial(model.batched_union_estimate, q=q))

    return {
        f"estimate_p{p}_b{batch}": to_hlo_text(est.lower(spec)),
        f"intersect_p{p}_b{batch}": to_hlo_text(inter.lower(spec, spec)),
        f"union_p{p}_b{batch}": to_hlo_text(union.lower(spec, spec)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=",".join(f"{p}:{b}" for p, b in CONFIGS),
        help="comma list of p:batch pairs",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    configs = [tuple(map(int, c.split(":"))) for c in args.configs.split(",")]
    manifest_lines = []
    for p, batch in configs:
        q = WORD_BITS - p
        r = 1 << p
        arts = lower_artifacts(p, batch)
        for name, text in arts.items():
            kind = name.split("_")[0]
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest_lines.append(f"{name} {kind} {p} {q} {r} {batch} {fname}")
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
