"""Pure-jnp reference oracle for the Pallas HLL register kernels.

These functions define the *ground-truth semantics* of the L1 kernels in
``hll_kernels.py``. Everything here is straight-line jnp over dense register
arrays; the Pallas kernels must match these bit-for-bit (integers) or to
float tolerance (harmonic sums). The pytest suite (``python/tests``) sweeps
shapes and register distributions with hypothesis and asserts agreement.

Register conventions (shared with the rust implementation, see
``rust/src/hll``):

* An HLL(p, q) sketch has ``r = 2**p`` registers with integer values in
  ``[0, q + 1]``; value 0 means "never touched".
* ``kmax = q + 1`` is the saturation value, so each register takes one of
  ``q + 2`` distinct values.
"""

from __future__ import annotations

import jax.numpy as jnp


def harmonic_stats(regs: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-sketch harmonic sum and zero-register count.

    Args:
      regs: int32 array ``[B, R]`` of register values.

    Returns:
      ``(hsum, zeros)`` where ``hsum[b] = sum_i 2**-regs[b, i]`` (float32;
      zero registers contribute 1.0) and ``zeros[b] = #{i : regs[b,i] == 0}``
      (int32).
    """
    hsum = jnp.sum(jnp.exp2(-regs.astype(jnp.float32)), axis=-1)
    zeros = jnp.sum((regs == 0).astype(jnp.int32), axis=-1)
    return hsum, zeros


def register_histogram(regs: jnp.ndarray, kmax: int) -> jnp.ndarray:
    """Per-sketch histogram of register values.

    Args:
      regs: int32 ``[B, R]``.
      kmax: maximum register value (``q + 1``).

    Returns:
      int32 ``[B, kmax + 1]`` with ``out[b, k] = #{i : regs[b, i] == k}``.
    """
    ks = jnp.arange(kmax + 1, dtype=regs.dtype)
    return jnp.sum(
        (regs[:, :, None] == ks[None, None, :]).astype(jnp.int32), axis=1
    )


def pair_stats(a: jnp.ndarray, b: jnp.ndarray, kmax: int) -> jnp.ndarray:
    """Joint register-comparison count statistics (paper Eq. 19).

    For each sketch pair, counts per register value ``k`` in five categories:

    * ``out[b, 0, k] = #{i : k = a_i <  b_i}``  (``c_k^{A,<}``)
    * ``out[b, 1, k] = #{i : k = a_i >  b_i}``  (``c_k^{A,>}``)
    * ``out[b, 2, k] = #{i : k = b_i <  a_i}``  (``c_k^{B,<}``)
    * ``out[b, 3, k] = #{i : k = b_i >  a_i}``  (``c_k^{B,>}``)
    * ``out[b, 4, k] = #{i : k = a_i =  b_i}``  (``c_k^{=}``)

    These are the sufficient statistics for the joint Poisson MLE
    intersection estimator (Ertl 2017); the likelihood never needs the raw
    registers once these are known.

    Args:
      a, b: int32 ``[B, R]`` register arrays of two sketch batches.
      kmax: maximum register value (``q + 1``).

    Returns:
      int32 ``[B, 5, kmax + 1]``.
    """
    ks = jnp.arange(kmax + 1, dtype=a.dtype)[None, None, :]
    a3 = a[:, :, None]
    b3 = b[:, :, None]
    lt = (a < b)[:, :, None]
    gt = (a > b)[:, :, None]
    eq = (a == b)[:, :, None]
    c_a_lt = jnp.sum(((a3 == ks) & lt).astype(jnp.int32), axis=1)
    c_a_gt = jnp.sum(((a3 == ks) & gt).astype(jnp.int32), axis=1)
    c_b_lt = jnp.sum(((b3 == ks) & gt).astype(jnp.int32), axis=1)
    c_b_gt = jnp.sum(((b3 == ks) & lt).astype(jnp.int32), axis=1)
    c_eq = jnp.sum(((a3 == ks) & eq).astype(jnp.int32), axis=1)
    return jnp.stack([c_a_lt, c_a_gt, c_b_lt, c_b_gt, c_eq], axis=1)


def union_registers(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Element-wise register max — the HLL union/merge (paper Alg. 6)."""
    return jnp.maximum(a, b)
