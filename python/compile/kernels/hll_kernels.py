"""Layer-1 Pallas kernels: the HLL register-crunch hot spot.

Three kernels operate on dense register arrays ``[B, R]`` (``R = 2**p``,
int32 values in ``[0, q + 1]``):

* ``harmonic``  — per-sketch harmonic sum ``sum_i 2**-r_i`` + zero count.
* ``histogram`` — per-sketch register-value histogram ``[B, kmax + 1]``.
* ``pair_stats`` — per-pair Eq. 19 comparison statistics ``[B, 5, kmax+1]``.

All are written against the TPU mental model (see DESIGN.md
§Hardware-Adaptation): the register axis stays resident in VMEM while
BlockSpec partitions the batch axis into row blocks; histograms are expressed
as masked reductions (VPU-friendly — no scatter). ``interpret=True`` is
mandatory here: real TPU lowering emits Mosaic custom-calls the CPU PJRT
plugin cannot execute, and correctness is validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of registers processed per kernel invocation. 8 rows of 4096 int32
# registers = 128 KiB per operand block: comfortably VMEM-resident alongside
# the (tiny) output block.
DEFAULT_BLOCK_B = 8


def _block_b(batch: int, block_b: int) -> int:
    """Largest block size that divides ``batch`` and is <= ``block_b``."""
    bb = min(block_b, batch)
    while batch % bb != 0:
        bb -= 1
    return bb


# ---------------------------------------------------------------------------
# harmonic: [B, R] -> (hsum [B], zeros [B])
# ---------------------------------------------------------------------------


def _harmonic_kernel(regs_ref, hsum_ref, zeros_ref):
    regs = regs_ref[...]
    hsum_ref[...] = jnp.sum(jnp.exp2(-regs.astype(jnp.float32)), axis=-1)
    zeros_ref[...] = jnp.sum((regs == 0).astype(jnp.int32), axis=-1)


def harmonic(regs: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B):
    """Pallas harmonic-sum kernel; see ``ref.harmonic_stats``."""
    batch, r = regs.shape
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        _harmonic_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, r), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,
    )(regs)


# ---------------------------------------------------------------------------
# histogram: [B, R] -> [B, kmax + 1]
# ---------------------------------------------------------------------------


def _histogram_kernel(regs_ref, out_ref, *, kmax: int):
    regs = regs_ref[...]
    # Masked reduction per bucket: out[b, k] = sum_i (regs[b, i] == k).
    ks = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kmax + 1), 2)
    eq = (regs[:, :, None] == ks).astype(jnp.int32)
    out_ref[...] = jnp.sum(eq, axis=1)


def histogram(
    regs: jnp.ndarray, kmax: int, *, block_b: int = DEFAULT_BLOCK_B
) -> jnp.ndarray:
    """Pallas register-histogram kernel; see ``ref.register_histogram``."""
    batch, r = regs.shape
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        functools.partial(_histogram_kernel, kmax=kmax),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, kmax + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, kmax + 1), jnp.int32),
        interpret=True,
    )(regs)


# ---------------------------------------------------------------------------
# pair_stats: [B, R] x [B, R] -> [B, 5, kmax + 1]   (paper Eq. 19)
# ---------------------------------------------------------------------------


def _pair_stats_kernel(a_ref, b_ref, out_ref, *, kmax: int):
    a = a_ref[...]
    b = b_ref[...]
    ks = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kmax + 1), 2)
    a3 = a[:, :, None]
    b3 = b[:, :, None]
    lt = (a < b)[:, :, None]
    gt = (a > b)[:, :, None]
    eq = (a == b)[:, :, None]
    i32 = jnp.int32
    c_a_lt = jnp.sum(((a3 == ks) & lt).astype(i32), axis=1)
    c_a_gt = jnp.sum(((a3 == ks) & gt).astype(i32), axis=1)
    c_b_lt = jnp.sum(((b3 == ks) & gt).astype(i32), axis=1)
    c_b_gt = jnp.sum(((b3 == ks) & lt).astype(i32), axis=1)
    c_eq = jnp.sum(((a3 == ks) & eq).astype(i32), axis=1)
    out_ref[...] = jnp.stack([c_a_lt, c_a_gt, c_b_lt, c_b_gt, c_eq], axis=1)


def pair_stats(
    a: jnp.ndarray,
    b: jnp.ndarray,
    kmax: int,
    *,
    block_b: int = DEFAULT_BLOCK_B,
) -> jnp.ndarray:
    """Pallas Eq.-19 pair-statistics kernel; see ``ref.pair_stats``."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    batch, r = a.shape
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        functools.partial(_pair_stats_kernel, kmax=kmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 5, kmax + 1), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, 5, kmax + 1), jnp.int32),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# union_harmonic: fused merge + harmonic for the union estimate
# ---------------------------------------------------------------------------


def _union_harmonic_kernel(a_ref, b_ref, hsum_ref, zeros_ref):
    u = jnp.maximum(a_ref[...], b_ref[...])
    hsum_ref[...] = jnp.sum(jnp.exp2(-u.astype(jnp.float32)), axis=-1)
    zeros_ref[...] = jnp.sum((u == 0).astype(jnp.int32), axis=-1)


def union_harmonic(
    a: jnp.ndarray, b: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B
):
    """Fused register-max + harmonic stats of the union sketch."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    batch, r = a.shape
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        _union_harmonic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# union_histogram: fused merge + histogram (for the union cardinality
# estimate via the improved estimator, which consumes histograms)
# ---------------------------------------------------------------------------


def _union_histogram_kernel(a_ref, b_ref, out_ref, *, kmax: int):
    u = jnp.maximum(a_ref[...], b_ref[...])
    ks = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kmax + 1), 2)
    eq = (u[:, :, None] == ks).astype(jnp.int32)
    out_ref[...] = jnp.sum(eq, axis=1)


def union_histogram(
    a: jnp.ndarray,
    b: jnp.ndarray,
    kmax: int,
    *,
    block_b: int = DEFAULT_BLOCK_B,
) -> jnp.ndarray:
    """Fused register-max + histogram of the union sketch."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    batch, r = a.shape
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        functools.partial(_union_histogram_kernel, kmax=kmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, kmax + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, kmax + 1), jnp.int32),
        interpret=True,
    )(a, b)
