"""Layer-2 JAX compute graphs for DegreeSketch estimation.

Two exported computations (lowered AOT by ``aot.py`` and executed from the
rust coordinator via PJRT — python is never on the request path):

* ``batched_estimate``: dense register arrays ``[B, R]`` → cardinality
  estimates ``[B]`` using Ertl's *improved* estimator (σ/τ corrections; Ertl
  2017, Alg. 6). Unlike LogLogBeta it needs no empirically fitted constants,
  which keeps the PJRT artifact self-contained; the rust side implements the
  identical math natively so the two backends can be cross-checked.

* ``batched_intersect``: two register arrays ``[B, R]`` → ``[B, 4]`` of
  ``(λa, λb, λx, |A∪B|)`` where λx estimates ``|A ∩ B|`` via the joint
  Poisson maximum-likelihood model over the Eq. 19 count statistics
  (paper §4.1; Ertl 2017 §'joint MLE'). The statistics are produced by the
  Layer-1 Pallas kernel; the optimizer is a fixed-iteration Adam ascent on
  ``θ = log λ`` so the whole solve lowers to a single fori_loop in HLO.

Poisson model recap: registers of A are ``max(Ka', Kx)`` and of B are
``max(Kb', Kx)`` with independent per-register rates ``va = λa/m`` etc.;
``P(K ≤ k) = exp(-v·2^-k)`` for ``0 ≤ k ≤ q`` and 1 at ``k = q+1``. The
log-likelihood decomposes over the five Eq. 19 count vectors — see
``_log_likelihood`` for the numerically stable (expm1-based) factorization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import hll_kernels, ref

ALPHA_INF = 1.0 / (2.0 * jnp.log(2.0))  # α∞ = 1/(2 ln 2)

# Fixed iteration counts: these must be static so the AOT artifact is a
# single closed HLO module (no host control flow at runtime).
SIGMA_ITERS = 96
TAU_ITERS = 48
MLE_ITERS = 220


# ---------------------------------------------------------------------------
# Ertl improved single-sketch estimator (from a register histogram)
# ---------------------------------------------------------------------------


def _sigma(x: jnp.ndarray) -> jnp.ndarray:
    """Ertl's σ(x) = x + Σ_{k≥1} x^(2^k) · 2^(k-1), computed iteratively.

    Converges for x ∈ [0, 1); at x = 1 it diverges, which the estimate
    formula turns into a 0 cardinality (empty sketch) in the limit.
    """

    def body(_, state):
        xk, y, z = state
        xk = xk * xk
        z = z + xk * y
        y = 2.0 * y
        return (xk, y, z)

    _, _, z = jax.lax.fori_loop(0, SIGMA_ITERS, body, (x, 1.0, x))
    return z


def _tau(x: jnp.ndarray) -> jnp.ndarray:
    """Ertl's τ(x) = (1/3)(1 - x - Σ_{k≥1} (1 - x^(2^-k))² · 2^-k)."""

    def body(_, state):
        xk, y, z = state
        xk = jnp.sqrt(xk)
        y = 0.5 * y
        z = z - jnp.square(1.0 - xk) * y
        return (xk, y, z)

    _, _, z = jax.lax.fori_loop(0, TAU_ITERS, body, (x, 1.0, 1.0 - x))
    return z / 3.0


def ertl_estimate_from_hist(hist: jnp.ndarray, q: int) -> jnp.ndarray:
    """Improved cardinality estimate from register histograms.

    Args:
      hist: ``[B, q + 2]`` float array, ``hist[b, k] = #registers == k``.
      q: 64 - p; register values live in ``[0, q + 1]``.

    Returns:
      ``[B]`` cardinality estimates.
    """
    hist = hist.astype(jnp.float64)
    m = jnp.sum(hist, axis=-1)
    ks = jnp.arange(q + 2, dtype=jnp.float64)
    # Σ_{k=1}^{q} C[k]·2^-k (k = 0 and k = q+1 are handled by σ/τ terms).
    mid_mask = (ks >= 1) & (ks <= q)
    mid = jnp.sum(jnp.where(mid_mask, hist * jnp.exp2(-ks), 0.0), axis=-1)
    z = (
        m * _tau(1.0 - hist[:, q + 1] / m) * (2.0 ** float(-q))
        + mid
        + m * _sigma(hist[:, 0] / m)
    )
    return (ALPHA_INF * m * m / z).astype(jnp.float32)


def batched_estimate(regs: jnp.ndarray, *, q: int) -> jnp.ndarray:
    """[B, R] int32 registers → [B] float32 cardinality estimates."""
    hist = hll_kernels.histogram(regs, q + 1)
    return ertl_estimate_from_hist(hist, q)


def batched_union_estimate(
    a: jnp.ndarray, b: jnp.ndarray, *, q: int
) -> jnp.ndarray:
    """[B, R] x2 → [B] float32 estimates of |A ∪ B| (fused merge kernel)."""
    hist = hll_kernels.union_histogram(a, b, q + 1)
    return ertl_estimate_from_hist(hist, q)


# ---------------------------------------------------------------------------
# Joint Poisson MLE intersection
# ---------------------------------------------------------------------------

_TINY = 1e-300


def _log_likelihood(
    theta: jnp.ndarray, stats: jnp.ndarray, q: int, m: float
) -> jnp.ndarray:
    """Log-likelihood of Eq. 19 count statistics under the Poisson model.

    Args:
      theta: ``[3]`` log-rates ``(log λa, log λb, log λx)``.
      stats: ``[5, q + 2]`` float64 count statistics for ONE pair.
      q: 64 - p.
      m: number of registers.

    Returns: scalar log-likelihood.
    """
    lam = jnp.exp(theta)
    va, vb, vx = lam[0] / m, lam[1] / m, lam[2] / m

    ks = jnp.arange(q + 2, dtype=jnp.float64)
    # t_k = 2^-k for k ≤ q; the saturation bucket k = q+1 reuses t_q.
    t = jnp.where(ks <= q, jnp.exp2(-ks), 2.0 ** float(-q))
    sat = ks == (q + 1)

    def log_dF(u):
        # ΔF_u(k) = F_u(k) - F_u(k-1), stable via expm1:
        #   k = 0      : exp(-u)
        #   1 ≤ k ≤ q  : exp(-u·2^-k)·(-expm1(-u·2^-k))
        #   k = q + 1  : -expm1(-u·2^-q)
        ut = u * t
        body = -ut + jnp.log(jnp.maximum(-jnp.expm1(-ut), _TINY))
        body = jnp.where(sat, jnp.log(jnp.maximum(-jnp.expm1(-ut), _TINY)), body)
        return jnp.where(ks == 0, -u, body)

    # Unequal-register terms factorize (paper App. B / Ertl):
    #   a = k < b contributes ΔF_{va+vx}(k); the matching b = k' > a
    #   contributes ΔF_vb(k'); symmetric for a > b.
    ll = jnp.sum(stats[0] * log_dF(va + vx))
    ll += jnp.sum(stats[3] * log_dF(vb))
    ll += jnp.sum(stats[2] * log_dF(vb + vx))
    ll += jnp.sum(stats[1] * log_dF(va))

    # Equal registers a = b = k:
    #   pmf(k) = exp(-(va+vb+vx)·t)·B(t)   for 1 ≤ k ≤ q
    #   pmf(q+1) = B(2^-q),  pmf(0) = exp(-(va+vb+vx))
    # with the cancellation-free bracket
    #   B(t) = expm1(-(va+vx)t)·expm1(-(vb+vx)t)
    #        + exp(-(va+vb+vx)t)·(-expm1(-vx·t)).
    vs = va + vb + vx
    bracket = jnp.expm1(-(va + vx) * t) * jnp.expm1(-(vb + vx) * t) + jnp.exp(
        -vs * t
    ) * (-jnp.expm1(-vx * t))
    log_eq = jnp.where(sat, 0.0, -vs * t) + jnp.log(jnp.maximum(bracket, _TINY))
    log_eq = jnp.where(ks == 0, -vs, log_eq)
    ll += jnp.sum(stats[4] * log_eq)
    return ll


def _mle_single(stats: jnp.ndarray, q: int, m: float) -> jnp.ndarray:
    """Adam ascent of the joint likelihood for one pair's statistics.

    Returns ``[3]`` = (λa, λb, λx).
    """
    stats = stats.astype(jnp.float64)

    # Initialization from the inclusion-exclusion principle (paper Eq. 18)
    # using single-sketch improved estimates derived from the same stats:
    #   hist_A = c^{A,<} + c^{A,>} + c^=,   hist_B symmetric,
    #   hist_U[k] = c^{A,>}[k] + c^{B,>}[k] + c^=[k]  (register-wise max).
    hist_a = (stats[0] + stats[1] + stats[4])[None, :]
    hist_b = (stats[2] + stats[3] + stats[4])[None, :]
    hist_u = (stats[1] + stats[3] + stats[4])[None, :]
    est_a = ertl_estimate_from_hist(hist_a, q)[0].astype(jnp.float64)
    est_b = ertl_estimate_from_hist(hist_b, q)[0].astype(jnp.float64)
    est_u = ertl_estimate_from_hist(hist_u, q)[0].astype(jnp.float64)
    inter0 = jnp.clip(est_a + est_b - est_u, 1.0, jnp.minimum(est_a, est_b))
    a0 = jnp.maximum(est_a - inter0, 1.0)
    b0 = jnp.maximum(est_b - inter0, 1.0)
    theta0 = jnp.log(jnp.stack([a0, b0, inter0]))

    grad_fn = jax.grad(_log_likelihood)
    beta1, beta2, eps = 0.9, 0.999, 1e-8

    def body(i, state):
        theta, mom, vel = state
        g = grad_fn(theta, stats, q, m)
        lr = 0.35 * (0.02 / 0.35) ** (i / MLE_ITERS)  # exp decay 0.35 → 0.02
        mom = beta1 * mom + (1.0 - beta1) * g
        vel = beta2 * vel + (1.0 - beta2) * g * g
        mhat = mom / (1.0 - beta1 ** (i + 1.0))
        vhat = vel / (1.0 - beta2 ** (i + 1.0))
        theta = theta + lr * mhat / (jnp.sqrt(vhat) + eps)
        # λ ∈ [2^-16, m·2^70]: keep exp() finite and rates sane.
        theta = jnp.clip(theta, -11.0, jnp.log(m) + 48.0)
        return (theta, mom, vel)

    zeros = jnp.zeros_like(theta0)
    theta, _, _ = jax.lax.fori_loop(0, MLE_ITERS, body, (theta0, zeros, zeros))
    return jnp.exp(theta)


def batched_intersect(a: jnp.ndarray, b: jnp.ndarray, *, q: int) -> jnp.ndarray:
    """Joint-MLE intersection over a batch of register-array pairs.

    Args:
      a, b: int32 ``[B, R]`` register arrays.
      q: 64 - p.

    Returns:
      float32 ``[B, 4]``: columns ``(λa = |A\\B|, λb = |B\\A|,
      λx = |A ∩ B|, |A ∪ B|)``.
    """
    m = float(a.shape[1])
    stats = hll_kernels.pair_stats(a, b, q + 1)
    lam = jax.vmap(functools.partial(_mle_single, q=q, m=m))(stats)
    union = batched_union_estimate(a, b, q=q).astype(jnp.float64)
    return jnp.concatenate(
        [lam, union[:, None]], axis=-1
    ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Reference (pure-jnp) counterparts used by pytest to validate the Pallas
# route end-to-end: same math, ref.py statistics instead of kernels.
# ---------------------------------------------------------------------------


def batched_estimate_ref(regs: jnp.ndarray, *, q: int) -> jnp.ndarray:
    hist = ref.register_histogram(regs, q + 1)
    return ertl_estimate_from_hist(hist, q)


def batched_intersect_ref(
    a: jnp.ndarray, b: jnp.ndarray, *, q: int
) -> jnp.ndarray:
    m = float(a.shape[1])
    stats = ref.pair_stats(a, b, q + 1)
    lam = jax.vmap(functools.partial(_mle_single, q=q, m=m))(stats)
    hist_u = ref.register_histogram(ref.union_registers(a, b), q + 1)
    union = ertl_estimate_from_hist(hist_u, q).astype(jnp.float64)
    return jnp.concatenate([lam, union[:, None]], axis=-1).astype(jnp.float32)
