"""L2 model tests: estimator accuracy and MLE intersection recovery.

These are statistical tests with planted ground truth: sets of known
cardinality and overlap are hashed into registers and the estimators must
recover them within a few multiples of the HLL standard error
(≈ 1.04/sqrt(2^p)).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from tests.sketch_sim import build_registers


@pytest.mark.parametrize("p", [6, 8])
@pytest.mark.parametrize("n", [0, 1, 5, 50, 500, 5000, 50000])
def test_estimate_accuracy(p, n):
    q = 64 - p
    rng = np.random.default_rng(p * 1000 + n)
    ids = rng.integers(0, 1 << 62, n)
    regs = jnp.array(build_registers(ids, p)[None])
    est = float(model.batched_estimate_ref(regs, q=q)[0])
    if n == 0:
        assert est < 1.0
    else:
        se = 1.04 / np.sqrt(1 << p)
        # 5 standard errors + small-range slack.
        assert abs(est - n) <= max(5 * se * n, 3.0), (est, n)


@pytest.mark.parametrize("p", [8])
def test_estimate_pallas_equals_ref(p):
    q = 64 - p
    rng = np.random.default_rng(0)
    regs = np.stack(
        [build_registers(rng.integers(0, 1 << 62, n), p) for n in (10, 1000)]
    )
    a = model.batched_estimate(jnp.array(regs), q=q)
    b = model.batched_estimate_ref(jnp.array(regs), q=q)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5)


def _planted_pair(p, na, nb, nx, seed):
    rng = np.random.default_rng(seed)
    univ = rng.integers(0, 1 << 62, na + nb - nx)
    A = univ[:na]
    B = univ[na - nx :]
    return (
        jnp.array(build_registers(A, p)[None]),
        jnp.array(build_registers(B, p)[None]),
    )


@pytest.mark.parametrize(
    "na,nb,nx",
    [
        (3000, 3000, 1500),
        (5000, 5000, 4000),
        (10000, 2000, 1500),
    ],
)
def test_mle_intersection_recovery(na, nb, nx):
    """Large relative intersections must be recovered within ~20%.

    (The paper's own App. B shows small relative intersections are
    unrecoverable — that regime is exercised by fig7/fig8 benches, not
    asserted here.)
    """
    p, q = 8, 56
    a, b = _planted_pair(p, na, nb, nx, seed=na * 7 + nb * 3 + nx)
    out = np.array(model.batched_intersect_ref(a, b, q=q))[0]
    lam_a, lam_b, lam_x, union = out
    assert abs(lam_x - nx) / nx < 0.25, out
    assert abs(union - (na + nb - nx)) / (na + nb - nx) < 0.1, out
    assert abs(lam_a - (na - nx)) / max(na - nx, 1) < 0.35, out
    assert abs(lam_b - (nb - nx)) / max(nb - nx, 1) < 0.35, out


def test_mle_pallas_equals_ref():
    p, q = 6, 58
    a, b = _planted_pair(p, 2000, 2000, 1000, seed=11)
    out_k = np.array(model.batched_intersect(a, b, q=q))
    out_r = np.array(model.batched_intersect_ref(a, b, q=q))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-4)


def test_union_estimate_equals_merged_estimate():
    """|A ∪ B| via the fused kernel == estimate of the merged sketch."""
    p, q = 8, 56
    a, b = _planted_pair(p, 4000, 3000, 500, seed=3)
    u = jnp.maximum(a, b)
    fused = np.array(model.batched_union_estimate(a, b, q=q))
    merged = np.array(model.batched_estimate_ref(u, q=q))
    np.testing.assert_allclose(fused, merged, rtol=1e-5)


def test_disjoint_sets_small_intersection():
    """Disjoint sets must not produce a large phantom intersection."""
    p, q = 8, 56
    rng = np.random.default_rng(42)
    A = rng.integers(0, 1 << 61, 3000)
    B = rng.integers((1 << 61), 1 << 62, 3000)
    a = jnp.array(build_registers(A, p)[None])
    b = jnp.array(build_registers(B, p)[None])
    out = np.array(model.batched_intersect_ref(a, b, q=q))[0]
    # phantom intersection below ~15% of |A|
    assert out[2] < 0.15 * 3000, out


def test_sigma_tau_bounds():
    """σ, τ sanity: σ(0)=0, τ(0)=τ(1)=0, monotone σ on [0, 0.9]."""
    xs = jnp.linspace(0.0, 0.9, 10).astype(jnp.float64)
    sig = np.array(jax.vmap(model._sigma)(xs))
    assert sig[0] == 0.0
    assert np.all(np.diff(sig) > 0)
    # finite TAU_ITERS leaves a 2^-TAU_ITERS/3 residue at x = 0
    assert abs(float(model._tau(jnp.float64(0.0)))) < 1e-12
    assert abs(float(model._tau(jnp.float64(1.0)))) < 1e-12
