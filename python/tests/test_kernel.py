"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (batch, p) and register distributions; integer
outputs must match exactly, float outputs to tight tolerance.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hll_kernels as hk
from compile.kernels import ref

WORD_BITS = 64


def random_regs(rng, batch, r, kmax, zero_frac):
    regs = rng.integers(0, kmax + 1, (batch, r)).astype(np.int32)
    regs[rng.random((batch, r)) < zero_frac] = 0
    return regs


reg_cases = st.tuples(
    st.integers(min_value=1, max_value=13),  # batch (incl. non-divisible)
    st.sampled_from([4, 5, 6, 8]),  # p
    st.floats(min_value=0.0, max_value=1.0),  # zero fraction
    st.integers(min_value=0, max_value=2**32 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(reg_cases)
def test_harmonic_matches_ref(case):
    batch, p, zf, seed = case
    q = WORD_BITS - p
    rng = np.random.default_rng(seed)
    regs = jnp.array(random_regs(rng, batch, 1 << p, q + 1, zf))
    h_k, z_k = hk.harmonic(regs)
    h_r, z_r = ref.harmonic_stats(regs)
    np.testing.assert_allclose(np.array(h_k), np.array(h_r), rtol=1e-6)
    np.testing.assert_array_equal(np.array(z_k), np.array(z_r))


@settings(max_examples=40, deadline=None)
@given(reg_cases)
def test_histogram_matches_ref(case):
    batch, p, zf, seed = case
    q = WORD_BITS - p
    rng = np.random.default_rng(seed)
    regs = jnp.array(random_regs(rng, batch, 1 << p, q + 1, zf))
    np.testing.assert_array_equal(
        np.array(hk.histogram(regs, q + 1)),
        np.array(ref.register_histogram(regs, q + 1)),
    )


@settings(max_examples=40, deadline=None)
@given(reg_cases)
def test_pair_stats_matches_ref(case):
    batch, p, zf, seed = case
    q = WORD_BITS - p
    rng = np.random.default_rng(seed)
    a = jnp.array(random_regs(rng, batch, 1 << p, q + 1, zf))
    b = jnp.array(random_regs(rng, batch, 1 << p, q + 1, 1.0 - zf))
    np.testing.assert_array_equal(
        np.array(hk.pair_stats(a, b, q + 1)),
        np.array(ref.pair_stats(a, b, q + 1)),
    )


@settings(max_examples=25, deadline=None)
@given(reg_cases)
def test_union_kernels_match_ref(case):
    batch, p, zf, seed = case
    q = WORD_BITS - p
    rng = np.random.default_rng(seed)
    a = jnp.array(random_regs(rng, batch, 1 << p, q + 1, zf))
    b = jnp.array(random_regs(rng, batch, 1 << p, q + 1, zf))
    u = ref.union_registers(a, b)
    h_k, z_k = hk.union_harmonic(a, b)
    h_r, z_r = ref.harmonic_stats(u)
    np.testing.assert_allclose(np.array(h_k), np.array(h_r), rtol=1e-6)
    np.testing.assert_array_equal(np.array(z_k), np.array(z_r))
    np.testing.assert_array_equal(
        np.array(hk.union_histogram(a, b, q + 1)),
        np.array(ref.register_histogram(u, q + 1)),
    )


def test_pair_stats_invariants():
    """Category counts partition the register set (sum over all = r)."""
    rng = np.random.default_rng(7)
    p, q = 6, 58
    a = jnp.array(random_regs(rng, 4, 1 << p, q + 1, 0.4))
    b = jnp.array(random_regs(rng, 4, 1 << p, q + 1, 0.4))
    s = np.array(ref.pair_stats(a, b, q + 1))
    # lt_a + gt_a + eq partitions A's registers:
    np.testing.assert_array_equal(
        s[:, 0].sum(-1) + s[:, 1].sum(-1) + s[:, 4].sum(-1), 1 << p
    )
    # count of (a < b) registers equals count of (b > a) registers:
    np.testing.assert_array_equal(s[:, 0].sum(-1), s[:, 3].sum(-1))
    np.testing.assert_array_equal(s[:, 1].sum(-1), s[:, 2].sum(-1))


def test_shape_mismatch_raises():
    a = jnp.zeros((2, 64), jnp.int32)
    b = jnp.zeros((3, 64), jnp.int32)
    with pytest.raises(ValueError):
        hk.pair_stats(a, b, 59)
    with pytest.raises(ValueError):
        hk.union_harmonic(a, b)
