"""Shared helper: simulate HLL register arrays for known-cardinality sets.

Uses splitmix64 as the element hash — the same mixer family as the rust
side's PRNGs — so tests exercise realistic register distributions rather
than uniform-random register values.
"""

import numpy as np

MASK = (1 << 64) - 1


def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


def build_registers(ids, p: int) -> np.ndarray:
    """Insert ``ids`` into a fresh HLL(p, 64-p) and return its registers."""
    q = 64 - p
    regs = np.zeros(1 << p, np.int32)
    for e in ids:
        w = splitmix64(int(e))
        j = w >> (64 - p)
        rest = (w << p) & MASK
        rho = min((64 - rest.bit_length()) + 1 if rest else q + 1, q + 1)
        if rho > regs[j]:
            regs[j] = rho
    return regs
