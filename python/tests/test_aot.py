"""AOT smoke tests: lowering produces parseable-looking HLO text.

Full round-trip execution (load + compile + run via PJRT) is covered on the
rust side (``rust/tests/pjrt_roundtrip.rs``); here we check the emission
path itself stays healthy and the manifest format is stable.
"""

import os

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot


def test_lower_small_config():
    arts = aot.lower_artifacts(p=4, batch=4)
    assert set(arts) == {"estimate_p4_b4", "intersect_p4_b4", "union_p4_b4"}
    for name, text in arts.items():
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # jax >= 0.5 protos are rejected by xla_extension 0.5.1; text output
        # must not be binary proto bytes.
        assert text.isprintable() or "\n" in text


def test_manifest_format(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--configs", "4:4"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 3
    for line in manifest:
        name, kind, p, q, r, batch, fname = line.split()
        assert kind in ("estimate", "intersect", "union")
        assert int(p) + int(q) == 64
        assert int(r) == 1 << int(p)
        assert (out / fname).exists()
