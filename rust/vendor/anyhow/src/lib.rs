//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! crates.io is unreachable in the build environment, so this vendored
//! shim provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait on `Result`/`Option`, and
//! the [`anyhow!`] / [`bail!`] macros. Semantics mirror upstream:
//!
//! * `Error` is a cheap message + cause chain; it deliberately does **not**
//!   implement `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` conversion (and thus `?`) legal;
//! * `{:#}` formats the full chain as `msg: cause: cause`, `{:?}` as a
//!   multi-line report, matching how callers print `error: {e:#}`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // capture the std cause chain eagerly as owned messages, then
        // build the linked chain innermost-first
        let mut causes = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            causes.push(c.to_string());
            cur = c.source();
        }
        let mut chain: Option<Box<Error>> = None;
        for msg in causes.into_iter().rev() {
            chain = Some(Box::new(Error { msg, source: chain }));
        }
        Error {
            msg: e.to_string(),
            source: chain,
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, as in upstream anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("opening file");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        assert_eq!(format!("{e:#}"), "opening file: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let r: Result<u32> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(format!("{}", r.unwrap_err()), "missing 7");
        fn f() -> Result<()> {
            bail!("boom {}", 2)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 2");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
