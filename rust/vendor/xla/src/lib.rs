//! Type-level stub of the `xla` PJRT bindings.
//!
//! The real XLA/PJRT shared library is not present in the offline build
//! environment, so this crate supplies just enough API surface for
//! `degreesketch::runtime` to compile unchanged. Every load/compile entry
//! point returns [`Error`], so the PJRT path fails fast at runtime with a
//! clear message while the native estimators keep working; when a real
//! `xla` crate is swapped back in (same API), no caller changes.

use std::fmt;

/// Error type mirroring the bindings' debug-printable error.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT runtime is not available in this build \
         (offline stub; native estimators remain fully functional)"
            .to_string(),
    ))
}

/// Stub PJRT client: construction succeeds so `info`-style commands can
/// report the platform, but compilation/execution is unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "stub (no PJRT runtime linked)".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Stub HLO module proto; text parsing always fails (no parser linked).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// Stub computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Self {
        Self
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loaders_fail_fast_with_message() {
        assert!(PjRtClient::cpu().is_ok());
        let e = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(format!("{e:?}").contains("not available"));
    }
}
