//! `dslint` — the DegreeSketch invariant linter.
//!
//! Scans `<root>/rust/src/**/*.rs` with a comment/string-aware lexer
//! and enforces the cross-file contracts catalogued in
//! `CONTRIBUTING.md` (SAFETY/RELAXED annotations, frame-kind registry
//! integrity, BOOL_FLAGS parity, config-key wiring, trace-event
//! vocabulary, the transport quiescence invariant).
//!
//! Usage: `dslint [--root DIR]` (root defaults to the current
//! directory; CI runs it from the repository root). Exits 1 when any
//! violation is found, printing one `file:line: rule: message` per
//! finding.

mod lexer;
mod rules;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => {
                    eprintln!("dslint: --root requires a directory");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: dslint [--root DIR]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("dslint: unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }

    let tree = match rules::Tree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("dslint: failed to read {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if tree.files.is_empty() {
        eprintln!(
            "dslint: no Rust sources under {}/rust/src",
            root.display()
        );
        return ExitCode::from(2);
    }

    let mut violations = Vec::new();
    for rule in rules::all_rules() {
        violations.extend(rule.check(&tree));
    }
    violations.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });

    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!(
            "dslint: {} files scanned, {} rules, 0 violations",
            tree.files.len(),
            rules::all_rules().len()
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "dslint: {} violation(s) across {} files scanned",
            violations.len(),
            tree.files.len()
        );
        ExitCode::FAILURE
    }
}
