//! The invariant rules `dslint` enforces, each encoding one cross-file
//! contract the compiler cannot see:
//!
//! * `safety-comment` — every `unsafe` block / `unsafe impl` carries a
//!   `// SAFETY:` justification (mirror of clippy's
//!   `undocumented_unsafe_blocks`, so the tree stays clean even when
//!   only one of the two tools runs).
//! * `frame-kinds` — frame-kind constants in `comm/socket.rs` are
//!   unique, and every kind is referenced (dispatched) outside its
//!   defining module — a dead or duplicated wire tag is a protocol bug.
//! * `bool-flags` — every `args.has("x")` literal appears in
//!   `BOOL_FLAGS`, every `BOOL_FLAGS` entry has a `.has` site, and no
//!   value-taking accessor reads a `BOOL_FLAGS` name (the PR 9
//!   `--json` bug class, both directions).
//! * `config-parity` — every `serve.*` / `comm.*` / `telemetry.*`
//!   config key has a CLI flag in `main.rs`, sits in a validating
//!   (`bail`-capable) function in `config.rs`, and is mentioned in a
//!   `config.rs` comment.
//! * `trace-vocab` — trace-event kind literals passed to
//!   `event` / `driver_event` / `serve_event` match the vocabulary
//!   documented in `comm/mod.rs`.
//! * `relaxed-rationale` — every function touching
//!   `Ordering::Relaxed` carries a `// RELAXED:` rationale.
//! * `quiescence` — `.ship(` appears only inside
//!   `transport.rs::flush_outbox`, and `note_queued` precedes the
//!   first ship (the quiescence-counting contract from `comm/mod.rs`).

use crate::lexer::{enclosing_fn, fn_spans, FnSpan, LineClass, SourceFile, Tok};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// One finding. Rendered as `file:line: rule: msg`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A lexed source tree (everything under `<root>/rust/src`).
pub struct Tree {
    pub files: Vec<SourceFile>,
}

impl Tree {
    pub fn load(root: &Path) -> std::io::Result<Tree> {
        let mut files = Vec::new();
        let mut stack = vec![root.join("rust").join("src")];
        while let Some(dir) = stack.pop() {
            let rd = match std::fs::read_dir(&dir) {
                Ok(r) => r,
                Err(_) => continue,
            };
            for entry in rd.flatten() {
                let p = entry.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|e| e == "rs") {
                    let text = std::fs::read_to_string(&p)?;
                    let rel = p
                        .strip_prefix(root)
                        .unwrap_or(&p)
                        .to_string_lossy()
                        .replace('\\', "/");
                    files.push(SourceFile::lex(&rel, &text));
                }
            }
        }
        files.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(Tree { files })
    }

    pub fn find(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path.ends_with(suffix))
    }
}

pub trait Rule {
    fn name(&self) -> &'static str;
    fn check(&self, tree: &Tree) -> Vec<Violation>;
}

pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(SafetyComment),
        Box::new(FrameKinds),
        Box::new(BoolFlags),
        Box::new(ConfigParity),
        Box::new(TraceVocab),
        Box::new(RelaxedRationale),
        Box::new(Quiescence),
    ]
}

// ---------------------------------------------------------------- helpers

/// Token-index spans `[mod_kw, close_brace]` of every inline
/// `mod <name> { … }` in `file`.
fn mod_spans(file: &SourceFile, name: &str) -> Vec<(usize, usize)> {
    let toks = &file.tokens;
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].kind.is_ident("mod")
            && toks[i + 1].kind.is_ident(name)
            && toks[i + 2].kind.is_punct('{')
        {
            let mut depth = 0i32;
            let mut k = i + 2;
            while k < toks.len() {
                match &toks[k].kind {
                    Tok::Punct('{') => depth += 1,
                    Tok::Punct('}') => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            out.push((i, k));
            i = k;
        }
        i += 1;
    }
    out
}

fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|(a, b)| *a <= idx && idx <= *b)
}

/// Unit-test module spans — rules that audit production invariants
/// (flag wiring, trace kinds, ship sites, Relaxed rationales) skip
/// `mod tests` bodies so test scaffolding doesn't need annotations.
/// `safety-comment` deliberately does NOT skip them: the clippy deny
/// it mirrors applies to test code too.
fn test_spans(file: &SourceFile) -> Vec<(usize, usize)> {
    mod_spans(file, "tests")
}

/// Is a `SAFETY:` / `RELAXED:`-style marker attached to `line`?
/// Accepted on the line itself or in the contiguous comment /
/// attribute block directly above (clippy's
/// `accept-comment-above-attributes` behaviour).
fn marker_at(file: &SourceFile, line: usize, marker: &str) -> bool {
    if file
        .comment_on(line)
        .is_some_and(|c| c.contains(marker))
    {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        match file.line_class(l) {
            LineClass::CommentOnly => {
                if file.comment_on(l).is_some_and(|c| c.contains(marker)) {
                    return true;
                }
            }
            LineClass::AttributeOnly | LineClass::Blank => {}
            LineClass::Code => return false,
        }
    }
    false
}

/// First string literal inside the call whose opening paren is at
/// token index `open` (which must be a `(`), scanning to the matching
/// close. Returns `(line, literal)`.
fn first_str_in_call(
    file: &SourceFile,
    open: usize,
) -> Option<(usize, String)> {
    let toks = &file.tokens;
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        match &toks[k].kind {
            Tok::Punct('(') => depth += 1,
            Tok::Punct(')') => {
                depth -= 1;
                if depth == 0 {
                    return None;
                }
            }
            Tok::Str(s) => return Some((toks[k].line, s.clone())),
            _ => {}
        }
        k += 1;
    }
    None
}

// ------------------------------------------------------------ rule: safety

pub struct SafetyComment;

impl Rule for SafetyComment {
    fn name(&self) -> &'static str {
        "safety-comment"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        for file in &tree.files {
            let toks = &file.tokens;
            for i in 0..toks.len() {
                if !toks[i].kind.is_ident("unsafe") {
                    continue;
                }
                let what = match toks.get(i + 1).map(|t| &t.kind) {
                    Some(Tok::Punct('{')) => "unsafe block",
                    Some(Tok::Ident(k)) if k == "impl" => "unsafe impl",
                    // `unsafe fn` signatures document their contract in
                    // the doc comment; clippy's lint skips them too.
                    _ => continue,
                };
                if !marker_at(file, toks[i].line, "SAFETY:") {
                    out.push(Violation {
                        file: file.path.clone(),
                        line: toks[i].line,
                        rule: self.name(),
                        msg: format!("{what} without a `// SAFETY:` justification"),
                    });
                }
            }
        }
        out
    }
}

// ------------------------------------------------------- rule: frame-kinds

pub struct FrameKinds;

impl Rule for FrameKinds {
    fn name(&self) -> &'static str {
        "frame-kinds"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        let Some(socket) = tree.find("comm/socket.rs") else {
            return out;
        };
        let spans = mod_spans(socket, "kind");
        let Some(&kind_span) = spans.first() else {
            return out;
        };

        // consts inside `mod kind { … }`: (name, value, line)
        let toks = &socket.tokens;
        let mut consts: Vec<(String, u64, usize)> = Vec::new();
        let mut i = kind_span.0;
        while i <= kind_span.1 {
            if toks[i].kind.is_ident("const") {
                if let Some(Tok::Ident(name)) =
                    toks.get(i + 1).map(|t| &t.kind)
                {
                    let mut j = i + 2;
                    while j <= kind_span.1
                        && !toks[j].kind.is_punct('=')
                        && !toks[j].kind.is_punct(';')
                    {
                        j += 1;
                    }
                    if let Some(Tok::Num(n)) =
                        toks.get(j + 1).map(|t| &t.kind)
                    {
                        if let Some(v) = parse_num(n) {
                            consts.push((name.clone(), v, toks[i].line));
                        }
                    }
                }
            }
            i += 1;
        }

        // uniqueness
        let mut by_value: BTreeMap<u64, Vec<&(String, u64, usize)>> =
            BTreeMap::new();
        for c in &consts {
            by_value.entry(c.1).or_default().push(c);
        }
        for (v, dup) in by_value.iter().filter(|(_, d)| d.len() > 1) {
            let names: Vec<&str> =
                dup.iter().map(|c| c.0.as_str()).collect();
            out.push(Violation {
                file: socket.path.clone(),
                line: dup[1].2,
                rule: self.name(),
                msg: format!(
                    "frame-kind value {v} assigned to multiple constants: {}",
                    names.join(", ")
                ),
            });
        }

        // every kind referenced as `kind::NAME` outside the defining mod
        let mut referenced: BTreeSet<String> = BTreeSet::new();
        for file in &tree.files {
            let t = &file.tokens;
            for i in 0..t.len().saturating_sub(3) {
                if t[i].kind.is_ident("kind")
                    && t[i + 1].kind.is_punct(':')
                    && t[i + 2].kind.is_punct(':')
                {
                    if file.path == socket.path
                        && in_spans(&[kind_span], i)
                    {
                        continue;
                    }
                    if let Tok::Ident(name) = &t[i + 3].kind {
                        referenced.insert(name.clone());
                    }
                }
            }
        }
        for (name, _, line) in &consts {
            if !referenced.contains(name) {
                out.push(Violation {
                    file: socket.path.clone(),
                    line: *line,
                    rule: self.name(),
                    msg: format!(
                        "frame kind `{name}` is never referenced outside \
                         `mod kind` — dead wire tag or missing dispatch arm"
                    ),
                });
            }
        }
        out
    }
}

fn parse_num(n: &str) -> Option<u64> {
    let s: String = n.chars().filter(|c| *c != '_').collect();
    let s = s
        .trim_end_matches(|c: char| c.is_ascii_alphabetic())
        .to_string();
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

// -------------------------------------------------------- rule: bool-flags

/// Accessors on `Args` that take a value: a flag read through these
/// must NOT be in `BOOL_FLAGS` (and vice versa for `.has`).
const GET_FAMILY: &[&str] = &[
    "get", "get_or", "get_u64", "get_usize", "get_u64_opt", "get_u8",
    "require",
];

pub struct BoolFlags;

impl Rule for BoolFlags {
    fn name(&self) -> &'static str {
        "bool-flags"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        let Some(cli) = tree.find("src/cli.rs") else {
            return out;
        };

        // BOOL_FLAGS entries: string literals between `BOOL_FLAGS … =`
        // and the terminating `;`.
        let mut flags: BTreeMap<String, usize> = BTreeMap::new();
        let toks = &cli.tokens;
        if let Some(start) =
            toks.iter().position(|t| t.kind.is_ident("BOOL_FLAGS"))
        {
            for t in &toks[start..] {
                match &t.kind {
                    Tok::Str(s) => {
                        flags.entry(s.clone()).or_insert(t.line);
                    }
                    Tok::Punct(';') => break,
                    _ => {}
                }
            }
        }
        if flags.is_empty() {
            out.push(Violation {
                file: cli.path.clone(),
                line: 1,
                rule: self.name(),
                msg: "could not locate a populated BOOL_FLAGS table".into(),
            });
            return out;
        }

        let mut has_sites: BTreeMap<String, (String, usize)> =
            BTreeMap::new();
        for file in &tree.files {
            let skip = test_spans(file);
            let t = &file.tokens;
            for i in 0..t.len().saturating_sub(2) {
                if in_spans(&skip, i) || !t[i].kind.is_punct('.') {
                    continue;
                }
                let Tok::Ident(m) = &t[i + 1].kind else { continue };
                if !t[i + 2].kind.is_punct('(') {
                    continue;
                }
                let Some((line, lit)) = first_str_in_call(file, i + 2)
                else {
                    continue;
                };
                if m == "has" {
                    has_sites
                        .entry(lit.clone())
                        .or_insert((file.path.clone(), line));
                    if !flags.contains_key(&lit) {
                        out.push(Violation {
                            file: file.path.clone(),
                            line,
                            rule: self.name(),
                            msg: format!(
                                "`--{lit}` is read with `.has` but missing \
                                 from BOOL_FLAGS (the PR 9 `--json` bug class)"
                            ),
                        });
                    }
                } else if GET_FAMILY.contains(&m.as_str())
                    && flags.contains_key(&lit)
                {
                    out.push(Violation {
                        file: file.path.clone(),
                        line,
                        rule: self.name(),
                        msg: format!(
                            "`--{lit}` is in BOOL_FLAGS but read through \
                             value accessor `.{m}` — flags cannot be both"
                        ),
                    });
                }
            }
        }
        for (flag, line) in &flags {
            if !has_sites.contains_key(flag) {
                out.push(Violation {
                    file: cli.path.clone(),
                    line: *line,
                    rule: self.name(),
                    msg: format!(
                        "BOOL_FLAGS entry `{flag}` has no `.has(\"{flag}\")` \
                         site — dead flag"
                    ),
                });
            }
        }
        out
    }
}

// ----------------------------------------------------- rule: config-parity

/// Keys whose CLI flag is not the mechanical `last segment, _ → -`
/// derivation.
const FLAG_OVERRIDES: &[(&str, &str)] = &[
    ("comm.checkpoint_interval", "checkpoint"),
    ("comm.adaptive_flush", "fixed-flush"),
];

pub struct ConfigParity;

impl ConfigParity {
    fn flag_for(key: &str) -> String {
        for (k, f) in FLAG_OVERRIDES {
            if *k == key {
                return (*f).to_string();
            }
        }
        key.rsplit('.').next().unwrap_or(key).replace('_', "-")
    }

    fn is_key(s: &str) -> bool {
        let Some(rest) = ["serve.", "comm.", "telemetry."]
            .iter()
            .find_map(|p| s.strip_prefix(p))
        else {
            return false;
        };
        !rest.is_empty()
            && rest
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
    }
}

impl Rule for ConfigParity {
    fn name(&self) -> &'static str {
        "config-parity"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        let (Some(config), Some(main)) =
            (tree.find("src/config.rs"), tree.find("src/main.rs"))
        else {
            return out;
        };

        // keys: every dotted serve/comm/telemetry literal in config.rs
        // outside `mod tests`, with every token index it occurs at
        let skip = test_spans(config);
        let mut keys: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, t) in config.tokens.iter().enumerate() {
            if in_spans(&skip, i) {
                continue;
            }
            if let Tok::Str(s) = &t.kind {
                if Self::is_key(s) {
                    keys.entry(s.clone()).or_default().push(i);
                }
            }
        }

        let main_strs: BTreeSet<&str> = main
            .tokens
            .iter()
            .filter_map(|t| t.kind.as_str_lit())
            .collect();
        let spans = fn_spans(config);
        let bail_fns: Vec<&FnSpan> = spans
            .iter()
            .filter(|s| {
                config.tokens[s.sig_tok..=s.end_tok.min(config.tokens.len() - 1)]
                    .iter()
                    .any(|t| t.kind.is_ident("bail"))
            })
            .collect();
        let all_comments: String = config
            .comments
            .iter()
            .map(|(_, c)| c.as_str())
            .collect::<Vec<_>>()
            .join("\n");

        for (key, idxs) in &keys {
            let line = config.tokens[idxs[0]].line;
            let flag = Self::flag_for(key);
            if !main_strs.contains(flag.as_str()) {
                out.push(Violation {
                    file: config.path.clone(),
                    line,
                    rule: self.name(),
                    msg: format!(
                        "config key `{key}` has no matching `--{flag}` \
                         CLI flag in main.rs"
                    ),
                });
            }
            let validated = idxs.iter().any(|i| {
                bail_fns.iter().any(|s| s.sig_tok <= *i && *i <= s.end_tok)
            });
            if !validated {
                out.push(Violation {
                    file: config.path.clone(),
                    line,
                    rule: self.name(),
                    msg: format!(
                        "config key `{key}` never appears in a validating \
                         (`bail`-capable) function in config.rs"
                    ),
                });
            }
            if !all_comments.contains(key.as_str()) {
                out.push(Violation {
                    file: config.path.clone(),
                    line,
                    rule: self.name(),
                    msg: format!(
                        "config key `{key}` is not mentioned in any \
                         config.rs comment — undocumented knob"
                    ),
                });
            }
        }
        out
    }
}

// ------------------------------------------------------- rule: trace-vocab

/// Functions whose first string argument is a trace-event kind.
const EMITTERS: &[&str] = &["event", "driver_event", "serve_event"];

/// Kinds documented without a dot, so backtick extraction (which keys
/// on dotted names) cannot find them mechanically.
const BARE_KINDS: &[&str] = &["pause", "quiesce"];

pub struct TraceVocab;

impl Rule for TraceVocab {
    fn name(&self) -> &'static str {
        "trace-vocab"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        let Some(comm_mod) = tree.find("comm/mod.rs") else {
            return out;
        };

        // vocabulary: backticked dotted names in comm/mod.rs comments;
        // `chaos.<kind>` documents a wildcard family
        let mut vocab: BTreeSet<String> = BTreeSet::new();
        let mut prefixes: Vec<String> = Vec::new();
        for (_, text) in &comm_mod.comments {
            for (i, part) in text.split('`').enumerate() {
                if i % 2 == 0 {
                    continue;
                }
                if let Some(pos) = part.find(".<") {
                    let prefix = &part[..pos + 1];
                    if prefix
                        .chars()
                        .all(|c| c.is_ascii_lowercase() || c == '_' || c == '.')
                    {
                        prefixes.push(prefix.to_string());
                    }
                } else if part.contains('.')
                    && !part.starts_with('.')
                    && !part.ends_with('.')
                    && part.chars().all(|c| {
                        c.is_ascii_lowercase() || c == '_' || c == '.'
                    })
                {
                    vocab.insert(part.to_string());
                }
            }
        }
        if vocab.is_empty() {
            return out;
        }

        for file in &tree.files {
            let skip = test_spans(file);
            let t = &file.tokens;
            for i in 0..t.len().saturating_sub(1) {
                if in_spans(&skip, i) {
                    continue;
                }
                let Tok::Ident(name) = &t[i].kind else { continue };
                if !EMITTERS.contains(&name.as_str())
                    || !t[i + 1].kind.is_punct('(')
                {
                    continue;
                }
                // skip definitions and method calls on other receivers
                if i > 0
                    && (t[i - 1].kind.is_ident("fn")
                        || t[i - 1].kind.is_punct('.'))
                {
                    continue;
                }
                let Some((line, kind)) = first_str_in_call(file, i + 1)
                else {
                    continue;
                };
                let ok = vocab.contains(&kind)
                    || BARE_KINDS.contains(&kind.as_str())
                    || prefixes.iter().any(|p| kind.starts_with(p.as_str()));
                if !ok {
                    out.push(Violation {
                        file: file.path.clone(),
                        line,
                        rule: self.name(),
                        msg: format!(
                            "trace event kind `{kind}` is not in the \
                             vocabulary documented in comm/mod.rs"
                        ),
                    });
                }
            }
        }
        out
    }
}

// ------------------------------------------------- rule: relaxed-rationale

pub struct RelaxedRationale;

impl Rule for RelaxedRationale {
    fn name(&self) -> &'static str {
        "relaxed-rationale"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        for file in &tree.files {
            let skip = test_spans(file);
            let spans = fn_spans(file);
            let mut flagged: BTreeSet<usize> = BTreeSet::new();
            for (i, t) in file.tokens.iter().enumerate() {
                if !t.kind.is_ident("Relaxed") || in_spans(&skip, i) {
                    continue;
                }
                // `use …::Ordering::Relaxed;` and other non-fn sites
                // carry no memory-ordering decision of their own
                let Some(f) = enclosing_fn(&spans, i) else {
                    continue;
                };
                if flagged.contains(&f.sig_tok) {
                    continue;
                }
                // accepted anywhere from the comment block above the
                // signature to the end of the body
                let mut start = f.sig_line;
                while start > 1
                    && matches!(
                        file.line_class(start - 1),
                        LineClass::CommentOnly | LineClass::AttributeOnly
                    )
                {
                    start -= 1;
                }
                let has = file.comments.iter().any(|(l, c)| {
                    *l >= start && *l <= f.end_line && c.contains("RELAXED:")
                });
                if !has {
                    flagged.insert(f.sig_tok);
                    out.push(Violation {
                        file: file.path.clone(),
                        line: f.sig_line,
                        rule: self.name(),
                        msg: format!(
                            "fn `{}` uses Ordering::Relaxed without a \
                             `// RELAXED:` rationale",
                            f.name
                        ),
                    });
                }
            }
        }
        out
    }
}

// -------------------------------------------------------- rule: quiescence

pub struct Quiescence;

impl Rule for Quiescence {
    fn name(&self) -> &'static str {
        "quiescence"
    }

    fn check(&self, tree: &Tree) -> Vec<Violation> {
        let mut out = Vec::new();
        let mut transport_ships: Vec<usize> = Vec::new(); // lines
        for file in &tree.files {
            let is_transport = file.path.ends_with("comm/transport.rs");
            let skip = test_spans(file);
            let spans = fn_spans(file);
            let flush = spans.iter().find(|s| s.name == "flush_outbox");
            let t = &file.tokens;
            for i in 0..t.len().saturating_sub(2) {
                if in_spans(&skip, i) {
                    continue;
                }
                if !(t[i].kind.is_punct('.')
                    && t[i + 1].kind.is_ident("ship")
                    && t[i + 2].kind.is_punct('('))
                {
                    continue;
                }
                let line = t[i].line;
                let inside_flush = flush
                    .is_some_and(|s| s.sig_tok <= i && i <= s.end_tok);
                if is_transport && inside_flush {
                    transport_ships.push(line);
                } else {
                    out.push(Violation {
                        file: file.path.clone(),
                        line,
                        rule: self.name(),
                        msg: "`.ship(` outside transport.rs::flush_outbox \
                              bypasses quiescence accounting"
                            .into(),
                    });
                }
            }
            if is_transport {
                if let (Some(s), Some(&first_ship)) =
                    (flush, transport_ships.first())
                {
                    let queued_line = (s.sig_tok..=s.end_tok)
                        .filter(|&j| {
                            t[j].kind.is_ident("note_queued")
                                && t.get(j + 1)
                                    .is_some_and(|n| n.kind.is_punct('('))
                        })
                        .map(|j| t[j].line)
                        .min();
                    match queued_line {
                        Some(q) if q < first_ship => {}
                        Some(q) => out.push(Violation {
                            file: file.path.clone(),
                            line: q,
                            rule: self.name(),
                            msg: format!(
                                "note_queued (line {q}) must precede the \
                                 first ship (line {first_ship}) in \
                                 flush_outbox"
                            ),
                        }),
                        None => out.push(Violation {
                            file: file.path.clone(),
                            line: first_ship,
                            rule: self.name(),
                            msg: "flush_outbox ships frames without \
                                  calling note_queued first"
                                .into(),
                        }),
                    }
                }
            }
        }
        out
    }
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> Tree {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("fixtures")
            .join(name);
        Tree::load(&root).expect("fixture tree loads")
    }

    fn msgs(v: &[Violation]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn safety_comment_fires_on_seeded_violations() {
        let v = SafetyComment.check(&fixture("safety"));
        let m = msgs(&v);
        assert_eq!(v.len(), 2, "{m:?}");
        assert!(m.iter().any(|s| s.contains("unsafe block")), "{m:?}");
        assert!(m.iter().any(|s| s.contains("unsafe impl")), "{m:?}");
        // the annotated block and annotated impl must NOT fire
        assert!(v.iter().all(|x| x.line != 6 && x.line != 16), "{m:?}");
    }

    #[test]
    fn frame_kinds_fires_on_duplicate_and_dead_tags() {
        let v = FrameKinds.check(&fixture("frame_kinds"));
        let m = msgs(&v);
        assert_eq!(v.len(), 2, "{m:?}");
        assert!(
            m.iter().any(|s| s.contains("assigned to multiple")),
            "{m:?}"
        );
        assert!(m.iter().any(|s| s.contains("`GHOST`")), "{m:?}");
    }

    #[test]
    fn bool_flags_reproduces_the_pr9_json_bug() {
        let v = BoolFlags.check(&fixture("bool_flags"));
        let m = msgs(&v);
        assert_eq!(v.len(), 3, "{m:?}");
        // the PR 9 class: read with .has, missing from BOOL_FLAGS
        assert!(
            m.iter().any(|s| s.contains("--json") && s.contains("missing")),
            "{m:?}"
        );
        // dead entry with no .has site
        assert!(
            m.iter().any(|s| s.contains("`metrics`") && s.contains("dead")),
            "{m:?}"
        );
        // value accessor reading a BOOL_FLAGS name
        assert!(
            m.iter().any(|s| s.contains("--config") && s.contains(".get")),
            "{m:?}"
        );
    }

    #[test]
    fn config_parity_fires_on_unwired_key() {
        let v = ConfigParity.check(&fixture("config_parity"));
        let m = msgs(&v);
        // serve.widgets: no flag, no validation arm, no doc mention
        assert_eq!(v.len(), 3, "{m:?}");
        assert!(m.iter().all(|s| s.contains("serve.widgets")), "{m:?}");
        assert!(m.iter().any(|s| s.contains("--widgets")), "{m:?}");
        assert!(m.iter().any(|s| s.contains("validating")), "{m:?}");
        assert!(m.iter().any(|s| s.contains("undocumented knob")), "{m:?}");
    }

    #[test]
    fn trace_vocab_fires_on_undocumented_kind() {
        let v = TraceVocab.check(&fixture("trace_vocab"));
        let m = msgs(&v);
        assert_eq!(v.len(), 1, "{m:?}");
        assert!(m[0].contains("`bogus.kind`"), "{m:?}");
    }

    #[test]
    fn relaxed_rationale_fires_per_function() {
        let v = RelaxedRationale.check(&fixture("relaxed"));
        let m = msgs(&v);
        assert_eq!(v.len(), 1, "{m:?}");
        assert!(m[0].contains("`bump`"), "{m:?}");
    }

    #[test]
    fn quiescence_fires_on_rogue_ship_and_bad_ordering() {
        let v = Quiescence.check(&fixture("quiescence"));
        let m = msgs(&v);
        assert_eq!(v.len(), 2, "{m:?}");
        assert!(
            m.iter().any(|s| s.contains("outside transport.rs")),
            "{m:?}"
        );
        assert!(m.iter().any(|s| s.contains("must precede")), "{m:?}");
    }

    #[test]
    fn flag_derivation_handles_overrides() {
        assert_eq!(ConfigParity::flag_for("serve.batch_max"), "batch-max");
        assert_eq!(
            ConfigParity::flag_for("comm.checkpoint_interval"),
            "checkpoint"
        );
        assert_eq!(
            ConfigParity::flag_for("comm.adaptive_flush"),
            "fixed-flush"
        );
    }
}
