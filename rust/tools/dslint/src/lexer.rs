//! A comment/string-aware Rust lexer — just enough structure for the
//! cross-file invariant rules in [`crate::rules`].
//!
//! Hand-rolled (crates.io is unreachable in the build environment, so
//! syn/proc-macro2 are off the table — same precedent as the main
//! crate's crc32, JSON parser, and poll(2)/mmap bindings). It does NOT
//! parse Rust; it tokenizes it: identifiers, numbers, string literals,
//! and single-character punctuation, with comments and literal bodies
//! kept out of the token stream so a rule can never be fooled by
//! `"unsafe"` inside a string or `// .ship(` inside a comment.
//!
//! Handled literal forms: `//` line comments, nested `/* */` block
//! comments, `"…"` strings with escapes, raw strings `r"…"` /
//! `r#"…"#` (any hash depth, plus `b` prefixes), byte strings, char
//! literals (including escapes), and lifetimes (`'a` is NOT a char
//! literal).

/// One lexical token, tagged with its 1-based source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub line: usize,
    pub kind: Tok,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`unsafe`, `fn`, `kind`, …).
    Ident(String),
    /// Numeric literal, raw text (`0x1F`, `25`, `1_000u64`).
    Num(String),
    /// String literal *content* (delimiters and prefixes stripped,
    /// escapes left as written).
    Str(String),
    /// Any other non-whitespace character.
    Punct(char),
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(self, Tok::Ident(i) if i == s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, Tok::Punct(p) if *p == c)
    }

    pub fn as_str_lit(&self) -> Option<&str> {
        match self {
            Tok::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// One lexed source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the scan root, with `/` separators.
    pub path: String,
    pub tokens: Vec<Token>,
    /// Comment text per physical line it appears on (block comments
    /// contribute one entry per line they span).
    pub comments: Vec<(usize, String)>,
    /// The source with comments and literal bodies blanked to spaces —
    /// line-classification support for the walk-up rules.
    pub masked: Vec<String>,
}

impl SourceFile {
    pub fn lex(path: &str, text: &str) -> SourceFile {
        Lexer::new(text).run(path)
    }

    /// All comment texts on `line`, concatenated.
    pub fn comment_on(&self, line: usize) -> Option<String> {
        let mut out = String::new();
        for (l, t) in &self.comments {
            if *l == line {
                out.push_str(t);
                out.push(' ');
            }
        }
        if out.is_empty() {
            None
        } else {
            Some(out)
        }
    }

    /// Classify `line` (1-based) for the comment walk-up rules.
    pub fn line_class(&self, line: usize) -> LineClass {
        let code = self
            .masked
            .get(line - 1)
            .map(|s| s.trim().to_string())
            .unwrap_or_default();
        let has_comment = self.comment_on(line).is_some();
        if code.is_empty() {
            if has_comment {
                LineClass::CommentOnly
            } else {
                LineClass::Blank
            }
        } else if (code.starts_with("#[") || code.starts_with("#!["))
            && code.ends_with(']')
        {
            LineClass::AttributeOnly
        } else {
            LineClass::Code
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LineClass {
    Blank,
    CommentOnly,
    AttributeOnly,
    Code,
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    tokens: Vec<Token>,
    comments: Vec<(usize, String)>,
    masked: Vec<String>,
    cur_masked: String,
}

impl<'a> Lexer<'a> {
    fn new(text: &'a str) -> Self {
        Lexer {
            src: text.as_bytes(),
            pos: 0,
            line: 1,
            tokens: Vec::new(),
            comments: Vec::new(),
            masked: Vec::new(),
            cur_masked: String::new(),
        }
    }

    fn peek(&self, ahead: usize) -> u8 {
        *self.src.get(self.pos + ahead).unwrap_or(&0)
    }

    /// Consume one byte, maintaining line count and the masked view.
    /// `mask`: emit a space into the masked line instead of the byte.
    fn bump(&mut self, mask: bool) -> u8 {
        let b = self.src[self.pos];
        self.pos += 1;
        if b == b'\n' {
            let done = std::mem::take(&mut self.cur_masked);
            self.masked.push(done);
            self.line += 1;
        } else if mask {
            self.cur_masked.push(' ');
        } else {
            self.cur_masked.push(b as char);
        }
        b
    }

    fn run(mut self, path: &str) -> SourceFile {
        while self.pos < self.src.len() {
            let b = self.peek(0);
            match b {
                b'/' if self.peek(1) == b'/' => self.line_comment(),
                b'/' if self.peek(1) == b'*' => self.block_comment(),
                b'"' => self.string(0),
                b'r' | b'b' if self.raw_or_byte_prefix() => {}
                b'\'' => self.char_or_lifetime(),
                c if c.is_ascii_alphabetic() || c == b'_' => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                c if c.is_ascii_whitespace() => {
                    self.bump(false);
                }
                c => {
                    let line = self.line;
                    self.bump(false);
                    self.tokens.push(Token {
                        line,
                        kind: Tok::Punct(c as char),
                    });
                }
            }
        }
        if !self.cur_masked.is_empty() {
            let done = std::mem::take(&mut self.cur_masked);
            self.masked.push(done);
        }
        SourceFile {
            path: path.to_string(),
            tokens: self.tokens,
            comments: self.comments,
            masked: self.masked,
        }
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while self.pos < self.src.len() && self.peek(0) != b'\n' {
            text.push(self.bump(true) as char);
        }
        self.comments.push((line, text));
    }

    fn block_comment(&mut self) {
        self.bump(true); // '/'
        self.bump(true); // '*'
        let mut depth = 1usize;
        let mut text = String::new();
        let mut line = self.line;
        while self.pos < self.src.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.bump(true);
                self.bump(true);
                text.push_str("/*");
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.bump(true);
                self.bump(true);
            } else {
                let b = self.bump(true);
                if b == b'\n' {
                    self.comments.push((line, std::mem::take(&mut text)));
                    line = self.line;
                } else {
                    text.push(b as char);
                }
            }
        }
        self.comments.push((line, text));
    }

    /// Handle `r"…"`, `r#"…"#`, `br"…"`, `b"…"`, `b'…'` prefixes.
    /// Returns true when it consumed a literal; false means the `r`/`b`
    /// is a plain identifier start and the caller should fall through.
    fn raw_or_byte_prefix(&mut self) -> bool {
        let mut ahead = 1; // past the r/b
        if self.peek(0) == b'b' && self.peek(1) == b'r' {
            ahead = 2;
        }
        let mut hashes = 0usize;
        while self.peek(ahead + hashes) == b'#' {
            hashes += 1;
        }
        let next = self.peek(ahead + hashes);
        let is_raw = self.peek(0) == b'r' || ahead == 2;
        if is_raw && next == b'"' {
            for _ in 0..(ahead + hashes) {
                self.bump(false);
            }
            self.string(hashes);
            return true;
        }
        if self.peek(0) == b'b' && hashes == 0 && ahead == 1 {
            if next == b'"' {
                self.bump(false);
                self.string(0);
                return true;
            }
            if next == b'\'' {
                self.bump(false);
                self.char_literal();
                return true;
            }
        }
        false
    }

    /// Consume a string literal whose opening `"` is at `self.pos`;
    /// `hashes` > 0 means a raw string closed by `"` + that many `#`.
    fn string(&mut self, hashes: usize) {
        let line = self.line;
        self.bump(false); // opening quote
        let mut content = String::new();
        while self.pos < self.src.len() {
            if self.peek(0) == b'"' {
                let mut ok = true;
                for h in 0..hashes {
                    if self.peek(1 + h) != b'#' {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    self.bump(false);
                    for _ in 0..hashes {
                        self.bump(false);
                    }
                    break;
                }
            }
            if hashes == 0 && self.peek(0) == b'\\' {
                content.push(self.bump(true) as char);
                if self.pos < self.src.len() {
                    content.push(self.bump(true) as char);
                }
                continue;
            }
            content.push(self.bump(true) as char);
        }
        self.tokens.push(Token {
            line,
            kind: Tok::Str(content),
        });
    }

    /// At a `'`: char literal or lifetime? A lifetime is `'ident` not
    /// followed by a closing quote; everything else quote-delimited is
    /// a char literal.
    fn char_or_lifetime(&mut self) {
        let c1 = self.peek(1);
        if c1 == b'\\' || (self.peek(2) == b'\'' && c1 != b'\'') {
            self.char_literal();
        } else {
            // lifetime: drop the quote, let the ident lex normally
            self.bump(false);
        }
    }

    fn char_literal(&mut self) {
        self.bump(false); // opening '
        while self.pos < self.src.len() {
            match self.peek(0) {
                b'\\' => {
                    self.bump(true);
                    if self.pos < self.src.len() {
                        self.bump(true);
                    }
                }
                b'\'' => {
                    self.bump(false);
                    break;
                }
                _ => {
                    self.bump(true);
                }
            }
        }
    }

    fn ident(&mut self) {
        let line = self.line;
        let mut s = String::new();
        while self.pos < self.src.len()
            && (self.peek(0).is_ascii_alphanumeric() || self.peek(0) == b'_')
        {
            s.push(self.bump(false) as char);
        }
        self.tokens.push(Token {
            line,
            kind: Tok::Ident(s),
        });
    }

    fn number(&mut self) {
        let line = self.line;
        let mut s = String::new();
        while self.pos < self.src.len()
            && (self.peek(0).is_ascii_alphanumeric() || self.peek(0) == b'_')
        {
            s.push(self.bump(false) as char);
        }
        self.tokens.push(Token {
            line,
            kind: Tok::Num(s),
        });
    }
}

/// A `fn` item's extent in a token stream: `[sig_tok, end_tok]` token
/// indices and `[sig_line, end_line]` source lines.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub sig_line: usize,
    pub end_line: usize,
    pub sig_tok: usize,
    pub end_tok: usize,
}

/// Every `fn` item with a body. Brace-matched on the token stream
/// (paren/bracket-aware, so `fn f(x: [u8; 4]) -> R {…}` resolves the
/// right opening brace); bodyless trait methods (`;` before `{`) are
/// skipped.
pub fn fn_spans(file: &SourceFile) -> Vec<FnSpan> {
    let toks = &file.tokens;
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind.is_ident("fn") {
            let name = match toks.get(i + 1).map(|t| &t.kind) {
                Some(Tok::Ident(n)) => n.clone(),
                _ => {
                    i += 1;
                    continue;
                }
            };
            let sig_line = toks[i].line;
            let sig_tok = i;
            // find the body '{' at paren/bracket depth 0; a ';' first
            // means no body
            let mut j = i + 2;
            let mut depth = 0i32;
            let mut body = None;
            while j < toks.len() {
                match &toks[j].kind {
                    Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                    Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                    Tok::Punct(';') if depth == 0 => break,
                    Tok::Punct('{') if depth == 0 => {
                        body = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            if let Some(open) = body {
                let mut braces = 0i32;
                let mut k = open;
                while k < toks.len() {
                    match &toks[k].kind {
                        Tok::Punct('{') => braces += 1,
                        Tok::Punct('}') => {
                            braces -= 1;
                            if braces == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                spans.push(FnSpan {
                    name,
                    sig_line,
                    end_line: toks.get(k).map_or(sig_line, |t| t.line),
                    sig_tok,
                    end_tok: k.min(toks.len().saturating_sub(1)),
                });
            }
        }
        i += 1;
    }
    spans
}

/// The innermost span containing token index `idx`.
pub fn enclosing_fn(spans: &[FnSpan], idx: usize) -> Option<&FnSpan> {
    spans
        .iter()
        .filter(|s| s.sig_tok <= idx && idx <= s.end_tok)
        .min_by_key(|s| s.end_tok - s.sig_tok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_leave_the_token_stream() {
        let f = SourceFile::lex(
            "t.rs",
            "let x = \"unsafe {\"; // unsafe {\n/* .ship( */ call();\n",
        );
        assert!(!f.tokens.iter().any(|t| t.kind.is_ident("unsafe")));
        assert!(!f.tokens.iter().any(|t| t.kind.is_ident("ship")));
        assert_eq!(f.comments.len(), 2);
        assert_eq!(
            f.tokens.iter().filter(|t| t.kind.is_ident("call")).count(),
            1
        );
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let f = SourceFile::lex(
            "t.rs",
            "let s = r#\"has \"quotes\" and unsafe\"#;\nfn f<'a>(x: &'a str) {}\nlet c = '\\'';\nlet d = 'x';\n",
        );
        assert!(!f.tokens.iter().any(|t| t.kind.is_ident("unsafe")));
        assert!(!f.tokens.iter().any(|t| t.kind.is_ident("quotes")));
        // lifetime ident survives as a token (quote stripped)
        assert!(f.tokens.iter().any(|t| t.kind.is_ident("a")));
        assert!(f.tokens.iter().any(|t| t.kind.is_ident("str")));
    }

    #[test]
    fn fn_spans_cover_bodies_and_skip_trait_decls() {
        let src = "trait T { fn nope(&self); }\n\
                   fn outer(x: [u8; 3]) -> u32 {\n\
                       fn inner() -> u32 { 7 }\n\
                       inner()\n\
                   }\n";
        let f = SourceFile::lex("t.rs", src);
        let spans = fn_spans(&f);
        let names: Vec<&str> =
            spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner"]);
        assert_eq!(spans[0].sig_line, 2);
        assert_eq!(spans[0].end_line, 5);
        // innermost resolution
        let inner_tok = f
            .tokens
            .iter()
            .position(|t| matches!(&t.kind, Tok::Num(n) if n == "7"))
            .unwrap();
        assert_eq!(enclosing_fn(&spans, inner_tok).unwrap().name, "inner");
    }

    #[test]
    fn line_classes() {
        let src = "// just a comment\n#[cfg(test)]\nlet x = 1; // trailing\n\n";
        let f = SourceFile::lex("t.rs", src);
        assert_eq!(f.line_class(1), LineClass::CommentOnly);
        assert_eq!(f.line_class(2), LineClass::AttributeOnly);
        assert_eq!(f.line_class(3), LineClass::Code);
        assert_eq!(f.line_class(4), LineClass::Blank);
    }
}
