//! trace-vocab fixture: two documented emissions and one
//! out-of-vocabulary kind (`bogus.kind`).

pub fn go() {
    telemetry::event("epoch.start", &[]);
    telemetry::event("chaos.drop", &[]);
    telemetry::event("bogus.kind", &[]);
}
