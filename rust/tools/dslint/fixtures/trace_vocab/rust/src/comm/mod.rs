//! # Observability
//!
//! Documented trace-event kinds: `epoch.start` marks the beginning of
//! an epoch, and `chaos.<kind>` covers every injected-fault family.
