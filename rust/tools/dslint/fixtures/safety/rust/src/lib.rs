//! safety-comment fixture: two annotated sites (must not fire, lines 6
//! and 16) and two unannotated sites (must fire, lines 10 and 18).

pub fn ok_block(p: *const u8) -> u8 {
    // SAFETY: caller guarantees `p` points to a live byte.
    unsafe { *p }
}

pub fn bad_block(p: *const u8) -> u8 {
    unsafe { *p }
}

pub struct Handle(*const u8);

// SAFETY: Handle is an opaque token; the pointer is never dereferenced.
unsafe impl Send for Handle {}

unsafe impl Sync for Handle {}

// decoy: literal text must not be lexed as code
pub const DOC: &str = "unsafe { not real }";
