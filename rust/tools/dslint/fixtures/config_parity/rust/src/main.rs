//! config-parity fixture: only `--workers` exists as a flag.

pub fn apply(args: &Args) {
    let _ = args.get_u64_opt("workers");
}
