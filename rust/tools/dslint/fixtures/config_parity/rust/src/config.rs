//! config-parity fixture. The `serve.workers` key is fully wired:
//! documented here, range-checked below, and matched by `--workers`
//! in main.rs. The widgets knob below has none of the three (its key
//! is deliberately NOT spelled out in any comment).

pub fn serve_options(c: &Config) -> Result<i64> {
    let w = c.get_i64("serve.workers");
    if w > 4096 {
        bail!("serve.workers out of range");
    }
    Ok(w)
}

pub fn widgets(c: &Config) -> i64 {
    c.get_i64("serve.widgets")
}
