//! bool-flags fixture: `--json` is read with `.has` but was never
//! added to BOOL_FLAGS — a reproduction of the PR 9 bug.

pub fn run(args: &crate::cli::Args) {
    let _exact = args.has("exact");
    let _json = args.has("json");
    let _cfg_flag = args.has("config");
    let _cfg_value = args.get("config");
}
