//! bool-flags fixture: `metrics` is a dead entry (no `.has` site) and
//! `config` is listed here despite being a value-taking flag.

pub const BOOL_FLAGS: &[&str] = &["exact", "metrics", "config"];

pub struct Args;

impl Args {
    pub fn has(&self, _name: &str) -> bool {
        false
    }
    pub fn get(&self, _name: &str) -> Option<String> {
        None
    }
}
