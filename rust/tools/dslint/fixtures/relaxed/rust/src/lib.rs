//! relaxed-rationale fixture: `good` carries a RELAXED rationale,
//! `bump` does not.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Counter(AtomicU64);

impl Counter {
    // RELAXED: monotonic counter; readers tolerate staleness.
    pub fn good(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
}
