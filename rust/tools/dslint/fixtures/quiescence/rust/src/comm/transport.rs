//! quiescence fixture: flush_outbox ships BEFORE noting the queued
//! count — the ordering the real transport must never exhibit.

pub fn flush_outbox(t: &mut Outbox) {
    for f in t.frames.drain(..) {
        t.link.ship(f);
    }
    t.quiesce.note_queued(1);
}
