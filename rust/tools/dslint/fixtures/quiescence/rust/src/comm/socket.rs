//! quiescence fixture: a rogue ship outside transport.rs::flush_outbox.

pub fn send_direct(link: &mut Link, f: Frame) {
    link.ship(f);
}
