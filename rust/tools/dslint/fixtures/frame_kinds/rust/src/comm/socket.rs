//! frame-kinds fixture: REPORT duplicates PROBE's value, and GHOST has
//! no dispatch arm anywhere.

pub mod kind {
    pub const MSGS: u8 = 0;
    pub const PROBE: u8 = 1;
    pub const REPORT: u8 = 1;
    pub const GHOST: u8 = 3;
}

pub fn dispatch(k: u8) {
    match k {
        kind::MSGS => {}
        kind::PROBE => {}
        kind::REPORT => {}
        _ => {}
    }
}
