//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `degreesketch <subcommand> [--flag value]... [--bool-flag]...`
//! plus `--config file` / `--set section.key=value` feeding [`crate::config`].

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: subcommand + flag map + positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

/// Flags that take no value. Kept in lockstep with the `.has(...)`
/// call sites by dslint's `bool-flags` rule: every entry here must
/// have a `.has` reader, every `.has` literal must be listed here, and
/// no entry may double as a value-taking flag. (PR 9 shipped `--json`
/// reading as a value flag because it was missing from this table;
/// `metrics`/`write`/`quiet` were dead entries removed by the same
/// audit.)
const BOOL_FLAGS: &[&str] = &[
    "exact", "help", "discard-dominated", "verify", "self-check",
    "fixed-flush", "live-reload", "json",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if BOOL_FLAGS.contains(&name) {
                    args.bools.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .with_context(|| format!("--{name} needs a value"))?;
                    if name == "set" {
                        // repeatable: accumulate with \n separator
                        let prev = args.flags.remove("set").unwrap_or_default();
                        let joined = if prev.is_empty() {
                            val.clone()
                        } else {
                            format!("{prev}\n{val}")
                        };
                        args.flags.insert("set".into(), joined);
                    } else {
                        args.flags.insert(name.to_string(), val.clone());
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad number {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    /// Like [`Args::get_u64`] but distinguishes "absent" from a default,
    /// for flags that override a config key only when present.
    pub fn get_u64_opt(&self, name: &str) -> Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .with_context(|| format!("--{name}: bad number {v:?}")),
        }
    }

    pub fn get_u8(&self, name: &str, default: u8) -> Result<u8> {
        let v = self.get_u64(name, default as u64)?;
        if v > 255 {
            bail!("--{name}: {v} out of range");
        }
        Ok(v as u8)
    }

    pub fn has(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.bools.iter().any(|b| b == name)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .with_context(|| format!("missing required --{name}"))
    }

    /// Error on unknown flags (everything present but never consumed).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.flags.keys() {
            if !consumed.iter().any(|c| c == k) {
                bail!("unknown flag --{k} for `{}`", self.subcommand);
            }
        }
        for b in &self.bools {
            if !consumed.iter().any(|c| c == b) {
                bail!("unknown flag --{b} for `{}`", self.subcommand);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn basic_parsing() {
        let a = parse("anf --spec rmat:16:16 --ranks 8 --exact pos1");
        assert_eq!(a.subcommand, "anf");
        assert_eq!(a.get("spec"), Some("rmat:16:16"));
        assert_eq!(a.get_u64("ranks", 1).unwrap(), 8);
        assert!(a.has("exact"));
        assert_eq!(a.positional, vec!["pos1"]);
        a.finish().unwrap();
    }

    #[test]
    fn repeatable_set() {
        let a = parse("run --set a.b=1 --set c.d=2");
        assert_eq!(a.get("set"), Some("a.b=1\nc.d=2"));
    }

    #[test]
    fn unknown_flags_error() {
        let a = parse("anf --bogus 3");
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_value_errors() {
        let argv = vec!["x".to_string(), "--ranks".to_string()];
        assert!(Args::parse(&argv).is_err());
    }
}
