//! Exact baselines: the ground truth for every accuracy figure.
//!
//! * [`neighborhood_sizes`] — exact `N(x, t)` for all `t ≤ k` by truncated
//!   BFS from each source (paper Eq. 1/2; used by Figure 1's MRE).
//! * [`edge_triangles`] — exact `T(xy)` for every edge by sorted adjacency
//!   intersection (paper Eq. 3; Figures 2–3), the `O(m^{3/2})`-ish
//!   algorithm class the paper cites as the exact competitor.
//! * [`vertex_triangles`] / [`global_triangles`] — Eq. 4–6 derived counts.

use std::collections::VecDeque;

use super::csr::Csr;

/// Exact local t-neighborhood sizes `N(x, t)` for all vertices and all
/// `1 <= t <= max_t`, via BFS truncated at depth `max_t`.
///
/// Returns `out[x][t - 1] = N(x, t)` (compact vertex ids). `N(x, t)`
/// counts vertices at distance `<= t` **excluding** x itself... actually
/// per paper Eq. 1 it *includes* x (d(x,x) = 0 <= t), and our estimators
/// approximate the same union-of-adjacency sets, so we follow Eq. 1 and
/// include the source.
pub fn neighborhood_sizes(csr: &Csr, max_t: usize) -> Vec<Vec<usize>> {
    let n = csr.num_vertices();
    let mut out = vec![vec![0usize; max_t]; n];
    let mut dist = vec![u32::MAX; n];
    let mut queue = VecDeque::new();
    for src in 0..n as u32 {
        // truncated BFS
        dist[src as usize] = 0;
        queue.push_back(src);
        let mut counts = vec![0usize; max_t + 1]; // counts[d] = #at distance d
        counts[0] = 1;
        let mut touched = vec![src];
        while let Some(u) = queue.pop_front() {
            let du = dist[u as usize];
            if du as usize >= max_t {
                continue;
            }
            for &v in csr.neighbors(u) {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = du + 1;
                    counts[du as usize + 1] += 1;
                    touched.push(v);
                    queue.push_back(v);
                }
            }
        }
        let mut acc = counts[0];
        for t in 1..=max_t {
            acc += counts[t];
            out[src as usize][t - 1] = acc;
        }
        for v in touched {
            dist[v as usize] = u32::MAX;
        }
        queue.clear();
    }
    out
}

/// Exact global t-neighborhood `N(t) = Σ_x N(x, t)` (paper Eq. 2).
pub fn global_neighborhood(per_vertex: &[Vec<usize>]) -> Vec<usize> {
    if per_vertex.is_empty() {
        return Vec::new();
    }
    let max_t = per_vertex[0].len();
    let mut out = vec![0usize; max_t];
    for row in per_vertex {
        for (t, &c) in row.iter().enumerate() {
            out[t] += c;
        }
    }
    out
}

/// Exact edge-local triangle counts `T(xy)` for every canonical edge
/// (paper Eq. 3). Returns `(u, v, count)` with compact ids, u < v.
pub fn edge_triangles(csr: &Csr) -> Vec<(u32, u32, usize)> {
    csr.edges()
        .map(|(u, v)| (u, v, csr.common_neighbors(u, v)))
        .collect()
}

/// Exact vertex-local triangle counts `T(x) = ½ Σ_{xy∈E} T(xy)`
/// (paper Eq. 5), indexed by compact vertex id.
pub fn vertex_triangles(csr: &Csr) -> Vec<usize> {
    let mut t2 = vec![0usize; csr.num_vertices()]; // 2·T(x)
    for (u, v, c) in edge_triangles(csr) {
        t2[u as usize] += c;
        t2[v as usize] += c;
    }
    t2.into_iter().map(|x| x / 2).collect()
}

/// Exact global triangle count `T = ⅓ Σ_{xy∈E} T(xy)` (paper Eq. 6).
pub fn global_triangles(csr: &Csr) -> usize {
    let total: usize = edge_triangles(csr).iter().map(|&(_, _, c)| c).sum();
    debug_assert_eq!(total % 3, 0);
    total / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::karate;

    #[test]
    fn triangle_of_triangle_graph() {
        let csr = Csr::from_edges(&[(0, 1), (1, 2), (2, 0)]);
        assert_eq!(global_triangles(&csr), 1);
        assert_eq!(vertex_triangles(&csr), vec![1, 1, 1]);
        for (_, _, c) in edge_triangles(&csr) {
            assert_eq!(c, 1);
        }
    }

    #[test]
    fn k5_counts() {
        let mut edges = Vec::new();
        for i in 0..5u64 {
            for j in i + 1..5 {
                edges.push((i, j));
            }
        }
        let csr = Csr::from_edges(&edges);
        // C(5,3) = 10 triangles; each vertex in C(4,2) = 6; each edge in 3.
        assert_eq!(global_triangles(&csr), 10);
        assert!(vertex_triangles(&csr).iter().all(|&t| t == 6));
        assert!(edge_triangles(&csr).iter().all(|&(_, _, c)| c == 3));
    }

    #[test]
    fn karate_has_45_triangles() {
        // The canonical Zachary karate club value.
        let csr = Csr::from_edges(&karate::edges());
        assert_eq!(global_triangles(&csr), 45);
    }

    #[test]
    fn path_graph_neighborhoods() {
        // path 0-1-2-3-4
        let csr = Csr::from_edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let ns = neighborhood_sizes(&csr, 4);
        let v0 = csr.compact_id(0).unwrap() as usize;
        let v2 = csr.compact_id(2).unwrap() as usize;
        assert_eq!(ns[v0], vec![2, 3, 4, 5]);
        assert_eq!(ns[v2], vec![3, 5, 5, 5]);
    }

    #[test]
    fn neighborhood_saturates_at_component() {
        // two disjoint triangles
        let csr =
            Csr::from_edges(&[(0, 1), (1, 2), (2, 0), (10, 11), (11, 12), (12, 10)]);
        let ns = neighborhood_sizes(&csr, 3);
        for row in &ns {
            assert_eq!(row[0], 3);
            assert_eq!(row[2], 3);
        }
        let g = global_neighborhood(&ns);
        assert_eq!(g, vec![18, 18, 18]);
    }

    #[test]
    fn vertex_counts_from_edge_counts() {
        let csr = Csr::from_edges(&karate::edges());
        let vt = vertex_triangles(&csr);
        let sum: usize = vt.iter().sum();
        assert_eq!(sum, 3 * global_triangles(&csr));
    }
}
