//! Edge streams: the σ of the paper (§2).
//!
//! A stream yields undirected edges and is *resettable* — Algorithm 2 takes
//! `t` passes and the triangle algorithms one more, so the source must be
//! replayable. Three implementations:
//!
//! * [`MemoryStream`] — a `Vec<Edge>` (generators produce these);
//! * [`FileStream`] — whitespace-separated `u v` text edge lists (the
//!   interchange format of SNAP datasets; `#`-prefixed comment lines are
//!   skipped);
//! * every stream can be [`EdgeStream::shard`]-ed into `|P|` substreams to
//!   model the unknown partitioning of σ the paper assumes.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::Edge;

/// A replayable source of undirected edges.
pub trait EdgeStream {
    /// Visit every edge once per pass. Self-loops are delivered as-is;
    /// consumers that need simple graphs filter them.
    fn for_each(&self, f: &mut dyn FnMut(Edge));

    /// Number of edges per pass, if cheaply known.
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// Collect into memory.
    fn collect_edges(&self) -> Vec<Edge> {
        let mut v = Vec::with_capacity(self.len_hint().unwrap_or(0));
        self.for_each(&mut |e| v.push(e));
        v
    }

    /// Round-robin shard into `shards` memory substreams (`σ_P` per
    /// processor). The paper's partitioning of σ is "by some unknown
    /// means"; round-robin matches its experimental setup.
    fn shard(&self, shards: usize) -> Vec<MemoryStream> {
        assert!(shards > 0);
        let mut parts: Vec<Vec<Edge>> = vec![Vec::new(); shards];
        let mut i = 0usize;
        self.for_each(&mut |e| {
            parts[i % shards].push(e);
            i += 1;
        });
        parts.into_iter().map(MemoryStream::new).collect()
    }
}

/// An in-memory edge stream.
#[derive(Debug, Clone, Default)]
pub struct MemoryStream {
    edges: Vec<Edge>,
}

impl MemoryStream {
    pub fn new(edges: Vec<Edge>) -> Self {
        Self { edges }
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn into_edges(self) -> Vec<Edge> {
        self.edges
    }
}

impl EdgeStream for MemoryStream {
    fn for_each(&self, f: &mut dyn FnMut(Edge)) {
        for &e in &self.edges {
            f(e);
        }
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.edges.len())
    }
}

/// A text edge-list file stream (`u v` per line, `#` comments allowed).
/// Re-reads the file on every pass — the true semi-streaming access
/// pattern, and how the multi-hundred-GB graphs of Table 1 would be fed.
#[derive(Debug, Clone)]
pub struct FileStream {
    path: PathBuf,
    len: usize,
}

impl FileStream {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        // One validation pass: counts edges and surfaces parse errors early.
        let mut len = 0usize;
        for_each_line(&path, &mut |_, _| len += 1)?;
        Ok(Self { path, len })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl EdgeStream for FileStream {
    fn for_each(&self, f: &mut dyn FnMut(Edge)) {
        for_each_line(&self.path, &mut |u, v| f((u, v)))
            .expect("edge file became unreadable between passes");
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.len)
    }
}

fn for_each_line(path: &Path, f: &mut dyn FnMut(u64, u64)) -> Result<()> {
    let file = File::open(path)
        .with_context(|| format!("opening edge list {}", path.display()))?;
    let reader = BufReader::with_capacity(1 << 20, file);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut it = s.split_whitespace();
        let parse = |tok: Option<&str>| -> Result<u64> {
            tok.with_context(|| format!("{}:{}: missing field", path.display(), lineno + 1))?
                .parse::<u64>()
                .with_context(|| format!("{}:{}: bad vertex id", path.display(), lineno + 1))
        };
        let u = parse(it.next())?;
        let v = parse(it.next())?;
        f(u, v);
    }
    Ok(())
}

/// Write an edge list in the text interchange format.
pub fn write_edge_list<P: AsRef<Path>>(path: P, edges: &[Edge]) -> Result<()> {
    let file = File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::with_capacity(1 << 20, file);
    for &(u, v) in edges {
        writeln!(w, "{u} {v}")?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_stream_replays() {
        let s = MemoryStream::new(vec![(0, 1), (1, 2), (2, 0)]);
        assert_eq!(s.len_hint(), Some(3));
        let a = s.collect_edges();
        let b = s.collect_edges();
        assert_eq!(a, b);
    }

    #[test]
    fn shard_partitions_all_edges() {
        let edges: Vec<Edge> = (0..100).map(|i| (i, i + 1)).collect();
        let s = MemoryStream::new(edges.clone());
        let shards = s.shard(7);
        assert_eq!(shards.len(), 7);
        let mut collected: Vec<Edge> =
            shards.iter().flat_map(|p| p.edges().to_vec()).collect();
        collected.sort_unstable();
        let mut want = edges;
        want.sort_unstable();
        assert_eq!(collected, want);
    }

    #[test]
    fn file_stream_round_trip() {
        let dir = std::env::temp_dir().join("degreesketch_test_stream");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let edges = vec![(0u64, 1u64), (5, 9), (7, 7)];
        write_edge_list(&path, &edges).unwrap();
        // append a comment and blank line; loader must skip them
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "# comment\n").unwrap();
        }
        let s = FileStream::open(&path).unwrap();
        assert_eq!(s.len_hint(), Some(3));
        assert_eq!(s.collect_edges(), edges);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn file_stream_rejects_garbage() {
        let dir = std::env::temp_dir().join("degreesketch_test_stream2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "1 banana\n").unwrap();
        assert!(FileStream::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
