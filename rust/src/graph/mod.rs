//! Graph substrate: edge streams, CSR, generators, exact baselines.
//!
//! The paper's input model is a *semi-streaming* one: the graph arrives as
//! an edge stream `σ` partitioned across processors, and algorithms may
//! take a bounded number of passes ([`stream::EdgeStream`]). On top of that
//! we provide:
//!
//! * [`csr::Csr`] — an in-memory compressed-sparse-row view used by the
//!   *exact* baselines (the paper's ground truth for Figures 1–3);
//! * [`gen`] — synthetic graph generators standing in for the paper's SNAP
//!   / Kronecker corpora (see DESIGN.md §Distributed-substrate
//!   substitution), including the nonstochastic Kronecker construction of
//!   Appendix C with exact edge-local triangle formulas ([`kron_truth`]);
//! * [`exact`] — exact t-neighborhood sizes (BFS) and exact edge-/vertex-
//!   local triangle counts (sorted adjacency intersection).

pub mod csr;
pub mod exact;
pub mod gen;
pub mod kron_truth;
pub mod stream;

/// Vertex identifier. Streams may carry arbitrary u64 ids (they need not be
/// contiguous); CSR construction compacts them.
pub type VertexId = u64;

/// An undirected edge. Stored unordered; [`Edge::canonical`] normalizes.
pub type Edge = (VertexId, VertexId);

/// Canonical form (min, max) of an undirected edge — the key used for
/// dedup, exact counts, and heavy-hitter identity.
#[inline]
pub fn canonical(e: Edge) -> Edge {
    if e.0 <= e.1 {
        e
    } else {
        (e.1, e.0)
    }
}
