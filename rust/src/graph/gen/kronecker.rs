//! Nonstochastic Kronecker graph products (paper Appendix C;
//! Weichsel 1962).
//!
//! For factor graphs `A` (n_a vertices) and `B` (n_b vertices), the product
//! `C = A ⊗ B` has vertex set `V_A × V_B` (encoded `a · n_b + b`) and an
//! edge `{(a1,b1), (a2,b2)}` iff `a1a2 ∈ E_A` and `b1b2 ∈ E_B`. Each pair
//! of factor edges therefore contributes (up to) two product edges:
//! `(a1,b1)-(a2,b2)` and `(a1,b2)-(a2,b1)`.
//!
//! The attraction (paper App. C): exact triangle ground truth is cheap —
//! see [`super::super::kron_truth`].

use crate::graph::Edge;

/// Kronecker product of two undirected edge lists.
///
/// `n_b` is the vertex-universe size of `B` used for id encoding
/// (`id = a * n_b + b`); `n_a` is accepted for symmetry/validation.
pub fn kronecker_product(
    a_edges: &[Edge],
    n_a: u64,
    b_edges: &[Edge],
    n_b: u64,
) -> Vec<Edge> {
    for &(u, v) in a_edges {
        assert!(u < n_a && v < n_a, "A edge ({u},{v}) out of range {n_a}");
    }
    for &(u, v) in b_edges {
        assert!(u < n_b && v < n_b, "B edge ({u},{v}) out of range {n_b}");
    }
    let mut edges = Vec::with_capacity(a_edges.len() * b_edges.len() * 2);
    for &(a1, a2) in a_edges {
        for &(b1, b2) in b_edges {
            edges.push((a1 * n_b + b1, a2 * n_b + b2));
            edges.push((a1 * n_b + b2, a2 * n_b + b1));
        }
    }
    super::finish(edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::gen::karate;

    #[test]
    fn triangle_squared() {
        // C3 ⊗ C3: tensor product of two triangles.
        let c3 = vec![(0u64, 1u64), (1, 2), (0, 2)];
        let prod = kronecker_product(&c3, 3, &c3, 3);
        let csr = Csr::from_edges(&prod);
        // tensor product of C3 with itself = two disjoint C... in general
        // m = 2·m_A·m_B (minus collisions/self-loops): 2·3·3 = 18
        assert_eq!(csr.num_edges(), 18);
        // every vertex has degree d_A·d_B = 4
        for v in 0..csr.num_vertices() as u32 {
            assert_eq!(csr.degree(v), 4);
        }
    }

    #[test]
    fn matches_brute_force_adjacency() {
        // definition check on small random-ish factors
        let a = vec![(0u64, 1u64), (1, 2), (2, 3), (0, 3), (0, 2)];
        let b = karate::edges();
        let n_a = 4u64;
        let n_b = karate::NUM_VERTICES as u64;
        let prod = kronecker_product(&a, n_a, &b, n_b);
        let csr = Csr::from_edges(&prod);
        let has =
            |x: u64, y: u64| -> bool {
                match (csr.compact_id(x), csr.compact_id(y)) {
                    (Some(cx), Some(cy)) => csr.has_edge(cx, cy),
                    _ => false,
                }
            };
        let a_adj = |u: u64, v: u64| {
            a.iter().any(|&(x, y)| (x, y) == (u.min(v), u.max(v)))
        };
        let b_adj = |u: u64, v: u64| {
            b.iter().any(|&(x, y)| (x, y) == (u.min(v), u.max(v)))
        };
        // sample the full product adjacency on a subset
        for a1 in 0..n_a {
            for a2 in 0..n_a {
                for b1 in 0..6 {
                    for b2 in 0..6 {
                        let expect = a_adj(a1, a2) && b_adj(b1, b2);
                        let got = has(a1 * n_b + b1, a2 * n_b + b2);
                        assert_eq!(
                            got, expect,
                            "({a1},{b1})-({a2},{b2})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn edge_count_upper_bound() {
        let k = karate::edges();
        let n = karate::NUM_VERTICES as u64;
        let prod = kronecker_product(&k, n, &k, n);
        // 2·78·78 = 12168 minus self-loops/collisions
        assert!(prod.len() <= 12168);
        assert!(prod.len() > 11000);
    }
}
