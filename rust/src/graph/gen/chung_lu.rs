//! Chung–Lu random graphs with a power-law expected degree sequence:
//! vertex `i` gets weight `w_i ∝ (i + i0)^(-1/(γ-1))`, and edges are
//! sampled by picking endpoints with probability proportional to weight.
//! A degree-sequence-controlled alternative to RMAT for the paper's
//! "moderate SNAP graph" suite.

use crate::graph::Edge;
use crate::hash::Xoshiro256ss;

/// Generate a Chung–Lu graph with `n` vertices and power-law exponent
/// `gamma` (typically 2.1–3.0). The expected edge count is ~`n · avg_w / 2`
/// with the weight normalization chosen to give mean degree ≈ 8.
pub fn chung_lu(n: u64, gamma: f64, seed: u64) -> Vec<Edge> {
    assert!(n >= 2);
    assert!(gamma > 1.5, "gamma must exceed 1.5");
    let mut rng = Xoshiro256ss::new(seed);
    let alpha = 1.0 / (gamma - 1.0);
    // weights w_i = c · (i + i0)^(-alpha); i0 avoids the singularity.
    let i0 = 10.0;
    let mut weights: Vec<f64> = (0..n)
        .map(|i| (i as f64 + i0).powf(-alpha))
        .collect();
    let wsum: f64 = weights.iter().sum();
    let target_mean_degree = 8.0_f64.min((n - 1) as f64);
    let scale = target_mean_degree * n as f64 / wsum / 2.0;
    for w in &mut weights {
        *w *= scale.sqrt();
    }

    // cumulative table for weight-proportional sampling
    let mut cum: Vec<f64> = Vec::with_capacity(n as usize);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cum.push(acc);
    }
    let total = acc;
    let m = ((weights.iter().sum::<f64>()).powi(2)
        / (2.0 * weights.iter().sum::<f64>()).max(1.0)
        * 1.0) as usize;
    let m = m.max(n as usize); // at least ~n edges
    let pick = |rng: &mut Xoshiro256ss| -> u64 {
        let x = rng.next_f64() * total;
        cum.partition_point(|&c| c < x) as u64
    };
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = pick(&mut rng).min(n - 1);
        let v = pick(&mut rng).min(n - 1);
        edges.push((u, v));
    }
    super::finish(edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn shape_and_determinism() {
        let a = chung_lu(2000, 2.5, 4);
        let b = chung_lu(2000, 2.5, 4);
        assert_eq!(a, b);
        let csr = Csr::from_edges(&a);
        assert!(csr.num_edges() >= 1000);
        for &(u, v) in &a {
            assert!(u < v && v < 2000);
        }
    }

    #[test]
    fn skewed_degrees() {
        let edges = chung_lu(5000, 2.2, 1);
        let csr = Csr::from_edges(&edges);
        let mut degs: Vec<usize> =
            (0..csr.num_vertices() as u32).map(|v| csr.degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        assert!(
            degs[0] as f64 > 5.0 * mean,
            "top degree {} vs mean {mean}",
            degs[0]
        );
    }
}
