//! Synthetic graph generators — the stand-ins for the paper's SNAP /
//! KONECT / WDC corpora (no network access in this environment; see
//! DESIGN.md §Distributed-substrate substitution).
//!
//! All generators emit deduplicated, self-loop-free, undirected edge lists
//! with canonical (u < v) ordering, deterministic in their seed.
//!
//! * [`karate`] — the real Zachary karate-club graph, built in (the small
//!   "natural" factor for Appendix C Kronecker products);
//! * [`erdos_renyi`] — G(n, m)-style uniform random graphs;
//! * [`barabasi_albert`] — preferential attachment (heavy-tail degrees,
//!   the social-network shape);
//! * [`watts_strogatz`] — small-world ring rewiring (high clustering —
//!   triangle-dense like ca-HepTh);
//! * [`chung_lu`] — configuration-model power-law (degree-sequence
//!   controlled);
//! * [`rmat`] — recursive matrix power-law (the SNAP/web-graph shape,
//!   including its low-triangle-density P2P-like regime);
//! * [`kronecker`] — nonstochastic Kronecker products (paper Appendix C)
//!   with exact triangle ground truth via [`super::kron_truth`].

pub mod ba;
pub mod chung_lu;
pub mod er;
pub mod karate;
pub mod kronecker;
pub mod rmat;
pub mod ws;

pub use ba::barabasi_albert;
pub use chung_lu::chung_lu;
pub use er::erdos_renyi;
pub use kronecker::kronecker_product;
pub use rmat::rmat;
pub use ws::watts_strogatz;

use crate::graph::Edge;

/// Canonicalize + sort + dedup + strip self-loops: the common postlude of
/// every generator.
pub(crate) fn finish(mut edges: Vec<Edge>) -> Vec<Edge> {
    for e in edges.iter_mut() {
        *e = crate::graph::canonical(*e);
    }
    edges.retain(|&(u, v)| u != v);
    edges.sort_unstable();
    edges.dedup();
    edges
}

/// A named graph spec used by the CLI and experiment suites.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphSpec {
    Karate,
    /// karate ⊗ karate ⊗ ... (`order` factors).
    KronKarate { order: u32 },
    ErdosRenyi { n: u64, m: u64 },
    BarabasiAlbert { n: u64, k: u64 },
    WattsStrogatz { n: u64, k: u64, rewire_pct: u64 },
    ChungLu { n: u64, exponent_x100: u64 },
    Rmat { scale: u32, edge_factor: u64 },
}

impl GraphSpec {
    /// Parse specs like `karate`, `kron-karate:2`, `er:1000:5000`,
    /// `ba:1000:4`, `ws:1000:8:10`, `cl:1000:250`, `rmat:16:16`.
    pub fn parse(s: &str) -> Option<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |i: usize| parts.get(i).and_then(|x| x.parse::<u64>().ok());
        match parts[0] {
            "karate" => Some(Self::Karate),
            "kron-karate" => Some(Self::KronKarate {
                order: num(1)? as u32,
            }),
            "er" => Some(Self::ErdosRenyi {
                n: num(1)?,
                m: num(2)?,
            }),
            "ba" => Some(Self::BarabasiAlbert {
                n: num(1)?,
                k: num(2)?,
            }),
            "ws" => Some(Self::WattsStrogatz {
                n: num(1)?,
                k: num(2)?,
                rewire_pct: num(3)?,
            }),
            "cl" => Some(Self::ChungLu {
                n: num(1)?,
                exponent_x100: num(2)?,
            }),
            "rmat" => Some(Self::Rmat {
                scale: num(1)? as u32,
                edge_factor: num(2)?,
            }),
            _ => None,
        }
    }

    /// Human-readable type name (Table 1 column).
    pub fn type_name(&self) -> &'static str {
        match self {
            Self::Karate => "Social (real)",
            Self::KronKarate { .. } => "Kronecker",
            Self::ErdosRenyi { .. } => "Erdős–Rényi",
            Self::BarabasiAlbert { .. } => "Pref. attachment",
            Self::WattsStrogatz { .. } => "Small world",
            Self::ChungLu { .. } => "Power law (CL)",
            Self::Rmat { .. } => "RMAT",
        }
    }

    /// Generate the edge list.
    pub fn generate(&self, seed: u64) -> Vec<Edge> {
        match *self {
            Self::Karate => karate::edges(),
            Self::KronKarate { order } => {
                let base = karate::edges();
                let mut edges = base.clone();
                let mut n = karate::NUM_VERTICES as u64;
                for _ in 1..order.max(1) {
                    edges = kronecker_product(&edges, n, &base, karate::NUM_VERTICES as u64);
                    n *= karate::NUM_VERTICES as u64;
                }
                edges
            }
            Self::ErdosRenyi { n, m } => erdos_renyi(n, m, seed),
            Self::BarabasiAlbert { n, k } => barabasi_albert(n, k, seed),
            Self::WattsStrogatz { n, k, rewire_pct } => {
                watts_strogatz(n, k, rewire_pct as f64 / 100.0, seed)
            }
            Self::ChungLu { n, exponent_x100 } => {
                chung_lu(n, exponent_x100 as f64 / 100.0, seed)
            }
            Self::Rmat { scale, edge_factor } => {
                rmat(scale, edge_factor, 0.57, 0.19, 0.19, seed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_cleans() {
        let edges = finish(vec![(3, 1), (1, 3), (2, 2), (1, 3), (0, 5)]);
        assert_eq!(edges, vec![(0, 5), (1, 3)]);
    }

    #[test]
    fn spec_parse_round_trips() {
        for s in [
            "karate",
            "kron-karate:2",
            "er:100:300",
            "ba:100:3",
            "ws:100:6:10",
            "cl:100:250",
            "rmat:10:8",
        ] {
            let spec = GraphSpec::parse(s).unwrap_or_else(|| panic!("{s}"));
            let edges = spec.generate(7);
            assert!(!edges.is_empty(), "{s} generated no edges");
            // canonical + dedup + no self loops
            for &(u, v) in &edges {
                assert!(u < v);
            }
            let mut d = edges.clone();
            d.dedup();
            assert_eq!(d.len(), edges.len());
        }
        assert!(GraphSpec::parse("wat:1").is_none());
    }

    #[test]
    fn generation_is_deterministic() {
        let a = GraphSpec::parse("rmat:10:8").unwrap().generate(5);
        let b = GraphSpec::parse("rmat:10:8").unwrap().generate(5);
        assert_eq!(a, b);
        let c = GraphSpec::parse("rmat:10:8").unwrap().generate(6);
        assert_ne!(a, c);
    }
}
