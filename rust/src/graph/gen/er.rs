//! Erdős–Rényi G(n, m): m uniform random vertex pairs (rejecting
//! self-loops and duplicates). The low-clustering baseline of the suite —
//! its near-zero triangle density mimics the paper's P2P-Gnutella outlier
//! in Figure 3.

use std::collections::HashSet;

use crate::graph::Edge;
use crate::hash::Xoshiro256ss;

/// Generate an undirected simple G(n, m) graph.
///
/// Panics if `m` exceeds the number of possible edges.
pub fn erdos_renyi(n: u64, m: u64, seed: u64) -> Vec<Edge> {
    assert!(n >= 2, "need at least 2 vertices");
    let possible = n * (n - 1) / 2;
    assert!(m <= possible, "m={m} exceeds C({n},2)={possible}");
    let mut rng = Xoshiro256ss::new(seed);
    let mut seen: HashSet<Edge> = HashSet::with_capacity(m as usize * 2);
    let mut edges = Vec::with_capacity(m as usize);
    while edges.len() < m as usize {
        let u = rng.next_below(n);
        let v = rng.next_below(n);
        if u == v {
            continue;
        }
        let e = crate::graph::canonical((u, v));
        if seen.insert(e) {
            edges.push(e);
        }
    }
    edges.sort_unstable();
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn exact_edge_count() {
        let edges = erdos_renyi(500, 2000, 1);
        assert_eq!(edges.len(), 2000);
        let csr = Csr::from_edges(&edges);
        assert_eq!(csr.num_edges(), 2000);
        assert!(csr.num_vertices() <= 500);
    }

    #[test]
    fn deterministic() {
        assert_eq!(erdos_renyi(100, 300, 9), erdos_renyi(100, 300, 9));
        assert_ne!(erdos_renyi(100, 300, 9), erdos_renyi(100, 300, 10));
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn too_many_edges_panics() {
        erdos_renyi(4, 100, 0);
    }
}
