//! RMAT (recursive matrix) generator — the standard stand-in for scale-free
//! SNAP/web graphs (Graph500 uses the same construction). Each edge is
//! placed by `scale` recursive quadrant choices with probabilities
//! (a, b, c, d).

use crate::graph::Edge;
use crate::hash::Xoshiro256ss;

/// Generate an RMAT graph over `2^scale` vertices with `edge_factor`
/// directed samples per vertex (dedup makes the final count slightly
/// lower). `(a, b, c)` are the quadrant probabilities; `d = 1 - a - b - c`.
pub fn rmat(
    scale: u32,
    edge_factor: u64,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> Vec<Edge> {
    assert!(scale >= 1 && scale <= 30);
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0 && a > 0.0 && b >= 0.0 && c >= 0.0);
    let n = 1u64 << scale;
    let m = n * edge_factor;
    let mut rng = Xoshiro256ss::new(seed);
    let mut edges = Vec::with_capacity(m as usize);
    for _ in 0..m {
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            u <<= 1;
            v <<= 1;
            let x = rng.next_f64();
            if x < a {
                // (0,0)
            } else if x < a + b {
                v |= 1;
            } else if x < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        edges.push((u, v));
    }
    super::finish(edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn shape_and_determinism() {
        let a = rmat(12, 8, 0.57, 0.19, 0.19, 3);
        let b = rmat(12, 8, 0.57, 0.19, 0.19, 3);
        assert_eq!(a, b);
        for &(u, v) in &a {
            assert!(u < v && v < (1 << 12));
        }
        // dedup loses some of the 32768 samples but not most
        assert!(a.len() > 20_000, "{}", a.len());
    }

    #[test]
    fn skewed_quadrants_give_hubs() {
        let edges = rmat(13, 8, 0.57, 0.19, 0.19, 1);
        let csr = Csr::from_edges(&edges);
        let mut degs: Vec<usize> =
            (0..csr.num_vertices() as u32).map(|v| csr.degree(v)).collect();
        degs.sort_unstable_by(|x, y| y.cmp(x));
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        assert!(degs[0] as f64 > 10.0 * mean);
    }

    #[test]
    fn uniform_quadrants_approximate_er() {
        let edges = rmat(12, 8, 0.25, 0.25, 0.25, 2);
        let csr = Csr::from_edges(&edges);
        let max_deg = (0..csr.num_vertices() as u32)
            .map(|v| csr.degree(v))
            .max()
            .unwrap();
        // no big hubs when quadrants are uniform
        assert!(max_deg < 40, "{max_deg}");
    }
}
