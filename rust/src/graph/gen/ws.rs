//! Watts–Strogatz small world: a ring lattice (each vertex linked to its
//! `k` nearest neighbors) with a fraction of edges rewired uniformly.
//! High clustering at low rewiring — the triangle-dense regime (and, at
//! k-regular ties, a source of the triangle-count *ties* the paper blames
//! for ca-HepTh's poor heavy-hitter separability in Figure 3).

use crate::graph::Edge;
use crate::hash::Xoshiro256ss;

/// Generate a WS graph: `n` vertices on a ring, each joined to the `k/2`
/// neighbors on each side, each edge rewired with probability `beta`.
pub fn watts_strogatz(n: u64, k: u64, beta: f64, seed: u64) -> Vec<Edge> {
    assert!(k >= 2 && k % 2 == 0, "k must be even and >= 2");
    assert!(n > k, "need n > k");
    assert!((0.0..=1.0).contains(&beta));
    let mut rng = Xoshiro256ss::new(seed);
    let mut edges: Vec<Edge> = Vec::with_capacity((n * k / 2) as usize);
    for u in 0..n {
        for d in 1..=k / 2 {
            let v = (u + d) % n;
            if rng.next_f64() < beta {
                // rewire the far endpoint uniformly (avoiding u)
                let mut w = rng.next_below(n);
                while w == u {
                    w = rng.next_below(n);
                }
                edges.push((u, w));
            } else {
                edges.push((u, v));
            }
        }
    }
    super::finish(edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::exact;

    #[test]
    fn unrewired_ring_is_regular_and_triangle_rich() {
        let edges = watts_strogatz(100, 6, 0.0, 1);
        let csr = Csr::from_edges(&edges);
        assert_eq!(csr.num_edges(), 300);
        for v in 0..csr.num_vertices() as u32 {
            assert_eq!(csr.degree(v), 6);
        }
        // ring with k=6: each vertex participates in exactly 2·3 triangles
        // minus boundary-free ring => uniform positive counts
        let t = exact::vertex_triangles(&csr);
        assert!(t.iter().all(|&x| x > 0));
        // ties everywhere: all vertices have the same count
        assert!(t.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn rewiring_reduces_clustering() {
        let t0 = exact::global_triangles(&Csr::from_edges(&watts_strogatz(
            500, 8, 0.0, 2,
        )));
        let t1 = exact::global_triangles(&Csr::from_edges(&watts_strogatz(
            500, 8, 0.9, 2,
        )));
        assert!(t1 < t0 / 2, "rewired {t1} vs lattice {t0}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            watts_strogatz(200, 4, 0.3, 5),
            watts_strogatz(200, 4, 0.3, 5)
        );
    }
}
