//! Zachary's karate club (Zachary 1977) — the one *real* graph we can
//! carry without network access. 34 vertices, 78 edges, 45 triangles.
//!
//! It serves the role the UF sparse matrix collection's small graphs
//! (polbooks, celegans, …) play in the paper's Appendix C: a natural
//! small factor for nonstochastic Kronecker products with exact triangle
//! ground truth.

use crate::graph::Edge;

/// Number of vertices (ids 0..34).
pub const NUM_VERTICES: usize = 34;

/// The canonical 78-edge list (0-indexed, u < v).
pub fn edges() -> Vec<Edge> {
    // 1-indexed pairs from the canonical UCINET data, shifted to 0-index.
    const E: [(u64, u64); 78] = [
        (1, 2), (1, 3), (1, 4), (1, 5), (1, 6), (1, 7), (1, 8), (1, 9),
        (1, 11), (1, 12), (1, 13), (1, 14), (1, 18), (1, 20), (1, 22),
        (1, 32), (2, 3), (2, 4), (2, 8), (2, 14), (2, 18), (2, 20), (2, 22),
        (2, 31), (3, 4), (3, 8), (3, 9), (3, 10), (3, 14), (3, 28), (3, 29),
        (3, 33), (4, 8), (4, 13), (4, 14), (5, 7), (5, 11), (6, 7), (6, 11),
        (6, 17), (7, 17), (9, 31), (9, 33), (9, 34), (10, 34), (14, 34),
        (15, 33), (15, 34), (16, 33), (16, 34), (19, 33), (19, 34), (20, 34),
        (21, 33), (21, 34), (23, 33), (23, 34), (24, 26), (24, 28), (24, 30),
        (24, 33), (24, 34), (25, 26), (25, 28), (25, 32), (26, 32), (27, 30),
        (27, 34), (28, 34), (29, 32), (29, 34), (30, 33), (30, 34), (31, 33),
        (31, 34), (32, 33), (32, 34), (33, 34),
    ];
    E.iter().map(|&(u, v)| (u - 1, v - 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape() {
        let e = edges();
        assert_eq!(e.len(), 78);
        let max = e.iter().map(|&(u, v)| u.max(v)).max().unwrap();
        assert_eq!(max as usize + 1, NUM_VERTICES);
        for &(u, v) in &e {
            assert!(u < v);
        }
    }

    #[test]
    fn known_degrees() {
        // vertex 34 (0-indexed 33) has degree 17; vertex 1 (0-indexed 0)
        // degree 16 — the two "leaders" of the club.
        let mut deg = [0usize; NUM_VERTICES];
        for (u, v) in edges() {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        assert_eq!(deg[33], 17);
        assert_eq!(deg[0], 16);
        assert_eq!(deg.iter().sum::<usize>(), 2 * 78);
    }
}
