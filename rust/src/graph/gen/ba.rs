//! Barabási–Albert preferential attachment: each new vertex attaches to
//! `k` existing vertices chosen proportionally to degree. Produces the
//! heavy-tailed degree distribution of social networks — the shape that
//! stresses DegreeSketch's sparse→dense transition and the domination
//! phenomenon of Appendix B (hubs dominate leaves).

use crate::graph::Edge;
use crate::hash::Xoshiro256ss;

/// Generate a BA graph with `n` vertices and `k` attachments per vertex.
pub fn barabasi_albert(n: u64, k: u64, seed: u64) -> Vec<Edge> {
    assert!(k >= 1, "k must be >= 1");
    assert!(n > k, "need n > k");
    let mut rng = Xoshiro256ss::new(seed);
    // `targets` holds one entry per degree unit — sampling uniformly from
    // it is exactly degree-proportional sampling.
    let mut targets: Vec<u64> = Vec::with_capacity((2 * k * n) as usize);
    let mut edges: Vec<Edge> = Vec::with_capacity((k * n) as usize);

    // seed clique on k+1 vertices
    for u in 0..=k {
        for v in u + 1..=k {
            edges.push((u, v));
            targets.push(u);
            targets.push(v);
        }
    }
    for u in k + 1..n {
        let mut picked: Vec<u64> = Vec::with_capacity(k as usize);
        while picked.len() < k as usize {
            let t = targets[rng.next_below(targets.len() as u64) as usize];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &v in &picked {
            edges.push((v, u));
            targets.push(u);
            targets.push(v);
        }
    }
    super::finish(edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn shape_and_connectivity() {
        let edges = barabasi_albert(500, 3, 2);
        let csr = Csr::from_edges(&edges);
        assert_eq!(csr.num_vertices(), 500);
        // m = C(4,2) + 3·(n - 4)
        assert_eq!(csr.num_edges(), 6 + 3 * (500 - 4));
        // connected: BFS from 0 reaches everything
        let ns = crate::graph::exact::neighborhood_sizes(&csr, 500.min(32));
        assert_eq!(ns[0][31], 500);
    }

    #[test]
    fn heavy_tail() {
        let edges = barabasi_albert(2000, 2, 3);
        let csr = Csr::from_edges(&edges);
        let max_deg = (0..csr.num_vertices() as u32)
            .map(|v| csr.degree(v))
            .max()
            .unwrap();
        // hubs should far exceed the mean degree (4)
        assert!(max_deg > 40, "max degree {max_deg} not heavy-tailed");
    }

    #[test]
    fn deterministic() {
        assert_eq!(barabasi_albert(200, 2, 7), barabasi_albert(200, 2, 7));
    }
}
