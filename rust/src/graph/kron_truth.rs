//! Exact triangle ground truth for Kronecker products (paper Appendix C;
//! Sanders et al. 2018).
//!
//! For `C = A ⊗ B`, a common neighbor of product vertices `(a1,b1)` and
//! `(a2,b2)` is any `(a3,b3)` with `a3 ∈ N_A(a1) ∩ N_A(a2)` and
//! `b3 ∈ N_B(b1) ∩ N_B(b2)`. Hence the edge-local triangle count of a
//! product edge factorizes:
//!
//! ```text
//! T_C((a1,b1)-(a2,b2)) = cn_A(a1, a2) · cn_B(b1, b2)
//! ```
//!
//! where `cn` is the common-neighbor count in the factor. This lets the
//! benches ground-truth graphs whose product is far too large to triangle-
//! count directly — the paper's reason for using Kronecker graphs at scale.

use super::csr::Csr;
use super::Edge;

/// Precomputed common-neighbor counts of a factor graph.
#[derive(Debug, Clone)]
pub struct FactorCommonNeighbors {
    csr: Csr,
}

impl FactorCommonNeighbors {
    pub fn new(edges: &[Edge]) -> Self {
        Self {
            csr: Csr::from_edges(edges),
        }
    }

    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// Common-neighbor count between two original vertex ids (0 for ids
    /// absent from the factor).
    pub fn count(&self, u: u64, v: u64) -> usize {
        match (self.csr.compact_id(u), self.csr.compact_id(v)) {
            (Some(cu), Some(cv)) => self.csr.common_neighbors(cu, cv),
            _ => 0,
        }
    }
}

/// Exact edge-local triangle count of a product edge, given the factor
/// tables and the B-universe size used for id encoding.
pub fn product_edge_triangles(
    a: &FactorCommonNeighbors,
    b: &FactorCommonNeighbors,
    n_b: u64,
    edge: Edge,
) -> usize {
    let (x, y) = edge;
    let (a1, b1) = (x / n_b, x % n_b);
    let (a2, b2) = (y / n_b, y % n_b);
    a.count(a1, a2) * b.count(b1, b2)
}

/// Exact edge-local triangle counts for every edge of the product graph
/// (streamed over the product edge list; never materializes the product
/// adjacency).
pub fn all_product_edge_triangles(
    a: &FactorCommonNeighbors,
    b: &FactorCommonNeighbors,
    n_b: u64,
    product_edges: &[Edge],
) -> Vec<(Edge, usize)> {
    product_edges
        .iter()
        .map(|&e| (e, product_edge_triangles(a, b, n_b, e)))
        .collect()
}

/// Exact global triangle count of the product from edge-local counts
/// (paper Eq. 6: `T = ⅓ Σ T(xy)`).
pub fn product_global_triangles(
    a: &FactorCommonNeighbors,
    b: &FactorCommonNeighbors,
    n_b: u64,
    product_edges: &[Edge],
) -> usize {
    let total: usize = product_edges
        .iter()
        .map(|&e| product_edge_triangles(a, b, n_b, e))
        .sum();
    debug_assert_eq!(total % 3, 0);
    total / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::exact;
    use crate::graph::gen::{karate, kronecker_product};

    #[test]
    fn formula_matches_direct_count_karate_squared() {
        let k = karate::edges();
        let n = karate::NUM_VERTICES as u64;
        let prod = kronecker_product(&k, n, &k, n);
        let fa = FactorCommonNeighbors::new(&k);
        let fb = FactorCommonNeighbors::new(&k);

        // direct exact count on the product
        let csr = Csr::from_edges(&prod);
        for (cu, cv, truth) in exact::edge_triangles(&csr) {
            let e = (csr.original_id(cu), csr.original_id(cv));
            let formula = product_edge_triangles(&fa, &fb, n, e);
            assert_eq!(formula, truth, "edge {e:?}");
        }

        // and the global count agrees
        let g_formula = product_global_triangles(&fa, &fb, n, &prod);
        assert_eq!(g_formula, exact::global_triangles(&csr));
    }

    #[test]
    fn formula_matches_on_mixed_factors() {
        let a_edges = vec![(0u64, 1u64), (1, 2), (0, 2), (2, 3)];
        let b_edges = karate::edges();
        let n_b = karate::NUM_VERTICES as u64;
        let prod = kronecker_product(&a_edges, 4, &b_edges, n_b);
        let fa = FactorCommonNeighbors::new(&a_edges);
        let fb = FactorCommonNeighbors::new(&b_edges);
        let csr = Csr::from_edges(&prod);
        for (cu, cv, truth) in exact::edge_triangles(&csr) {
            let e = (csr.original_id(cu), csr.original_id(cv));
            assert_eq!(product_edge_triangles(&fa, &fb, n_b, e), truth);
        }
    }
}
