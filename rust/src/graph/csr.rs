//! Compressed sparse row adjacency — the in-memory view used by exact
//! baselines (the paper's ground-truth computations) and by tests.
//!
//! Construction mirrors the paper's data hygiene (§5 "Graphs"): input
//! edges are cast as undirected, and self-loops and repeated edges are
//! dropped. Vertex ids are compacted to `0..n`; the original ids are kept
//! for reporting.

use std::collections::HashMap;

use super::stream::EdgeStream;
use super::{Edge, VertexId};

/// Immutable undirected simple graph in CSR form.
#[derive(Debug, Clone)]
pub struct Csr {
    /// Offsets into `adj`, length n+1.
    offsets: Vec<usize>,
    /// Concatenated sorted neighbor lists (compact ids).
    adj: Vec<u32>,
    /// Compact id -> original id.
    vertex_ids: Vec<VertexId>,
    /// Original id -> compact id.
    index: HashMap<VertexId, u32>,
    /// Number of undirected edges after dedup.
    num_edges: usize,
}

impl Csr {
    /// Build from an edge stream (one pass), dropping self-loops and
    /// duplicate edges, ignoring direction.
    pub fn from_stream(stream: &dyn EdgeStream) -> Self {
        Self::from_edges_impl(&stream.collect_edges())
    }

    /// Build from an edge slice.
    pub fn from_edges(edges: &[Edge]) -> Self {
        Self::from_edges_impl(edges)
    }

    fn from_edges_impl(raw: &[Edge]) -> Self {
        // compact ids in first-seen order (deterministic)
        let mut index: HashMap<VertexId, u32> = HashMap::new();
        let mut vertex_ids: Vec<VertexId> = Vec::new();
        let intern = |id: VertexId,
                          index: &mut HashMap<VertexId, u32>,
                          vertex_ids: &mut Vec<VertexId>| {
            *index.entry(id).or_insert_with(|| {
                vertex_ids.push(id);
                (vertex_ids.len() - 1) as u32
            })
        };
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(raw.len());
        for &(u, v) in raw {
            if u == v {
                continue;
            }
            let cu = intern(u, &mut index, &mut vertex_ids);
            let cv = intern(v, &mut index, &mut vertex_ids);
            pairs.push((cu.min(cv), cu.max(cv)));
        }
        pairs.sort_unstable();
        pairs.dedup();
        let n = vertex_ids.len();
        let num_edges = pairs.len();

        let mut degree = vec![0usize; n];
        for &(u, v) in &pairs {
            degree[u as usize] += 1;
            degree[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let mut adj = vec![0u32; offsets[n]];
        let mut cursor = offsets.clone();
        for &(u, v) in &pairs {
            adj[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        for i in 0..n {
            adj[offsets[i]..offsets[i + 1]].sort_unstable();
        }
        Self {
            offsets,
            adj,
            vertex_ids,
            index,
            num_edges,
        }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.vertex_ids.len()
    }

    /// Number of undirected edges (post dedup / self-loop removal).
    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    /// Sorted neighbors of a compact vertex id.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.adj[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Degree of a compact vertex id.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        self.neighbors(v).len()
    }

    /// Original id of a compact id.
    #[inline]
    pub fn original_id(&self, v: u32) -> VertexId {
        self.vertex_ids[v as usize]
    }

    /// Compact id of an original id, if present.
    #[inline]
    pub fn compact_id(&self, id: VertexId) -> Option<u32> {
        self.index.get(&id).copied()
    }

    /// Whether the (undirected) edge u–v exists (compact ids).
    #[inline]
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Iterate canonical (u < v, compact) edges.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.num_vertices() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Size of the sorted intersection of two neighbor lists — the common
    /// neighbor count, i.e. the exact edge-local triangle count when u–v is
    /// an edge (paper Eq. 3).
    pub fn common_neighbors(&self, u: u32, v: u32) -> usize {
        let (a, b) = (self.neighbors(u), self.neighbors(v));
        let (mut i, mut j, mut count) = (0usize, 0usize, 0usize);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    count += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_self_loops() {
        let csr = Csr::from_edges(&[(1, 2), (2, 1), (1, 1), (2, 3), (2, 3)]);
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.num_edges(), 2);
        let v1 = csr.compact_id(1).unwrap();
        let v2 = csr.compact_id(2).unwrap();
        let v3 = csr.compact_id(3).unwrap();
        assert!(csr.has_edge(v1, v2));
        assert!(csr.has_edge(v2, v3));
        assert!(!csr.has_edge(v1, v3));
    }

    #[test]
    fn triangle_common_neighbors() {
        // K4: every edge has 2 common neighbors.
        let csr = Csr::from_edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        for (u, v) in csr.edges() {
            assert_eq!(csr.common_neighbors(u, v), 2);
        }
    }

    #[test]
    fn neighbors_sorted_and_degrees_consistent() {
        let csr = Csr::from_edges(&[(5, 1), (5, 9), (5, 3), (1, 9)]);
        let v5 = csr.compact_id(5).unwrap();
        let ns = csr.neighbors(v5);
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(csr.degree(v5), 3);
        let total: usize =
            (0..csr.num_vertices() as u32).map(|v| csr.degree(v)).sum();
        assert_eq!(total, 2 * csr.num_edges());
    }

    #[test]
    fn edges_iterator_yields_each_once() {
        let csr = Csr::from_edges(&[(0, 1), (1, 2), (2, 0), (2, 3)]);
        let edges: Vec<_> = csr.edges().collect();
        assert_eq!(edges.len(), csr.num_edges());
        let mut dedup = edges.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), edges.len());
    }
}
