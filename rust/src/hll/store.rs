//! Arena-backed per-rank sketch storage — the accumulation hot path.
//!
//! The naive layout (`HashMap<VertexId, Hll>`) pays one heap allocation
//! per vertex sketch, duplicates the 16-byte `HllConfig` (hash seed
//! included) into every `Hll`, and scatters register data across the heap.
//! [`SketchStore`] owns an entire shard's registers in contiguous memory
//! with **one** shared config:
//!
//! ```text
//! SketchStore
//! ├── slots:  HashMap<VertexId, SlotId>       flat vertex → slot index
//! │             SlotId::Sparse(s) | SlotId::Dense(d)
//! ├── sparse: SparsePool                      pooled pair buffers
//! │     slots[s]  = { class, block, len }     per-sketch metadata (8 B)
//! │     classes[c] = slab of fixed-capacity blocks of (u16 idx, u8 val)
//! │                  pairs, capacity 4 << c; freed blocks recycle via a
//! │                  per-class free list (saturation returns blocks)
//! └── dense:  DenseArena                      saturated sketches
//!       regs  = one Vec<u8>,  r bytes per sketch, slot-major
//!       hists = one Vec<u32>, (kmax + 1) counters per sketch, maintained
//!               incrementally on every insert/merge so estimates are
//!               O(kmax) with no register scan
//! ```
//!
//! A sketch starts as a class-0 sparse block (4 pairs), doubles through
//! size classes as it grows, and saturates into the dense arena once its
//! pair count exceeds `r / 4` (the paper's Alg. 6 threshold) — exactly
//! the same transition rule as [`Hll`], so store-backed accumulation is
//! **bit-identical** to the per-sketch path, representation included.
//!
//! Reads hand out [`SketchRef`] — borrowed register views that estimate,
//! merge, and materialize without touching the owning arena. Bulk updates
//! go through [`SketchStore::insert_batch`], which groups `(vertex,
//! element)` messages per vertex, pre-hashes and sorts each group, and
//! applies it as one two-pointer merge instead of per-element
//! binary-search + `Vec::insert`.

use std::collections::HashMap;

use super::estimate::estimate_from_hist;
use super::kernels;
use super::{Estimator, Hll, HllConfig};

/// Initial sparse block capacity (pairs); class `c` holds `4 << c`.
const BASE_CAP: usize = 4;

/// Where a vertex's registers live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotId {
    Sparse(u32),
    Dense(u32),
}

/// Per-sparse-sketch metadata: which class slab, which block, how full.
#[derive(Debug, Clone, Copy)]
struct SparseSlot {
    class: u8,
    block: u32,
    len: u16,
}

/// One size class: a slab of equal-capacity pair blocks plus a free list.
#[derive(Debug, Clone)]
struct ClassSlab {
    cap: usize,
    pairs: Vec<(u16, u8)>,
    free: Vec<u32>,
}

#[derive(Debug, Clone, Default)]
struct SparsePool {
    slots: Vec<SparseSlot>,
    free_slots: Vec<u32>,
    classes: Vec<ClassSlab>,
}

impl SparsePool {
    fn ensure_class(&mut self, c: usize) {
        while self.classes.len() <= c {
            let cap = BASE_CAP << self.classes.len();
            self.classes.push(ClassSlab {
                cap,
                pairs: Vec::new(),
                free: Vec::new(),
            });
        }
    }

    fn alloc_block(&mut self, c: usize) -> u32 {
        self.ensure_class(c);
        let slab = &mut self.classes[c];
        if let Some(b) = slab.free.pop() {
            return b;
        }
        let b = (slab.pairs.len() / slab.cap) as u32;
        slab.pairs.resize(slab.pairs.len() + slab.cap, (0, 0));
        b
    }

    fn alloc_slot(&mut self) -> u32 {
        let block = self.alloc_block(0);
        let meta = SparseSlot {
            class: 0,
            block,
            len: 0,
        };
        if let Some(s) = self.free_slots.pop() {
            self.slots[s as usize] = meta;
            s
        } else {
            self.slots.push(meta);
            (self.slots.len() - 1) as u32
        }
    }

    fn free_block(&mut self, meta: SparseSlot) {
        self.classes[meta.class as usize].free.push(meta.block);
    }

    fn free_slot(&mut self, s: u32) {
        self.free_slots.push(s);
    }

    fn pairs_of(&self, meta: SparseSlot) -> &[(u16, u8)] {
        let slab = &self.classes[meta.class as usize];
        let start = meta.block as usize * slab.cap;
        &slab.pairs[start..start + meta.len as usize]
    }

    fn memory_bytes(&self) -> usize {
        self.classes
            .iter()
            .map(|s| {
                s.pairs.capacity() * std::mem::size_of::<(u16, u8)>()
                    + s.free.capacity() * 4
            })
            .sum::<usize>()
            + self.slots.capacity() * std::mem::size_of::<SparseSlot>()
            + self.free_slots.capacity() * 4
    }
}

/// Dense register arena: slot-major registers plus per-slot histograms.
#[derive(Debug, Clone)]
struct DenseArena {
    r: usize,
    bins: usize,
    count: usize,
    regs: Vec<u8>,
    hists: Vec<u32>,
}

impl DenseArena {
    fn new(r: usize, bins: usize) -> Self {
        Self {
            r,
            bins,
            count: 0,
            regs: Vec::new(),
            hists: Vec::new(),
        }
    }

    /// Append a zeroed slot (`hist[0] = r`) and return its index.
    fn alloc(&mut self) -> u32 {
        let idx = self.count;
        self.count += 1;
        self.regs.resize(self.regs.len() + self.r, 0);
        self.hists.resize(self.hists.len() + self.bins, 0);
        self.hists[idx * self.bins] = self.r as u32;
        idx as u32
    }

    /// Scatter sorted pairs into a freshly allocated slot.
    fn scatter(&mut self, idx: u32, pairs: &[(u16, u8)]) {
        let ro = idx as usize * self.r;
        let ho = idx as usize * self.bins;
        for &(j, x) in pairs {
            self.regs[ro + j as usize] = x;
            self.hists[ho + x as usize] += 1;
        }
        self.hists[ho] -= pairs.len() as u32;
    }

    #[inline]
    fn insert(&mut self, idx: usize, j: u32, x: u8) {
        let slot = &mut self.regs[idx * self.r + j as usize];
        if x > *slot {
            let ho = idx * self.bins;
            self.hists[ho + *slot as usize] -= 1;
            self.hists[ho + x as usize] += 1;
            *slot = x;
        }
    }

    /// SWAR byte-max merge of a dense register slice into slot `idx`.
    fn merge_dense(&mut self, idx: usize, src: &[u8]) {
        let ro = idx * self.r;
        let ho = idx * self.bins;
        let regs = &mut self.regs[ro..ro + self.r];
        let hist = &mut self.hists[ho..ho + self.bins];
        kernels::merge_max_hist(regs, src, hist);
    }

    fn regs_of(&self, idx: u32) -> &[u8] {
        let ro = idx as usize * self.r;
        &self.regs[ro..ro + self.r]
    }

    fn hist_of(&self, idx: u32) -> &[u32] {
        let ho = idx as usize * self.bins;
        &self.hists[ho..ho + self.bins]
    }

    fn memory_bytes(&self) -> usize {
        self.regs.capacity() + self.hists.capacity() * 4
    }
}

/// A borrowed, zero-copy view of one sketch inside a [`SketchStore`]
/// (or materialized data elsewhere). Carries the shared config by value
/// (`HllConfig` is `Copy`).
#[derive(Debug, Clone, Copy)]
pub enum SketchRef<'a> {
    Sparse {
        config: HllConfig,
        pairs: &'a [(u16, u8)],
    },
    Dense {
        config: HllConfig,
        regs: &'a [u8],
        hist: &'a [u32],
    },
}

impl SketchRef<'_> {
    pub fn config(&self) -> HllConfig {
        match self {
            Self::Sparse { config, .. } | Self::Dense { config, .. } => *config,
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Self::Dense { .. })
    }

    pub fn nonzero_registers(&self) -> usize {
        match self {
            Self::Sparse { pairs, .. } => pairs.len(),
            Self::Dense { config, hist, .. } => {
                config.num_registers() - hist[0] as usize
            }
        }
    }

    /// Cardinality estimate — `O(kmax)` for dense views thanks to the
    /// arena-maintained histogram.
    pub fn estimate_with(&self, estimator: Estimator) -> f64 {
        let config = self.config();
        let q = config.q() as usize;
        let p = config.p();
        match self {
            Self::Dense { hist, .. } => {
                estimate_from_hist(hist, q, p, estimator)
            }
            Self::Sparse { pairs, .. } => {
                let hist = super::sparse_histogram(&config, pairs);
                estimate_from_hist(&hist, q, p, estimator)
            }
        }
    }

    pub fn estimate(&self) -> f64 {
        self.estimate_with(Estimator::default())
    }

    /// Materialize into an owned [`Hll`] (same representation: a sparse
    /// view yields a sparse sketch, a dense view a dense one).
    pub fn to_hll(&self) -> Hll {
        match self {
            Self::Sparse { config, pairs } => {
                Hll::from_sparse_parts(*config, pairs.to_vec())
            }
            Self::Dense { config, regs, hist } => {
                Hll::from_dense_parts(*config, regs.to_vec(), hist.to_vec())
            }
        }
    }
}

/// Borrow a view of an owned [`Hll`] (the compat direction: lets store
/// code and sketch code share one merge implementation).
pub fn view_of(h: &Hll) -> SketchRef<'_> {
    match h.sparse_pairs() {
        Some(pairs) => SketchRef::Sparse {
            config: *h.config(),
            pairs,
        },
        None => {
            let config = *h.config();
            // dense sketches always carry registers + histogram
            let regs = h.dense_registers().expect("dense");
            SketchRef::Dense {
                config,
                regs,
                hist: h.dense_hist().expect("dense"),
            }
        }
    }
}

/// One rank's shard of vertex sketches in contiguous arena storage.
#[derive(Debug, Clone)]
pub struct SketchStore {
    config: HllConfig,
    threshold: usize,
    slots: HashMap<u64, SlotId>,
    sparse: SparsePool,
    dense: DenseArena,
    /// Reused two-pointer merge output buffer.
    scratch: Vec<(u16, u8)>,
    /// Reused per-vertex group buffer for [`SketchStore::insert_batch`].
    group: Vec<(u16, u8)>,
}

impl SketchStore {
    pub fn new(config: HllConfig) -> Self {
        let r = config.num_registers();
        let bins = config.kmax() as usize + 1;
        Self {
            config,
            threshold: config.saturation_threshold(),
            slots: HashMap::new(),
            sparse: SparsePool::default(),
            dense: DenseArena::new(r, bins),
            scratch: Vec::new(),
            group: Vec::new(),
        }
    }

    pub fn config(&self) -> &HllConfig {
        &self.config
    }

    /// Number of vertices holding a sketch.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Number of sketches that have saturated into the dense arena.
    pub fn dense_count(&self) -> usize {
        self.dense.count
    }

    /// INSERT(D[v], element): hash and max into the vertex's sketch.
    #[inline]
    pub fn insert_element(&mut self, v: u64, element: u64) {
        let w = self.config.hasher().hash_u64(element);
        self.insert_hashed(v, w);
    }

    #[inline]
    pub fn insert_hashed(&mut self, v: u64, w: u64) {
        let (j, rho) = self.config.split_hash(w);
        self.insert_register(v, j, rho);
    }

    pub fn insert_register(&mut self, v: u64, j: u32, x: u8) {
        debug_assert!((j as usize) < self.config.num_registers());
        debug_assert!(x <= self.config.kmax());
        if x == 0 {
            return;
        }
        match self.slot_or_new(v) {
            SlotId::Dense(d) => self.dense.insert(d as usize, j, x),
            SlotId::Sparse(s) => {
                if let Some(new_id) = self.sparse_insert(s, j as u16, x) {
                    self.slots.insert(v, new_id);
                }
            }
        }
    }

    /// Merge a sorted, strictly-increasing, deduplicated pair run into the
    /// vertex's sketch — one two-pointer pass instead of `len` binary
    /// searches and `Vec::insert` shifts.
    pub fn merge_pairs(&mut self, v: u64, pairs: &[(u16, u8)]) {
        if pairs.is_empty() {
            return;
        }
        // out-of-range values would index into the NEXT slot's histogram
        // region of the flat arena — catch misuse before it corrupts
        debug_assert!(pairs.iter().all(|&(j, x)| {
            (j as usize) < self.config.num_registers()
                && x >= 1
                && x <= self.config.kmax()
        }));
        match self.slot_or_new(v) {
            SlotId::Dense(d) => {
                for &(j, x) in pairs {
                    self.dense.insert(d as usize, j as u32, x);
                }
            }
            SlotId::Sparse(s) => {
                let meta = self.sparse.slots[s as usize];
                let cap = self.sparse.classes[meta.class as usize].cap;
                kernels::merge_sorted_pairs(
                    self.sparse.pairs_of(meta),
                    pairs,
                    &mut self.scratch,
                );
                let merged_len = self.scratch.len();
                if merged_len > self.threshold {
                    let d = self.dense.alloc();
                    self.dense.scatter(d, &self.scratch);
                    self.sparse.free_block(meta);
                    self.sparse.free_slot(s);
                    self.slots.insert(v, SlotId::Dense(d));
                } else if merged_len > cap {
                    let mut c = meta.class as usize + 1;
                    while (BASE_CAP << c) < merged_len {
                        c += 1;
                    }
                    let nb = self.sparse.alloc_block(c);
                    let ncap = self.sparse.classes[c].cap;
                    let nstart = nb as usize * ncap;
                    self.sparse.classes[c].pairs[nstart..nstart + merged_len]
                        .copy_from_slice(&self.scratch);
                    self.sparse.free_block(meta);
                    self.sparse.slots[s as usize] = SparseSlot {
                        class: c as u8,
                        block: nb,
                        len: merged_len as u16,
                    };
                } else {
                    let slab =
                        &mut self.sparse.classes[meta.class as usize];
                    let start = meta.block as usize * slab.cap;
                    slab.pairs[start..start + merged_len]
                        .copy_from_slice(&self.scratch);
                    self.sparse.slots[s as usize].len = merged_len as u16;
                }
            }
        }
    }

    /// Merge an owned sketch into the vertex's slot.
    pub fn merge_hll(&mut self, v: u64, other: &Hll) {
        assert_eq!(
            &self.config,
            other.config(),
            "cannot merge sketches with different (p, seed)"
        );
        self.merge_ref_parts(v, view_of(other));
    }

    /// Merge a borrowed view (possibly from another store) into `v`.
    pub fn merge_ref(&mut self, v: u64, other: SketchRef<'_>) {
        assert_eq!(
            self.config,
            other.config(),
            "cannot merge sketches with different (p, seed)"
        );
        self.merge_ref_parts(v, other);
    }

    fn merge_ref_parts(&mut self, v: u64, other: SketchRef<'_>) {
        match other {
            SketchRef::Sparse { pairs, .. } => self.merge_pairs(v, pairs),
            SketchRef::Dense { regs, .. } => self.merge_dense_slice(v, regs),
        }
    }

    /// Merge a raw dense register slice into `v`'s sketch — the
    /// histogram-free entry point for wire decodes (`comm::codec`'s
    /// `SketchView` payloads carry registers only; the arena maintains
    /// its own histograms), saving the owned-`Hll` round trip.
    pub(crate) fn merge_dense_regs(&mut self, v: u64, src: &[u8]) {
        debug_assert_eq!(src.len(), self.config.num_registers());
        self.merge_dense_slice(v, src);
    }

    fn merge_dense_slice(&mut self, v: u64, src: &[u8]) {
        let d = match self.slot_or_new(v) {
            SlotId::Dense(d) => d,
            SlotId::Sparse(s) => {
                let d = self.saturate_slot(s);
                self.slots.insert(v, SlotId::Dense(d));
                d
            }
        };
        self.dense.merge_dense(d as usize, src);
    }

    /// Batch-apply `(vertex, element)` insertions: sorts to group by
    /// vertex, pre-hashes and max-dedupes each group, then lands every
    /// group as a single sorted-run merge. Insertion order never matters
    /// (register max commutes), so the result is identical to applying
    /// the messages one by one. Drains `batch`.
    pub fn insert_batch(&mut self, batch: &mut Vec<(u64, u64)>) {
        batch.sort_unstable_by_key(|&(v, _)| v);
        let mut group = std::mem::take(&mut self.group);
        let mut i = 0;
        while i < batch.len() {
            let v = batch[i].0;
            group.clear();
            while i < batch.len() && batch[i].0 == v {
                let w = self.config.hasher().hash_u64(batch[i].1);
                let (j, rho) = self.config.split_hash(w);
                group.push((j as u16, rho));
                i += 1;
            }
            if group.len() == 1 {
                let (j, x) = group[0];
                self.insert_register(v, j as u32, x);
            } else {
                // sort by (index, value); keep the max value per index
                // (the last element of each equal-index run)
                group.sort_unstable();
                let mut w = 0;
                for k in 0..group.len() {
                    if k + 1 < group.len() && group[k + 1].0 == group[k].0 {
                        continue;
                    }
                    group[w] = group[k];
                    w += 1;
                }
                group.truncate(w);
                self.merge_pairs(v, &group);
            }
        }
        batch.clear();
        self.group = group;
    }

    /// Borrowed view of the vertex's sketch.
    pub fn get(&self, v: u64) -> Option<SketchRef<'_>> {
        match *self.slots.get(&v)? {
            SlotId::Sparse(s) => {
                let meta = self.sparse.slots[s as usize];
                Some(SketchRef::Sparse {
                    config: self.config,
                    pairs: self.sparse.pairs_of(meta),
                })
            }
            SlotId::Dense(d) => Some(SketchRef::Dense {
                config: self.config,
                regs: self.dense.regs_of(d),
                hist: self.dense.hist_of(d),
            }),
        }
    }

    /// Materialize the vertex's sketch as an owned [`Hll`].
    pub fn to_hll(&self, v: u64) -> Option<Hll> {
        Some(self.get(v)?.to_hll())
    }

    /// `|D[v]|` — degree estimate (None if the vertex was never seen).
    pub fn estimate_with(
        &self,
        v: u64,
        estimator: Estimator,
    ) -> Option<f64> {
        Some(self.get(v)?.estimate_with(estimator))
    }

    /// Iterate `(vertex, view)` in arbitrary (hash) order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, SketchRef<'_>)> + '_ {
        self.slots
            .keys()
            .map(move |&v| (v, self.get(v).expect("key present")))
    }

    /// All vertex ids, sorted (for deterministic REDUCEs and saves).
    pub fn vertices_sorted(&self) -> Vec<u64> {
        let mut keys: Vec<u64> = self.slots.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Consume the store into `(vertex, Hll)` pairs sorted by vertex id.
    pub fn into_sorted_hlls(self) -> Vec<(u64, Hll)> {
        let keys = self.vertices_sorted();
        keys.into_iter()
            .map(|v| (v, self.to_hll(v).expect("key present")))
            .collect()
    }

    /// Approximate heap footprint — the semi-streaming space accounting.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.slots.capacity()
                * (std::mem::size_of::<u64>()
                    + std::mem::size_of::<SlotId>())
            + self.sparse.memory_bytes()
            + self.dense.memory_bytes()
    }

    fn slot_or_new(&mut self, v: u64) -> SlotId {
        if let Some(&id) = self.slots.get(&v) {
            return id;
        }
        let id = SlotId::Sparse(self.sparse.alloc_slot());
        self.slots.insert(v, id);
        id
    }

    /// Insert into a sparse slot; returns the new slot id on saturation.
    fn sparse_insert(
        &mut self,
        s: u32,
        j: u16,
        x: u8,
    ) -> Option<SlotId> {
        let meta = self.sparse.slots[s as usize];
        let cap = self.sparse.classes[meta.class as usize].cap;
        let start = meta.block as usize * cap;
        let len = meta.len as usize;
        let search = self.sparse.classes[meta.class as usize].pairs
            [start..start + len]
            .binary_search_by_key(&j, |&(i, _)| i);
        match search {
            Ok(pos) => {
                let p = &mut self.sparse.classes[meta.class as usize]
                    .pairs[start + pos];
                if x > p.1 {
                    p.1 = x;
                }
                None
            }
            Err(pos) => {
                let new_len = len + 1;
                if new_len > self.threshold {
                    let d = self.saturate_slot(s);
                    self.dense.insert(d as usize, j as u32, x);
                    Some(SlotId::Dense(d))
                } else if new_len > cap {
                    self.grow_and_insert(s, pos, j, x);
                    None
                } else {
                    let slab =
                        &mut self.sparse.classes[meta.class as usize];
                    let abs = start + pos;
                    slab.pairs.copy_within(abs..start + len, abs + 1);
                    slab.pairs[abs] = (j, x);
                    self.sparse.slots[s as usize].len = new_len as u16;
                    None
                }
            }
        }
    }

    /// Promote a sparse slot into the dense arena; frees its block and
    /// slot, returns the dense index.
    fn saturate_slot(&mut self, s: u32) -> u32 {
        let meta = self.sparse.slots[s as usize];
        let d = self.dense.alloc();
        self.dense.scatter(d, self.sparse.pairs_of(meta));
        self.sparse.free_block(meta);
        self.sparse.free_slot(s);
        d
    }

    /// Move a full block to the next size class, inserting `(j, x)` at
    /// `pos` on the way.
    fn grow_and_insert(&mut self, s: u32, pos: usize, j: u16, x: u8) {
        let meta = self.sparse.slots[s as usize];
        let len = meta.len as usize;
        self.scratch.clear();
        {
            let old = self.sparse.pairs_of(meta);
            self.scratch.extend_from_slice(&old[..pos]);
            self.scratch.push((j, x));
            self.scratch.extend_from_slice(&old[pos..]);
        }
        let c = meta.class as usize + 1;
        let nb = self.sparse.alloc_block(c);
        let ncap = self.sparse.classes[c].cap;
        let nstart = nb as usize * ncap;
        self.sparse.classes[c].pairs[nstart..nstart + len + 1]
            .copy_from_slice(&self.scratch);
        self.sparse.free_block(meta);
        self.sparse.slots[s as usize] = SparseSlot {
            class: c as u8,
            block: nb,
            len: (len + 1) as u16,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn cfg(p: u8) -> HllConfig {
        HllConfig::new(p, 0x570E)
    }

    /// Reference model: the plain per-vertex `Hll` map the store replaces.
    fn reference_insert(
        map: &mut HashMap<u64, Hll>,
        config: HllConfig,
        v: u64,
        e: u64,
    ) {
        map.entry(v).or_insert_with(|| Hll::new(config)).insert(e);
    }

    fn assert_store_matches(
        store: &SketchStore,
        map: &HashMap<u64, Hll>,
    ) {
        assert_eq!(store.len(), map.len());
        for (&v, h) in map {
            let got = store.to_hll(v).expect("vertex present");
            // representation-equal, not just histogram-equal
            assert_eq!(&got, h, "vertex {v}");
        }
    }

    #[test]
    fn store_matches_hll_map_bit_for_bit() {
        Cases::new("store_parity", 15).run(|rng| {
            let c = cfg(6); // r = 64: lots of saturations
            let mut store = SketchStore::new(c);
            let mut map: HashMap<u64, Hll> = HashMap::new();
            for _ in 0..rng.next_below(6000) {
                let v = rng.next_below(40);
                let e = rng.next_below(2000);
                store.insert_element(v, e);
                reference_insert(&mut map, c, v, e);
            }
            assert_store_matches(&store, &map);
        });
    }

    #[test]
    fn batched_equals_incremental() {
        Cases::new("store_batch", 15).run(|rng| {
            let c = cfg(8);
            let mut batched = SketchStore::new(c);
            let mut incremental = SketchStore::new(c);
            let mut batch = Vec::new();
            for _ in 0..rng.next_below(8000) {
                let v = rng.next_below(60);
                let e = rng.next_u64();
                incremental.insert_element(v, e);
                batch.push((v, e));
                if batch.len() >= 100 && rng.next_below(4) == 0 {
                    batched.insert_batch(&mut batch);
                }
            }
            batched.insert_batch(&mut batch);
            assert_eq!(batched.len(), incremental.len());
            for v in incremental.vertices_sorted() {
                assert_eq!(
                    batched.to_hll(v),
                    incremental.to_hll(v),
                    "vertex {v}"
                );
            }
        });
    }

    #[test]
    fn saturation_boundary_matches_hll() {
        let c = cfg(6); // threshold = 16
        let mut store = SketchStore::new(c);
        let mut h = Hll::new(c);
        let mut e = 0u64;
        // drive a single vertex straight through the boundary
        while !h.is_dense() {
            store.insert_element(7, e);
            h.insert(e);
            e += 1;
        }
        let got = store.to_hll(7).unwrap();
        assert!(got.is_dense());
        assert_eq!(got, h);
        assert_eq!(store.dense_count(), 1);
        // keep inserting after saturation
        for e2 in e..e + 500 {
            store.insert_element(7, e2);
            h.insert(e2);
        }
        assert_eq!(store.to_hll(7).unwrap(), h);
    }

    #[test]
    fn merge_ref_across_stores_equals_hll_merge() {
        Cases::new("store_merge_ref", 10).run(|rng| {
            let c = cfg(7);
            let mut a = SketchStore::new(c);
            let mut b = SketchStore::new(c);
            let mut ha = Hll::new(c);
            let mut hb = Hll::new(c);
            for _ in 0..1 + rng.next_below(3000) {
                let e = rng.next_u64();
                a.insert_element(1, e);
                ha.insert(e);
            }
            for _ in 0..rng.next_below(3000) {
                let e = rng.next_u64();
                b.insert_element(2, e);
                hb.insert(e);
            }
            if let Some(view) = b.get(2) {
                a.merge_ref(1, view);
            }
            ha.merge(&hb);
            assert_eq!(a.to_hll(1).unwrap().histogram(), ha.histogram());
            // merging into an absent vertex materializes the source
            if let Some(view) = b.get(2) {
                a.merge_ref(99, view);
            }
            assert_eq!(
                a.to_hll(99).map(|h| h.histogram()),
                (!hb.is_empty()).then(|| hb.histogram())
            );
        });
    }

    #[test]
    fn estimates_match_hll_exactly() {
        let c = cfg(8);
        let mut store = SketchStore::new(c);
        let mut h = Hll::new(c);
        for e in 0..30_000u64 {
            store.insert_element(3, e * 2654435761);
            h.insert(e * 2654435761);
        }
        for est in [
            Estimator::Classic,
            Estimator::LogLogBeta,
            Estimator::ErtlImproved,
        ] {
            let a = store.estimate_with(3, est).unwrap();
            let b = h.estimate_with(est);
            assert_eq!(a.to_bits(), b.to_bits(), "{est:?}");
        }
        assert_eq!(store.estimate_with(999, Estimator::default()), None);
    }

    #[test]
    fn views_report_shape() {
        let c = cfg(10);
        let mut store = SketchStore::new(c);
        store.insert_element(5, 42);
        let view = store.get(5).unwrap();
        assert!(!view.is_dense());
        assert_eq!(view.nonzero_registers(), 1);
        assert!(store.get(6).is_none());
        assert_eq!(store.len(), 1);
        assert!(store.memory_bytes() > 0);
    }

    #[test]
    fn into_sorted_hlls_is_sorted_and_complete() {
        let c = cfg(9);
        let mut store = SketchStore::new(c);
        for v in [9u64, 2, 7, 100, 1] {
            store.insert_element(v, v * 31);
        }
        let all = store.into_sorted_hlls();
        let ids: Vec<u64> = all.iter().map(|&(v, _)| v).collect();
        assert_eq!(ids, vec![1, 2, 7, 9, 100]);
        for (_, h) in &all {
            assert_eq!(h.nonzero_registers(), 1);
        }
    }

    #[test]
    fn block_recycling_bounds_slab_growth() {
        // saturating many vertices should recycle their class-0 blocks
        let c = cfg(4); // r = 16, threshold 4: saturates at the 5th pair
        let mut store = SketchStore::new(c);
        for v in 0..50u64 {
            // deterministic saturation: fill every register directly
            for j in 0..16u32 {
                store.insert_register(v, j, 1);
            }
        }
        assert_eq!(store.dense_count(), 50);
        // all sparse blocks were freed back to their pools
        let free_total: usize =
            store.sparse.classes.iter().map(|s| s.free.len()).sum();
        let block_total: usize = store
            .sparse
            .classes
            .iter()
            .map(|s| s.pairs.len() / s.cap)
            .sum();
        assert_eq!(free_total, block_total);
    }
}
