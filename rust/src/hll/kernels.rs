//! Word-parallel (SWAR) register kernels for the dense HLL hot paths.
//!
//! Dense register arrays are plain `u8` slices whose values are bounded by
//! `kmax = 64 - p + 1 <= 61 < 128`; the high bit of every byte is
//! therefore always clear, which admits the classic borrow-free SWAR
//! comparison on eight registers per `u64` lane:
//!
//! ```text
//! t    = ((x | 0x80..80) - y) & 0x80..80   # bit7 set per lane iff x >= y
//! mask = (t >> 7) * 0xFF                   # expand to 0x00 / 0xFF per lane
//! max  = (x & mask) | (y & !mask)
//! ```
//!
//! On top of [`merge8`] this module provides the register-slice kernels the
//! sketch layer and the [`super::store::SketchStore`] arena use: bulk
//! byte-max merge (with or without incremental-histogram maintenance),
//! chunked histogram accumulation (4 interleaved count tables to dodge
//! store-forwarding stalls on repeated equal bytes), a fused
//! harmonic-sum + zero-count pass, and the two-pointer sorted-pair merge
//! shared by the sparse representations.

const HI: u64 = 0x8080_8080_8080_8080;

/// Byte-wise max of eight packed registers. Both operands must have every
/// byte `< 0x80` (always true for HLL registers, where `kmax <= 61`).
#[inline]
pub fn merge8(x: u64, y: u64) -> u64 {
    let t = ((x | HI).wrapping_sub(y)) & HI;
    let mask = (t >> 7).wrapping_mul(0xFF);
    (x & mask) | (y & !mask)
}

#[inline]
fn load8(s: &[u8]) -> u64 {
    u64::from_le_bytes(s.try_into().expect("8-byte chunk"))
}

/// `dst[i] = max(dst[i], src[i])`, eight registers per iteration.
pub fn merge_max(dst: &mut [u8], src: &[u8]) {
    debug_assert_eq!(dst.len(), src.len());
    let split = dst.len() / 8 * 8;
    let (dh, dt) = dst.split_at_mut(split);
    let (sh, st) = src.split_at(split);
    for (dc, sc) in dh.chunks_exact_mut(8).zip(sh.chunks_exact(8)) {
        let x = load8(dc);
        let y = load8(sc);
        let m = merge8(x, y);
        if m != x {
            dc.copy_from_slice(&m.to_le_bytes());
        }
    }
    for (a, &b) in dt.iter_mut().zip(st) {
        if b > *a {
            *a = b;
        }
    }
}

/// [`merge_max`] that also maintains an incremental register histogram:
/// for every register that grows from `a` to `b`, `hist[a] -= 1` and
/// `hist[b] += 1`. `hist` must cover `0..=kmax`.
pub fn merge_max_hist(dst: &mut [u8], src: &[u8], hist: &mut [u32]) {
    debug_assert_eq!(dst.len(), src.len());
    let split = dst.len() / 8 * 8;
    let (dh, dt) = dst.split_at_mut(split);
    let (sh, st) = src.split_at(split);
    for (dc, sc) in dh.chunks_exact_mut(8).zip(sh.chunks_exact(8)) {
        let x = load8(dc);
        let y = load8(sc);
        let m = merge8(x, y);
        if m != x {
            // touch the histogram only for lanes that actually changed
            let mut diff = m ^ x;
            while diff != 0 {
                let shift = diff.trailing_zeros() & !7;
                let old = ((x >> shift) & 0xFF) as usize;
                let new = ((m >> shift) & 0xFF) as usize;
                hist[old] -= 1;
                hist[new] += 1;
                diff &= !(0xFFu64 << shift);
            }
            dc.copy_from_slice(&m.to_le_bytes());
        }
    }
    for (a, &b) in dt.iter_mut().zip(st) {
        if b > *a {
            hist[*a as usize] -= 1;
            hist[b as usize] += 1;
            *a = b;
        }
    }
}

/// Register-value histogram of a dense array: `out[k] = #{i : regs[i] == k}`
/// with `out.len() == kmax + 1`. Accumulates into four interleaved count
/// tables so runs of equal register values don't serialize on one counter.
pub fn histogram(regs: &[u8], kmax: u8) -> Vec<u32> {
    let bins = kmax as usize + 1;
    let mut acc = vec![0u32; bins * 4];
    let mut chunks = regs.chunks_exact(4);
    for c in chunks.by_ref() {
        acc[c[0] as usize] += 1;
        acc[bins + c[1] as usize] += 1;
        acc[2 * bins + c[2] as usize] += 1;
        acc[3 * bins + c[3] as usize] += 1;
    }
    for &x in chunks.remainder() {
        acc[x as usize] += 1;
    }
    let mut out = vec![0u32; bins];
    for (k, o) in out.iter_mut().enumerate() {
        *o = acc[k] + acc[bins + k] + acc[2 * bins + k] + acc[3 * bins + k];
    }
    out
}

/// Fused single pass over dense registers: returns
/// `(Σ 2^-regs[i], #{i : regs[i] == 0})` — the sufficient statistics of the
/// classic estimator — using an exact bit-constructed `2^-k` lookup table
/// instead of per-register `exp2` calls.
pub fn fused_harmonic(regs: &[u8]) -> (f64, u32) {
    // 2^-k as IEEE-754 bits: exponent field (1023 - k), zero mantissa.
    // Built once per process, not per call.
    static TABLE: std::sync::OnceLock<[f64; 64]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0f64; 64];
        for (k, v) in t.iter_mut().enumerate() {
            *v = f64::from_bits((1023 - k as u64) << 52);
        }
        t
    });
    let mut sum = 0.0;
    let mut zeros = 0u32;
    for &x in regs {
        sum += table[x as usize];
        zeros += u32::from(x == 0);
    }
    (sum, zeros)
}

/// Two-pointer merge of two index-sorted `(register, value)` pair lists,
/// taking the max value on index ties. `out` is cleared first. Both inputs
/// must be strictly increasing in index.
pub fn merge_sorted_pairs(
    a: &[(u16, u8)],
    b: &[(u16, u8)],
    out: &mut Vec<(u16, u8)>,
) {
    out.clear();
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let (ia, xa) = a[i];
        let (ib, xb) = b[j];
        match ia.cmp(&ib) {
            std::cmp::Ordering::Less => {
                out.push((ia, xa));
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push((ib, xb));
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push((ia, xa.max(xb)));
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn scalar_max(dst: &mut [u8], src: &[u8]) {
        for (a, &b) in dst.iter_mut().zip(src) {
            if b > *a {
                *a = b;
            }
        }
    }

    fn scalar_hist(regs: &[u8], kmax: u8) -> Vec<u32> {
        let mut h = vec![0u32; kmax as usize + 1];
        for &x in regs {
            h[x as usize] += 1;
        }
        h
    }

    fn random_regs(rng: &mut crate::hash::Xoshiro256ss, n: usize, kmax: u8) -> Vec<u8> {
        (0..n)
            .map(|_| {
                if rng.next_below(3) == 0 {
                    0
                } else {
                    rng.next_below(kmax as u64 + 1) as u8
                }
            })
            .collect()
    }

    #[test]
    fn merge8_matches_scalar_exhaustive_lanes() {
        // every (a, b) pair in one lane, plus mixed neighbors
        for a in [0u8, 1, 2, 30, 56, 57, 60, 61] {
            for b in [0u8, 1, 2, 30, 56, 57, 60, 61] {
                let x = u64::from_le_bytes([a, b, 0, 61, a, a, b, 1]);
                let y = u64::from_le_bytes([b, a, 61, 0, a, b, b, 2]);
                let m = merge8(x, y).to_le_bytes();
                let xs = x.to_le_bytes();
                let ys = y.to_le_bytes();
                for i in 0..8 {
                    assert_eq!(m[i], xs[i].max(ys[i]), "lane {i}: {xs:?} {ys:?}");
                }
            }
        }
    }

    #[test]
    fn merge_max_matches_scalar() {
        Cases::new("swar_merge", 40).run(|rng| {
            let kmax = 61;
            // off-multiples-of-8 lengths exercise the remainder loop
            let n = 1 + rng.next_below(700) as usize;
            let a = random_regs(rng, n, kmax);
            let b = random_regs(rng, n, kmax);
            let mut swar = a.clone();
            merge_max(&mut swar, &b);
            let mut scalar = a;
            scalar_max(&mut scalar, &b);
            assert_eq!(swar, scalar);
        });
    }

    #[test]
    fn merge_max_hist_maintains_invariant() {
        Cases::new("swar_merge_hist", 40).run(|rng| {
            let kmax = 57u8; // p = 8
            let n = 256;
            let a = random_regs(rng, n, kmax);
            let b = random_regs(rng, n, kmax);
            let mut hist = scalar_hist(&a, kmax);
            let mut merged = a;
            merge_max_hist(&mut merged, &b, &mut hist);
            let mut scalar = merged.clone();
            scalar_max(&mut scalar, &b); // idempotent: merged is final
            assert_eq!(merged, scalar);
            assert_eq!(hist, scalar_hist(&merged, kmax));
        });
    }

    #[test]
    fn histogram_matches_scalar() {
        Cases::new("swar_hist", 30).run(|rng| {
            let kmax = 53u8; // p = 12
            let n = 1 + rng.next_below(5000) as usize;
            let regs = random_regs(rng, n, kmax);
            assert_eq!(histogram(&regs, kmax), scalar_hist(&regs, kmax));
        });
    }

    #[test]
    fn fused_harmonic_matches_reference() {
        Cases::new("swar_harmonic", 30).run(|rng| {
            let regs = random_regs(rng, 512, 61);
            let (sum, zeros) = fused_harmonic(&regs);
            let want_sum: f64 =
                regs.iter().map(|&x| (-(x as f64)).exp2()).sum();
            let want_zeros = regs.iter().filter(|&&x| x == 0).count() as u32;
            assert!((sum - want_sum).abs() < 1e-12 * want_sum.max(1.0));
            assert_eq!(zeros, want_zeros);
        });
    }

    #[test]
    fn pow2_table_is_exact() {
        let (sum, _) = fused_harmonic(&[0, 1, 2, 10, 61]);
        let want = 1.0 + 0.5 + 0.25 + (2f64).powi(-10) + (2f64).powi(-61);
        assert_eq!(sum, want);
    }

    #[test]
    fn merge_sorted_pairs_matches_map_union() {
        Cases::new("pair_merge", 30).run(|rng| {
            use std::collections::BTreeMap;
            let gen = |rng: &mut crate::hash::Xoshiro256ss| {
                let mut m = BTreeMap::new();
                for _ in 0..rng.next_below(60) {
                    m.insert(
                        rng.next_below(300) as u16,
                        1 + rng.next_below(50) as u8,
                    );
                }
                m
            };
            let ma = gen(rng);
            let mb = gen(rng);
            let a: Vec<(u16, u8)> = ma.iter().map(|(&i, &x)| (i, x)).collect();
            let b: Vec<(u16, u8)> = mb.iter().map(|(&i, &x)| (i, x)).collect();
            let mut got = Vec::new();
            merge_sorted_pairs(&a, &b, &mut got);
            let mut want = ma;
            for (i, x) in mb {
                let e = want.entry(i).or_insert(0);
                *e = (*e).max(x);
            }
            let want: Vec<(u16, u8)> = want.into_iter().collect();
            assert_eq!(got, want);
        });
    }
}
