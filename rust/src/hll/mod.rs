//! HyperLogLog cardinality sketches (paper §4, Algorithm 6).
//!
//! An `HLL(p, q, h)` sketch has `r = 2^p` registers holding values in
//! `[0, q + 1]` where `q = 64 - p`. For a hashed 64-bit word `w`,
//! `ξ(w)` (the top `p` bits) selects a register and `ρ(w)` (number of
//! leading zeros of the remaining `q` bits, plus one) is max-ed into it.
//!
//! Two representations, as in Heule et al. 2013 / paper §4:
//! * **sparse** — a sorted list of `(index, value)` pairs for small
//!   cardinalities (most graph vertices have small degree);
//! * **dense** — a flat `r`-byte register array, saturated to from sparse
//!   once the pair list exceeds `r / 4` entries. Dense storage carries an
//!   **incrementally maintained register histogram** so `estimate()` is
//!   `O(kmax)` instead of an `O(r)` register scan, and dense merges run
//!   through the word-parallel [`kernels`].
//!
//! Merging takes element-wise register maxima and requires both sketches to
//! share `(p, hash seed)` — enforced at the type level by [`HllConfig`].
//!
//! For bulk, per-rank storage of many sketches (one per vertex) see
//! [`store::SketchStore`], which keeps registers in contiguous arenas and
//! shares one `HllConfig` across the shard.

mod beta;
mod estimate;
mod intersect;
pub mod kernels;
mod serde;
pub mod store;

pub use beta::{
    beta_correction, eval_beta, fit_beta, BetaCoefficients, BETA_TABLE,
};
pub use estimate::{
    alpha, ertl_estimate_from_hist, estimate_from_hist, Estimator,
};
pub use intersect::{
    domination, grad_log_likelihood, inclusion_exclusion,
    inclusion_exclusion_ref, log_likelihood, mle_from_stats, mle_intersect,
    mle_intersect_ref, pair_stats, pair_stats_ref, Domination,
    IntersectionEstimate, MleOptions,
    PairStats,
};
pub use store::{view_of, SketchRef, SketchStore};

use crate::hash::XxHash64;

/// Shared sketch parameters: all sketches in a DegreeSketch instance are
/// `HLL(p, q, h)` with `p + q = 64` and a fixed hash seed (paper §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HllConfig {
    p: u8,
    hasher: XxHash64,
}

impl HllConfig {
    /// Create a config with prefix size `p` (typically 4..=16) and a hash
    /// seed shared by every processor.
    pub fn new(p: u8, seed: u64) -> Self {
        assert!((4..=16).contains(&p), "p must be in 4..=16, got {p}");
        Self {
            p,
            hasher: XxHash64::new(seed),
        }
    }

    #[inline]
    pub fn p(&self) -> u8 {
        self.p
    }

    /// q = 64 - p: the number of suffix bits scanned for leading zeros.
    #[inline]
    pub fn q(&self) -> u8 {
        64 - self.p
    }

    /// r = 2^p: the register count.
    #[inline]
    pub fn num_registers(&self) -> usize {
        1usize << self.p
    }

    /// Maximum register value `kmax = q + 1` (the saturation value).
    #[inline]
    pub fn kmax(&self) -> u8 {
        self.q() + 1
    }

    #[inline]
    pub fn hasher(&self) -> &XxHash64 {
        &self.hasher
    }

    /// Sparse→dense saturation threshold (paper Alg. 6: `|R| > r / 4`).
    #[inline]
    pub(crate) fn saturation_threshold(&self) -> usize {
        self.num_registers() / 4
    }

    /// Decompose a hashed word into `(register index, ρ)`.
    #[inline]
    pub fn split_hash(&self, w: u64) -> (u32, u8) {
        let q = self.q() as u32;
        let j = (w >> q) as u32; // top p bits
        let rest = w << self.p; // remaining q bits, left-aligned
        let rho = if rest == 0 {
            q + 1
        } else {
            (rest.leading_zeros() + 1).min(q + 1)
        };
        (j, rho as u8)
    }
}

/// Register histogram of a sorted sparse pair list (the single source of
/// the `hist[0] = r - len` zero-register accounting, shared by [`Hll`]
/// and borrowed [`SketchRef`] views so their estimates stay bit-equal).
pub(crate) fn sparse_histogram(
    config: &HllConfig,
    pairs: &[(u16, u8)],
) -> Vec<u32> {
    let mut hist = vec![0u32; config.kmax() as usize + 1];
    hist[0] = (config.num_registers() - pairs.len()) as u32;
    for &(_, x) in pairs {
        hist[x as usize] += 1;
    }
    hist
}

/// Register storage: sparse pair list or dense byte array. Dense storage
/// additionally carries `hist[k] = #{j : reg_j == k}` (length `kmax + 1`),
/// kept in sync by every insert/merge so estimators never rescan `r`
/// registers.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Registers {
    /// Sorted by index; indices fit in u16 because p <= 16.
    Sparse(Vec<(u16, u8)>),
    Dense { regs: Vec<u8>, hist: Vec<u32> },
}

/// A single HyperLogLog sketch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hll {
    config: HllConfig,
    regs: Registers,
}

impl Hll {
    /// Fresh empty sketch (sparse mode).
    pub fn new(config: HllConfig) -> Self {
        Self {
            config,
            regs: Registers::Sparse(Vec::new()),
        }
    }

    /// Construct directly from dense parts (used by the arena store when
    /// materializing a sketch). `hist` must be the histogram of `regs`.
    pub(crate) fn from_dense_parts(
        config: HllConfig,
        regs: Vec<u8>,
        hist: Vec<u32>,
    ) -> Self {
        debug_assert_eq!(regs.len(), config.num_registers());
        debug_assert_eq!(hist.len(), config.kmax() as usize + 1);
        Self {
            config,
            regs: Registers::Dense { regs, hist },
        }
    }

    /// Construct directly from a sorted sparse pair list (used by the
    /// arena store when materializing a sketch).
    pub(crate) fn from_sparse_parts(
        config: HllConfig,
        pairs: Vec<(u16, u8)>,
    ) -> Self {
        Self {
            config,
            regs: Registers::Sparse(pairs),
        }
    }

    /// Borrow the sorted sparse pair list if not yet saturated.
    pub(crate) fn sparse_pairs(&self) -> Option<&[(u16, u8)]> {
        match &self.regs {
            Registers::Sparse(v) => Some(v),
            Registers::Dense { .. } => None,
        }
    }

    /// Borrow the incrementally maintained histogram if dense.
    pub(crate) fn dense_hist(&self) -> Option<&[u32]> {
        match &self.regs {
            Registers::Dense { hist, .. } => Some(hist),
            Registers::Sparse(_) => None,
        }
    }

    #[inline]
    pub fn config(&self) -> &HllConfig {
        &self.config
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.regs, Registers::Dense { .. })
    }

    pub fn is_empty(&self) -> bool {
        match &self.regs {
            Registers::Sparse(v) => v.is_empty(),
            Registers::Dense { hist, .. } => {
                hist[0] as usize == self.config.num_registers()
            }
        }
    }

    /// INSERT(S, e): hash a vertex id and max it into its register.
    #[inline]
    pub fn insert(&mut self, element: u64) {
        let w = self.config.hasher.hash_u64(element);
        self.insert_hashed(w);
    }

    /// Insert a pre-hashed 64-bit word.
    #[inline]
    pub fn insert_hashed(&mut self, w: u64) {
        let (j, rho) = self.config.split_hash(w);
        self.insert_register(j, rho);
    }

    /// INSERT(S, j, x): max `x` into register `j`.
    pub fn insert_register(&mut self, j: u32, x: u8) {
        debug_assert!((j as usize) < self.config.num_registers());
        debug_assert!(x <= self.config.kmax());
        if x == 0 {
            return;
        }
        match &mut self.regs {
            Registers::Dense { regs, hist } => {
                let slot = &mut regs[j as usize];
                if x > *slot {
                    hist[*slot as usize] -= 1;
                    hist[x as usize] += 1;
                    *slot = x;
                }
            }
            Registers::Sparse(v) => {
                match v.binary_search_by_key(&(j as u16), |&(i, _)| i) {
                    Ok(pos) => {
                        if x > v[pos].1 {
                            v[pos].1 = x;
                        }
                    }
                    Err(pos) => {
                        v.insert(pos, (j as u16, x));
                        if v.len() > self.config.saturation_threshold() {
                            self.saturate();
                        }
                    }
                }
            }
        }
    }

    /// SATURATE(S): promote sparse storage to a dense register array
    /// (and build its histogram).
    pub fn saturate(&mut self) {
        if let Registers::Sparse(v) = &self.regs {
            let r = self.config.num_registers();
            let mut regs = vec![0u8; r];
            let mut hist = vec![0u32; self.config.kmax() as usize + 1];
            hist[0] = (r - v.len()) as u32;
            for &(j, x) in v {
                regs[j as usize] = x;
                hist[x as usize] += 1;
            }
            self.regs = Registers::Dense { regs, hist };
        }
    }

    /// MERGE: element-wise register max. Panics if configs differ (sketches
    /// hashed with different `(p, seed)` are not comparable — paper §4).
    ///
    /// Dense×dense runs the SWAR byte-max kernel (8 registers per step);
    /// sparse×sparse is a linear two-pointer merge of the sorted pair
    /// lists, saturating at most once afterwards.
    pub fn merge(&mut self, other: &Hll) {
        self.merge_view(store::view_of(other));
    }

    /// MERGE from a borrowed register view — the single implementation
    /// behind [`Hll::merge`], also fed directly by arena stores and
    /// mapped snapshots so every path lands identical registers.
    pub fn merge_view(&mut self, other: store::SketchRef<'_>) {
        assert_eq!(
            self.config,
            other.config(),
            "cannot merge sketches with different (p, seed)"
        );
        match other {
            store::SketchRef::Sparse { pairs: ov, .. } => {
                let needs_saturate = match &mut self.regs {
                    Registers::Sparse(sv) => {
                        let mut merged =
                            Vec::with_capacity(sv.len() + ov.len());
                        kernels::merge_sorted_pairs(sv, ov, &mut merged);
                        *sv = merged;
                        sv.len() > self.config.saturation_threshold()
                    }
                    Registers::Dense { regs, hist } => {
                        for &(j, x) in ov {
                            let slot = &mut regs[j as usize];
                            if x > *slot {
                                hist[*slot as usize] -= 1;
                                hist[x as usize] += 1;
                                *slot = x;
                            }
                        }
                        false
                    }
                };
                if needs_saturate {
                    self.saturate();
                }
            }
            store::SketchRef::Dense { regs: oregs, .. } => {
                self.saturate();
                if let Registers::Dense { regs, hist } = &mut self.regs {
                    kernels::merge_max_hist(regs, oregs, hist);
                }
            }
        }
    }

    /// Register value at index `j`.
    #[inline]
    pub fn register(&self, j: u32) -> u8 {
        match &self.regs {
            Registers::Dense { regs, .. } => regs[j as usize],
            Registers::Sparse(v) => v
                .binary_search_by_key(&(j as u16), |&(i, _)| i)
                .map(|pos| v[pos].1)
                .unwrap_or(0),
        }
    }

    /// Number of nonzero registers currently stored.
    pub fn nonzero_registers(&self) -> usize {
        match &self.regs {
            Registers::Sparse(v) => v.len(),
            Registers::Dense { hist, .. } => {
                self.config.num_registers() - hist[0] as usize
            }
        }
    }

    /// Dense copy of the register array (allocates for sparse sketches).
    pub fn to_dense_registers(&self) -> Vec<u8> {
        match &self.regs {
            Registers::Dense { regs, .. } => regs.clone(),
            Registers::Sparse(v) => {
                let mut dense = vec![0u8; self.config.num_registers()];
                for &(j, x) in v {
                    dense[j as usize] = x;
                }
                dense
            }
        }
    }

    /// Borrow the dense register slice if already saturated.
    pub fn dense_registers(&self) -> Option<&[u8]> {
        match &self.regs {
            Registers::Dense { regs, .. } => Some(regs),
            Registers::Sparse(_) => None,
        }
    }

    /// Iterate `(index, value)` over nonzero registers without allocating.
    pub fn iter_nonzero(&self) -> impl Iterator<Item = (u32, u8)> + '_ {
        let (sparse, dense): (Option<&[(u16, u8)]>, Option<&[u8]>) =
            match &self.regs {
                Registers::Sparse(v) => (Some(v.as_slice()), None),
                Registers::Dense { regs, .. } => (None, Some(regs.as_slice())),
            };
        sparse
            .into_iter()
            .flatten()
            .map(|&(j, x)| (j as u32, x))
            .chain(
                dense
                    .into_iter()
                    .flatten()
                    .enumerate()
                    .filter(|&(_, &x)| x != 0)
                    .map(|(j, &x)| (j as u32, x)),
            )
    }

    /// Histogram of register values: `hist[k] = #{j : reg_j == k}`,
    /// length `kmax + 1`. The sufficient statistic for all estimators.
    /// For dense sketches this is a copy of the incrementally maintained
    /// histogram; use [`Hll::with_histogram`] to avoid the allocation.
    pub fn histogram(&self) -> Vec<u32> {
        match &self.regs {
            Registers::Dense { hist, .. } => hist.clone(),
            Registers::Sparse(v) => sparse_histogram(&self.config, v),
        }
    }

    /// Run `f` on the register histogram without copying it when dense
    /// (the `O(kmax)` estimate path).
    pub fn with_histogram<T>(&self, f: impl FnOnce(&[u32]) -> T) -> T {
        match &self.regs {
            Registers::Dense { hist, .. } => f(hist),
            Registers::Sparse(_) => f(&self.histogram()),
        }
    }

    /// `|S|` — cardinality estimate with the library-default estimator
    /// (Ertl's improved estimator; see [`Estimator`] for alternatives).
    pub fn estimate(&self) -> f64 {
        self.estimate_with(Estimator::ErtlImproved)
    }

    /// Cardinality estimate with an explicit estimator.
    pub fn estimate_with(&self, estimator: Estimator) -> f64 {
        estimate::estimate(self, estimator)
    }

    /// Approximate heap footprint in bytes (for the semi-streaming space
    /// accounting reported by the benches). Sparse pairs are accounted at
    /// their in-memory `size_of::<(u16, u8)>()` (4 after alignment), not
    /// their 3 packed bytes.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + match &self.regs {
                Registers::Sparse(v) => {
                    v.capacity() * std::mem::size_of::<(u16, u8)>()
                }
                Registers::Dense { regs, hist } => {
                    regs.capacity()
                        + hist.capacity() * std::mem::size_of::<u32>()
                }
            }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn cfg(p: u8) -> HllConfig {
        HllConfig::new(p, 0xD5EE_5EED)
    }

    #[test]
    fn split_hash_bounds() {
        let c = cfg(8);
        for w in [0u64, 1, u64::MAX, 0x8000_0000_0000_0000, 0x00FF] {
            let (j, rho) = c.split_hash(w);
            assert!((j as usize) < c.num_registers());
            assert!(rho >= 1 && rho <= c.kmax());
        }
        // all-zero suffix saturates
        let (_, rho) = c.split_hash(0xFF00_0000_0000_0000 & !0u64 << 56);
        assert_eq!(rho, c.kmax());
    }

    #[test]
    fn empty_sketch_estimates_zero() {
        let s = Hll::new(cfg(8));
        assert!(s.is_empty());
        assert!(s.estimate() < 1e-9);
    }

    #[test]
    fn insert_is_idempotent() {
        let mut a = Hll::new(cfg(8));
        let mut b = Hll::new(cfg(8));
        for x in 0..100u64 {
            a.insert(x);
            b.insert(x);
            b.insert(x);
            b.insert(x);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn saturation_threshold_promotes() {
        let c = cfg(6); // r = 64, threshold 16
        let mut s = Hll::new(c);
        let mut x = 0u64;
        while !s.is_dense() {
            s.insert(x);
            x += 1;
            assert!(x < 10_000, "never saturated");
        }
        assert!(s.nonzero_registers() > c.saturation_threshold());
    }

    #[test]
    fn sparse_and_dense_agree() {
        let mut sparse = Hll::new(cfg(10));
        let mut dense = Hll::new(cfg(10));
        dense.saturate();
        for x in 0..200u64 {
            sparse.insert(x * 7919);
            dense.insert(x * 7919);
        }
        assert_eq!(sparse.histogram(), dense.histogram());
        assert_eq!(sparse.to_dense_registers(), dense.to_dense_registers());
        assert!((sparse.estimate() - dense.estimate()).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_union_insert() {
        Cases::new("merge_union", 30).run(|rng| {
            let c = cfg(7);
            let na = rng.next_below(3000) as u64;
            let nb = rng.next_below(3000) as u64;
            let mut a = Hll::new(c);
            let mut b = Hll::new(c);
            let mut u = Hll::new(c);
            for _ in 0..na {
                let e = rng.next_u64();
                a.insert(e);
                u.insert(e);
            }
            for _ in 0..nb {
                let e = rng.next_u64();
                b.insert(e);
                u.insert(e);
            }
            a.merge(&b);
            assert_eq!(a.histogram(), u.histogram());
        });
    }

    #[test]
    fn merge_is_commutative_and_idempotent() {
        Cases::new("merge_comm", 20).run(|rng| {
            let c = cfg(6);
            let mut a = Hll::new(c);
            let mut b = Hll::new(c);
            for _ in 0..rng.next_below(500) {
                a.insert(rng.next_u64());
            }
            for _ in 0..rng.next_below(500) {
                b.insert(rng.next_u64());
            }
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab.histogram(), ba.histogram());
            let mut abb = ab.clone();
            abb.merge(&b);
            assert_eq!(ab.histogram(), abb.histogram());
        });
    }

    #[test]
    fn histogram_sums_to_r() {
        let c = cfg(9);
        let mut s = Hll::new(c);
        for x in 0..5000u64 {
            s.insert(x);
        }
        let hist = s.histogram();
        assert_eq!(
            hist.iter().map(|&x| x as usize).sum::<usize>(),
            c.num_registers()
        );
    }

    #[test]
    fn incremental_histogram_tracks_registers() {
        // the dense histogram must stay identical to a recount from the
        // register array across inserts and all merge kinds
        Cases::new("hist_invariant", 20).run(|rng| {
            let c = cfg(7);
            let mut s = Hll::new(c);
            for _ in 0..rng.next_below(4000) {
                s.insert(rng.next_u64());
                if rng.next_below(10) == 0 {
                    let mut other = Hll::new(c);
                    for _ in 0..rng.next_below(600) {
                        other.insert(rng.next_u64());
                    }
                    s.merge(&other);
                }
            }
            let recount = kernels::histogram(
                &s.to_dense_registers(),
                c.kmax(),
            );
            assert_eq!(s.histogram(), recount);
        });
    }

    #[test]
    fn sparse_merge_stays_sorted_and_deduped() {
        let c = cfg(12); // big threshold: stays sparse
        let mut a = Hll::new(c);
        let mut b = Hll::new(c);
        for x in 0..40u64 {
            a.insert(x * 3);
            b.insert(x * 5);
        }
        a.merge(&b);
        assert!(!a.is_dense());
        let pairs = a.sparse_pairs().unwrap();
        for w in pairs.windows(2) {
            assert!(w[0].0 < w[1].0, "not strictly sorted: {pairs:?}");
        }
    }

    #[test]
    #[should_panic(expected = "cannot merge")]
    fn merge_mismatched_configs_panics() {
        let mut a = Hll::new(cfg(8));
        let b = Hll::new(cfg(9));
        a.merge(&b);
    }

    #[test]
    fn estimate_within_error_bound() {
        // 1.04/sqrt(r) standard error; allow 5 sigma over a few trials.
        Cases::new("est_bound", 20).run(|rng| {
            let c = cfg(8);
            let n = 1 + rng.next_below(50_000);
            let mut s = Hll::new(c);
            for _ in 0..n {
                s.insert(rng.next_u64());
            }
            let est = s.estimate();
            let se = 1.04 / (c.num_registers() as f64).sqrt();
            let tol = (5.0 * se * n as f64).max(3.0);
            assert!(
                (est - n as f64).abs() <= tol,
                "n={n} est={est} tol={tol}"
            );
        });
    }

    #[test]
    fn memory_accounting_uses_padded_pair_size() {
        // (u16, u8) occupies 4 bytes after alignment; the old `cap * 3`
        // accounting under-reported the semi-streaming space
        let mut s = Hll::new(cfg(12));
        for x in 0..100u64 {
            s.insert(x);
        }
        assert!(!s.is_dense());
        let pairs = s.sparse_pairs().unwrap();
        let cap_bytes = s.memory_bytes() - std::mem::size_of::<Hll>();
        assert_eq!(cap_bytes % 4, 0);
        assert!(cap_bytes >= pairs.len() * 4);

        s.saturate();
        let dense_bytes = s.memory_bytes() - std::mem::size_of::<Hll>();
        // registers + histogram
        assert!(dense_bytes >= 4096 + (s.config().kmax() as usize + 1) * 4);
    }
}
