//! LogLogBeta β(r, z) bias correction (Qin et al. 2016; paper Eq. 17).
//!
//! β is a 7th-degree polynomial in `zl = ln(z + 1)` (plus a linear `z`
//! term) whose weights are fitted experimentally by least squares, exactly
//! as §II.C of the LogLogBeta paper and the paper's §4 describe. The
//! shipped [`BETA_TABLE`] holds coefficients for the `p` values used by the
//! experiments, produced by [`fit_beta`] via the `degreesketch
//! calibrate-beta` subcommand (see EXPERIMENTS.md §Calibration); for other
//! `p` we fall back to the widely used m = 2^14 coefficient set from the
//! LogLogBeta paper.

use crate::hash::Xoshiro256ss;

use super::estimate::alpha;
use super::{Hll, HllConfig};

/// Coefficients for β(r, z) = c0·z + Σ_{i=1..7} c_i · ln(z+1)^i.
pub type BetaCoefficients = [f64; 8];

/// The m = 2^14 coefficients published in Qin et al. 2016 — the generic
/// fallback when no fitted entry exists for a given p.
pub const BETA_P14_PUBLISHED: BetaCoefficients = [
    -0.370393911,
    0.070471823,
    0.17393686,
    0.16339839,
    -0.09237745,
    0.03738027,
    -0.005384159,
    0.00042419,
];

/// Per-p fitted coefficients (`(p, coefficients)`), generated with
/// `degreesketch calibrate-beta`. Entries produced in this repository's
/// calibration run; see EXPERIMENTS.md §Calibration.
pub static BETA_TABLE: &[(u8, BetaCoefficients)] = &[
    (4, [3.581640264, 2.005361018, -18.413213625, 23.793264718, -18.370210807, 7.290935137, -1.435534385, 0.101802449]),
    (5, [127.136965589, -121.924909221, -82.571314958, 11.602882286, -31.986566720, 9.949333007, -2.292328500, 0.103955982]),
    (6, [55.349942095, -48.806846831, -41.886374943, 2.511776286, -4.174312703, -2.001299599, 0.644211962, -0.106428747]),
    (7, [-12.299911172, 14.556264519, 5.195603537, 1.250959494, 2.049902872, -0.453535376, 0.074988943, 0.006669360]),
    (8, [5.742229161, 2.452681334, -14.635993908, 5.986776996, -1.321132012, -0.336479677, 0.122145474, -0.014903268]),
    (9, [-1.735820947, 9.214533206, -13.425023715, 12.475311569, -5.059832508, 1.297172638, -0.174001665, 0.011837164]),
    (10, [0.318745506, 2.082782136, 1.963790596, -4.275641263, 2.444220780, -0.551988762, 0.055393657, -0.001594857]),
    (11, [0.820992132, 4.192246961, -6.240209312, 3.771918812, -0.961784934, 0.083300384, 0.004538386, -0.000889827]),
    (12, [-1.840330777, -25.741942217, 34.817510685, -1.062859544, -8.726788243, 3.649201020, -0.558311159, 0.032768518]),
    (13, [0.601617666, -8.889072510, 17.518333578, -10.415488830, 2.715389041, -0.311838441, 0.013192079, 0.000170989]),
    (14, [0.592797267, 4.128930414, -11.728886292, 9.074836392, -2.929852965, 0.495874221, -0.043436570, 0.001782752]),
    (15, [0.671072085, -8.899746937, 9.504358428, -7.547509985, 3.244504478, -0.657178793, 0.062400766, -0.002109866]),
    (16, [0.647516877, 4.092836996, -4.632061297, -0.755003812, 1.341550873, -0.316464388, 0.027671163, -0.000595391]),
];

/// Look up (or fall back for) the β polynomial and evaluate it at `z`.
pub fn beta_correction(p: u8, z: f64) -> f64 {
    let coeffs = BETA_TABLE
        .iter()
        .find(|&&(tp, _)| tp == p)
        .map(|&(_, c)| c)
        .unwrap_or(BETA_P14_PUBLISHED);
    eval_beta(&coeffs, z)
}

/// Evaluate a β polynomial at `z` registers-equal-to-zero.
pub fn eval_beta(coeffs: &BetaCoefficients, z: f64) -> f64 {
    let zl = (z + 1.0).ln();
    let mut acc = coeffs[0] * z;
    let mut pow = 1.0;
    for &c in &coeffs[1..] {
        pow *= zl;
        acc += c * pow;
    }
    acc
}

/// Fit β(r, z) for prefix size `p` by simulation + least squares
/// (Qin et al. §II.C): for a sweep of true cardinalities, accumulate
/// sketches, record `(z, hsum)` and solve for the β value that would make
/// Eq. 17 exact; then least-squares fit the polynomial basis
/// `[z, zl, zl², …, zl⁷]`.
///
/// `trials_per_n` sketches are simulated for each of `points`
/// log-spaced cardinalities in `[1, max_n]`.
pub fn fit_beta(
    p: u8,
    points: usize,
    trials_per_n: usize,
    max_n: u64,
    seed: u64,
) -> BetaCoefficients {
    let r = 1usize << p;
    let a = alpha(r);
    let mut rows: Vec<[f64; 8]> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let mut rng = Xoshiro256ss::new(seed);

    // Two sampling regimes: log-spaced cardinalities across [1, max_n],
    // plus a sweep that targets the small-z tail (z ≈ r·e^{-n/r}) where an
    // unconstrained polynomial otherwise extrapolates wildly for large p.
    let mut ns: Vec<u64> = Vec::new();
    for i in 0..points {
        let frac = i as f64 / (points - 1).max(1) as f64;
        ns.push(((max_n as f64).powf(frac)).round().max(1.0) as u64);
    }
    let z_targets = points / 2;
    for i in 0..z_targets {
        let frac = i as f64 / (z_targets - 1).max(1) as f64;
        // z from 1 up to r/4, log-spaced; n = r·ln(r/z)
        let z = (r as f64 / 4.0).powf(frac).max(1.0);
        ns.push((r as f64 * (r as f64 / z).ln()).round().max(1.0) as u64);
    }

    for &n in &ns {
        for _ in 0..trials_per_n {
            let mut s = Hll::new(HllConfig::new(p, rng.next_u64()));
            for _ in 0..n {
                s.insert(rng.next_u64());
            }
            let hist = s.histogram();
            let z = hist[0] as f64;
            if z == r as f64 {
                continue;
            }
            let hsum: f64 = hist
                .iter()
                .enumerate()
                .skip(1)
                .map(|(k, &c)| c as f64 * (-(k as f64)).exp2())
                .sum();
            // Eq. 17 solved for β:
            let beta_needed =
                a * r as f64 * (r as f64 - z) / n as f64 - hsum;
            let zl = (z + 1.0).ln();
            let mut row = [0.0f64; 8];
            row[0] = z;
            let mut pow = 1.0;
            for j in 1..8 {
                pow *= zl;
                row[j] = pow;
            }
            rows.push(row);
            ys.push(beta_needed);
        }
    }
    least_squares(&rows, &ys)
}

/// Solve min ‖Xw - y‖² via the normal equations (8×8 Gaussian elimination
/// with partial pivoting — tiny system, no external linalg needed).
fn least_squares(rows: &[[f64; 8]], ys: &[f64]) -> BetaCoefficients {
    let mut xtx = [[0.0f64; 8]; 8];
    let mut xty = [0.0f64; 8];
    for (row, &y) in rows.iter().zip(ys) {
        for i in 0..8 {
            xty[i] += row[i] * y;
            for j in 0..8 {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    // Equilibrate columns (scale to unit diagonal) so the collinear zl^i
    // basis is well conditioned, then apply a tiny relative ridge.
    let mut scale = [1.0f64; 8];
    for i in 0..8 {
        if xtx[i][i] > 0.0 {
            scale[i] = xtx[i][i].sqrt();
        }
    }
    for i in 0..8 {
        xty[i] /= scale[i];
        for j in 0..8 {
            xtx[i][j] /= scale[i] * scale[j];
        }
    }
    for (i, row) in xtx.iter_mut().enumerate() {
        row[i] += 1e-10;
    }
    let w = gaussian_solve(xtx, xty);
    std::array::from_fn(|i| w[i] / scale[i])
}

fn gaussian_solve(mut a: [[f64; 8]; 8], mut b: [f64; 8]) -> [f64; 8] {
    for col in 0..8 {
        // partial pivot
        let mut pivot = col;
        for row in col + 1..8 {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        let diag = a[col][col];
        assert!(diag.abs() > 1e-30, "singular normal equations");
        for row in col + 1..8 {
            let f = a[row][col] / diag;
            if f == 0.0 {
                continue;
            }
            for k in col..8 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 8];
    for col in (0..8).rev() {
        let mut acc = b[col];
        for k in col + 1..8 {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_beta_zero_registers() {
        // z = 0 ⇒ every term vanishes.
        assert_eq!(eval_beta(&BETA_P14_PUBLISHED, 0.0), 0.0);
    }

    #[test]
    fn gaussian_solve_identity() {
        let mut a = [[0.0; 8]; 8];
        for (i, row) in a.iter_mut().enumerate() {
            row[i] = 2.0;
        }
        let b = [2.0; 8];
        let x = gaussian_solve(a, b);
        for xi in x {
            assert!((xi - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn least_squares_recovers_planted_weights() {
        // y = 3·z - 2·zl + 0.5·zl³ exactly; fit must recover it.
        let mut rng = Xoshiro256ss::new(5);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..200 {
            let z = rng.next_below(1000) as f64;
            let zl = (z + 1.0).ln();
            let mut row = [0.0f64; 8];
            row[0] = z;
            let mut pow = 1.0;
            for j in 1..8 {
                pow *= zl;
                row[j] = pow;
            }
            rows.push(row);
            ys.push(3.0 * z - 2.0 * zl + 0.5 * zl * zl * zl);
        }
        let w = least_squares(&rows, &ys);
        // the zl^i basis is collinear, so check *predictions*, not weights
        for _ in 0..50 {
            let z = rng.next_below(1000) as f64;
            let zl = (z + 1.0).ln();
            let truth = 3.0 * z - 2.0 * zl + 0.5 * zl * zl * zl;
            let pred = eval_beta(&w, z);
            assert!(
                (pred - truth).abs() < 1e-3 * (1.0 + truth.abs()),
                "z={z} pred={pred} truth={truth} w={w:?}"
            );
        }
    }

    #[test]
    #[ignore] // slow calibration smoke test; run with --ignored
    fn fit_beta_improves_small_range() {
        let p = 8;
        let coeffs = fit_beta(p, 24, 8, 100_000, 99);
        // fitted β must keep mid/small-range error within a few std errs
        let mut rng = Xoshiro256ss::new(123);
        for n in [5u64, 50, 500, 5_000] {
            let mut errs = Vec::new();
            for _ in 0..20 {
                let mut s = Hll::new(HllConfig::new(p, rng.next_u64()));
                for _ in 0..n {
                    s.insert(rng.next_u64());
                }
                let hist = s.histogram();
                let z = hist[0] as f64;
                let r = 256.0;
                let hsum: f64 = hist
                    .iter()
                    .enumerate()
                    .skip(1)
                    .map(|(k, &c)| c as f64 * (-(k as f64)).exp2())
                    .sum();
                let est =
                    alpha(256) * r * (r - z) / (eval_beta(&coeffs, z) + hsum);
                errs.push((est - n as f64).abs() / n as f64);
            }
            let mre = errs.iter().sum::<f64>() / errs.len() as f64;
            assert!(mre < 0.2, "n={n} mre={mre}");
        }
    }
}
