//! Cardinality estimators over HLL register histograms.
//!
//! Three estimators are provided (paper §4 uses LogLogBeta; we also carry
//! the classic Flajolet estimator for reference and Ertl's improved σ/τ
//! estimator, which is the library default because it needs no empirically
//! fitted constants and is unbiased across the full cardinality range):
//!
//! * [`Estimator::Classic`] — Eq. 14 with the usual small-range linear
//!   counting switch-over.
//! * [`Estimator::LogLogBeta`] — Eq. 17, `α_r · r(r-z) / (β(r,z) + Σ 2^-r_i)`
//!   with per-p β polynomials fitted by least squares (see `beta.rs`,
//!   mirroring Qin et al. §II.C).
//! * [`Estimator::ErtlImproved`] — Ertl 2017 Alg. 6 (σ/τ corrected); this is
//!   also the math the L2 JAX artifact implements, so PJRT and native
//!   backends agree.

use super::beta::beta_correction;
use super::Hll;

/// Which cardinality estimator to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Estimator {
    /// Flajolet et al. 2007 bias-corrected harmonic mean + linear counting.
    Classic,
    /// LogLogBeta (Qin et al. 2016), the paper's Eq. 17.
    LogLogBeta,
    /// Ertl 2017 improved estimator (σ/τ corrections) — default.
    #[default]
    ErtlImproved,
}

impl Estimator {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "classic" => Some(Self::Classic),
            "beta" | "loglog-beta" => Some(Self::LogLogBeta),
            "ertl" | "improved" => Some(Self::ErtlImproved),
            _ => None,
        }
    }
}

/// α_r bias-correction constant (Flajolet et al. 2007).
pub fn alpha(r: usize) -> f64 {
    match r {
        16 => 0.673,
        32 => 0.697,
        64 => 0.709,
        _ => 0.7213 / (1.0 + 1.079 / r as f64),
    }
}

/// α_∞ = 1 / (2 ln 2), the limit constant used by the improved estimator.
pub const ALPHA_INF: f64 = 0.721_347_520_444_481_7;

pub(super) fn estimate(sketch: &Hll, estimator: Estimator) -> f64 {
    let q = sketch.config().q() as usize;
    let p = sketch.config().p();
    // dense sketches keep an incremental histogram, so this is O(kmax)
    // with no register scan and no allocation
    sketch.with_histogram(|hist| estimate_from_hist(hist, q, p, estimator))
}

/// Dispatch an estimator over a precomputed register histogram
/// (`hist.len() == q + 2`). This is the entry point used by borrowed
/// register views ([`crate::hll::SketchRef`]) and the arena store.
pub fn estimate_from_hist(
    hist: &[u32],
    q: usize,
    p: u8,
    estimator: Estimator,
) -> f64 {
    match estimator {
        Estimator::Classic => classic_from_hist(hist, q),
        Estimator::LogLogBeta => beta_from_hist(hist, q, p),
        Estimator::ErtlImproved => ertl_estimate_from_hist(hist, q),
    }
}

fn harmonic_sum(hist: &[u32]) -> f64 {
    // Σ C[k]·2^-k over all k (zero registers contribute C[0]·1).
    hist.iter()
        .enumerate()
        .map(|(k, &c)| c as f64 * (-(k as f64)).exp2())
        .sum()
}

/// Classic HLL estimate (paper Eq. 14) with linear-counting small-range
/// correction. The 64-bit hash makes the large-range correction moot
/// (paper §4).
pub fn classic_from_hist(hist: &[u32], _q: usize) -> f64 {
    let r: u32 = hist.iter().sum();
    let r = r as f64;
    let raw = alpha(r as usize) * r * r / harmonic_sum(hist);
    let zeros = hist[0] as f64;
    if raw <= 2.5 * r && zeros > 0.0 {
        // linear counting
        r * (r / zeros).ln()
    } else {
        raw
    }
}

/// LogLogBeta estimate (paper Eq. 17).
pub fn beta_from_hist(hist: &[u32], _q: usize, p: u8) -> f64 {
    let r: u32 = hist.iter().sum();
    let r = r as f64;
    let z = hist[0] as f64;
    if z == r {
        return 0.0;
    }
    // Σ over nonzero registers only (zero registers are absorbed into the
    // (r - z) factor and β, following Qin et al.).
    let hsum: f64 = hist
        .iter()
        .enumerate()
        .skip(1)
        .map(|(k, &c)| c as f64 * (-(k as f64)).exp2())
        .sum();
    alpha(r as usize) * r * (r - z) / (beta_correction(p, z) + hsum)
}

/// Ertl improved estimate from a register histogram (Ertl 2017 Alg. 6).
/// `hist.len()` must be `q + 2`.
pub fn ertl_estimate_from_hist(hist: &[u32], q: usize) -> f64 {
    debug_assert_eq!(hist.len(), q + 2);
    let m: u32 = hist.iter().sum();
    let m = m as f64;
    // z = m·τ(1 - C[q+1]/m); then Horner over k = q..1; then + m·σ(C[0]/m).
    let mut z = m * tau(1.0 - hist[q + 1] as f64 / m);
    for k in (1..=q).rev() {
        z = 0.5 * (z + hist[k] as f64);
    }
    z += m * sigma(hist[0] as f64 / m);
    if z.is_infinite() {
        return 0.0; // empty sketch: σ(1) = ∞ ⇒ estimate 0
    }
    ALPHA_INF * m * m / z
}

/// Ertl's σ(x) = x + Σ_{k≥1} x^(2^k)·2^(k-1); diverges at x = 1.
pub fn sigma(x: f64) -> f64 {
    if x == 1.0 {
        return f64::INFINITY;
    }
    let mut xk = x;
    let mut y = 1.0;
    let mut z = x;
    loop {
        xk *= xk;
        let z_prev = z;
        z += xk * y;
        y += y;
        if z == z_prev {
            return z;
        }
    }
}

/// Ertl's τ(x) = (1/3)·(1 - x - Σ_{k≥1} (1 - x^(2^-k))²·2^-k).
pub fn tau(x: f64) -> f64 {
    if x == 0.0 || x == 1.0 {
        return 0.0;
    }
    let mut xk = x;
    let mut y = 1.0;
    let mut z = 1.0 - x;
    loop {
        xk = xk.sqrt();
        let z_prev = z;
        y *= 0.5;
        z -= (1.0 - xk) * (1.0 - xk) * y;
        if z == z_prev {
            return z / 3.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hll::{Hll, HllConfig};
    use crate::util::prop::Cases;

    fn filled(p: u8, n: u64, seed: u64) -> Hll {
        let mut s = Hll::new(HllConfig::new(p, 0xABCD));
        let mut rng = crate::hash::Xoshiro256ss::new(seed);
        for _ in 0..n {
            s.insert(rng.next_u64());
        }
        s
    }

    #[test]
    fn sigma_tau_fixed_points() {
        assert_eq!(sigma(0.0), 0.0);
        assert!(sigma(1.0).is_infinite());
        assert_eq!(tau(0.0), 0.0);
        assert_eq!(tau(1.0), 0.0);
        // σ is increasing on [0, 1)
        let mut prev = -1.0;
        for i in 0..10 {
            let s = sigma(i as f64 * 0.1);
            assert!(s > prev);
            prev = s;
        }
    }

    #[test]
    fn all_estimators_track_truth() {
        for n in [10u64, 200, 5_000, 100_000] {
            let s = filled(10, n, n);
            let se = 1.04 / (1024f64).sqrt();
            for est in [
                Estimator::Classic,
                Estimator::LogLogBeta,
                Estimator::ErtlImproved,
            ] {
                let e = s.estimate_with(est);
                let tol = (6.0 * se * n as f64).max(4.0);
                assert!(
                    (e - n as f64).abs() < tol,
                    "{est:?} n={n} est={e}"
                );
            }
        }
    }

    #[test]
    fn ertl_matches_small_and_large_regimes() {
        Cases::new("ertl_regimes", 25).run(|rng| {
            let n = 1 + rng.next_below(200_000);
            let mut s = Hll::new(HllConfig::new(8, 0x11));
            for _ in 0..n {
                s.insert(rng.next_u64());
            }
            let e = s.estimate_with(Estimator::ErtlImproved);
            let se = 1.04 / 16.0; // p = 8
            assert!(
                (e - n as f64).abs() < (6.0 * se * n as f64).max(4.0),
                "n={n} est={e}"
            );
        });
    }

    #[test]
    fn estimators_agree_with_each_other() {
        // In the mid-range all three are near-identical.
        let s = filled(12, 40_000, 3);
        let c = s.estimate_with(Estimator::Classic);
        let b = s.estimate_with(Estimator::LogLogBeta);
        let e = s.estimate_with(Estimator::ErtlImproved);
        for (x, y) in [(c, b), (b, e), (c, e)] {
            assert!((x - y).abs() / x < 0.05, "{c} {b} {e}");
        }
    }

    #[test]
    fn estimator_parse() {
        assert_eq!(Estimator::parse("classic"), Some(Estimator::Classic));
        assert_eq!(Estimator::parse("beta"), Some(Estimator::LogLogBeta));
        assert_eq!(Estimator::parse("ertl"), Some(Estimator::ErtlImproved));
        assert_eq!(Estimator::parse("nope"), None);
    }

    #[test]
    fn alpha_constants() {
        assert_eq!(alpha(16), 0.673);
        assert_eq!(alpha(32), 0.697);
        assert_eq!(alpha(64), 0.709);
        assert!((alpha(1 << 14) - 0.7213 / (1.0 + 1.079 / 16384.0)).abs() < 1e-12);
    }
}
