//! Sketch intersection estimation (paper §4.1, Appendix B).
//!
//! Two estimators over a pair of HLL sketches `A`, `B`:
//!
//! * [`inclusion_exclusion`] — `|A∩B| ≈ |Ã| + |B̃| - |A∪B|` (paper Eq. 18);
//!   cheap but high-variance, kept as the baseline the paper compares
//!   against in Figure 8.
//! * [`mle_intersect`] — the joint Poisson maximum-likelihood estimator
//!   (Ertl 2017): compress the register pair into the Eq. 19 count
//!   statistics, then ascend the log-likelihood of `(λa, λb, λx)` =
//!   `(|A\B|, |B\A|, |A∩B|)` in log-space with Adam and an analytic
//!   gradient. The math mirrors `python/compile/model.py` exactly so the
//!   PJRT artifact and this native path can be cross-checked.
//!
//! Appendix B's *domination* phenomenon (all of one sketch's registers ≥
//! the other's) is detected by [`domination`]; dominated pairs yield
//! unreliable intersection estimates and callers may choose to discard
//! them (`MleOptions::flag_dominated`).

use super::estimate::ertl_estimate_from_hist;
use super::store::{view_of, SketchRef};
use super::Hll;

/// Eq. 19 count statistics for a register pair.
///
/// `c[0][k] = #{i : k = a_i < b_i}`   (`c_k^{A,<}`)
/// `c[1][k] = #{i : k = a_i > b_i}`   (`c_k^{A,>}`)
/// `c[2][k] = #{i : k = b_i < a_i}`   (`c_k^{B,<}`)
/// `c[3][k] = #{i : k = b_i > a_i}`   (`c_k^{B,>}`)
/// `c[4][k] = #{i : k = a_i = b_i}`   (`c_k^{=}`)
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairStats {
    pub c: [Vec<u32>; 5],
    pub q: usize,
    pub m: usize,
}

impl PairStats {
    /// Histogram of A's registers (`c^{A,<} + c^{A,>} + c^=`).
    pub fn hist_a(&self) -> Vec<u32> {
        self.combine(&[0, 1, 4])
    }

    /// Histogram of B's registers.
    pub fn hist_b(&self) -> Vec<u32> {
        self.combine(&[2, 3, 4])
    }

    /// Histogram of the union's registers (register-wise max:
    /// `c^{A,>} + c^{B,>} + c^=`).
    pub fn hist_union(&self) -> Vec<u32> {
        self.combine(&[1, 3, 4])
    }

    fn combine(&self, idx: &[usize]) -> Vec<u32> {
        let mut out = vec![0u32; self.q + 2];
        for &i in idx {
            for (o, &v) in out.iter_mut().zip(&self.c[i]) {
                *o += v;
            }
        }
        out
    }
}

/// Accumulate the Eq. 19 statistics for a sketch pair.
///
/// Panics if the sketches' configs differ (different `(p, seed)` sketches
/// are not comparable).
pub fn pair_stats(a: &Hll, b: &Hll) -> PairStats {
    pair_stats_ref(view_of(a), view_of(b))
}

/// Nonzero `(index, value)` registers of a borrowed view, ascending.
fn nonzero_of(v: SketchRef<'_>) -> Vec<(u32, u8)> {
    match v {
        SketchRef::Sparse { pairs, .. } => {
            pairs.iter().map(|&(j, x)| (j as u32, x)).collect()
        }
        SketchRef::Dense { regs, .. } => regs
            .iter()
            .enumerate()
            .filter(|&(_, &x)| x != 0)
            .map(|(j, &x)| (j as u32, x))
            .collect(),
    }
}

/// [`pair_stats`] over borrowed register views — the zero-copy entry
/// point used by mapped snapshots; the owned version delegates here so
/// both paths produce identical counts.
pub fn pair_stats_ref(a: SketchRef<'_>, b: SketchRef<'_>) -> PairStats {
    assert_eq!(
        a.config(),
        b.config(),
        "cannot intersect sketches with different (p, seed)"
    );
    let q = a.config().q() as usize;
    let m = a.config().num_registers();
    let mut c: [Vec<u32>; 5] = std::array::from_fn(|_| vec![0u32; q + 2]);

    match (a, b) {
        (
            SketchRef::Dense { regs: da, .. },
            SketchRef::Dense { regs: db, .. },
        ) => {
            for (&ra, &rb) in da.iter().zip(db) {
                bump(&mut c, ra, rb);
            }
        }
        _ => {
            // At least one side sparse: walk the union of nonzero indices,
            // then account for the all-zero remainder in c^=[0].
            let mut nonzero = 0usize;
            let av: Vec<(u32, u8)> = nonzero_of(a);
            let bv: Vec<(u32, u8)> = nonzero_of(b);
            let (mut i, mut j) = (0usize, 0usize);
            while i < av.len() || j < bv.len() {
                let (ra, rb) = match (av.get(i), bv.get(j)) {
                    (Some(&(ia, xa)), Some(&(ib, xb))) => {
                        if ia == ib {
                            i += 1;
                            j += 1;
                            (xa, xb)
                        } else if ia < ib {
                            i += 1;
                            (xa, 0)
                        } else {
                            j += 1;
                            (0, xb)
                        }
                    }
                    (Some(&(_, xa)), None) => {
                        i += 1;
                        (xa, 0)
                    }
                    (None, Some(&(_, xb))) => {
                        j += 1;
                        (0, xb)
                    }
                    (None, None) => unreachable!(),
                };
                bump(&mut c, ra, rb);
                nonzero += 1;
            }
            c[4][0] += (m - nonzero) as u32;
        }
    }
    PairStats { c, q, m }
}

#[inline]
fn bump(c: &mut [Vec<u32>; 5], ra: u8, rb: u8) {
    use std::cmp::Ordering::*;
    match ra.cmp(&rb) {
        Less => {
            c[0][ra as usize] += 1;
            c[3][rb as usize] += 1;
        }
        Greater => {
            c[1][ra as usize] += 1;
            c[2][rb as usize] += 1;
        }
        Equal => c[4][ra as usize] += 1,
    }
}

/// Appendix B domination classification of a sketch pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domination {
    /// Neither sketch dominates: the MLE has information to work with.
    None,
    /// A's registers ≥ B's everywhere (`c^{A,<} = c^{B,>} = 0`).
    ADominatesB,
    /// ...and additionally no ties at nonzero values (strict domination —
    /// the MLE's λx is unidentifiable, App. B).
    AStrictlyDominatesB,
    /// Symmetric cases.
    BDominatesA,
    BStrictlyDominatesA,
}

/// Detect domination from pair statistics (paper Appendix B).
pub fn domination(stats: &PairStats) -> Domination {
    let a_lt: u32 = stats.c[0].iter().sum();
    let b_lt: u32 = stats.c[2].iter().sum();
    let eq_nonzero: u32 = stats.c[4].iter().skip(1).sum();
    match (a_lt == 0, b_lt == 0) {
        (true, true) | (false, false) => Domination::None,
        (true, false) => {
            if eq_nonzero == 0 {
                Domination::AStrictlyDominatesB
            } else {
                Domination::ADominatesB
            }
        }
        (false, true) => {
            if eq_nonzero == 0 {
                Domination::BStrictlyDominatesA
            } else {
                Domination::BDominatesA
            }
        }
    }
}

/// The result of an intersection estimation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntersectionEstimate {
    /// |A \ B| estimate (MLE only; NaN for inclusion-exclusion).
    pub a_minus_b: f64,
    /// |B \ A| estimate (MLE only; NaN for inclusion-exclusion).
    pub b_minus_a: f64,
    /// |A ∩ B| estimate.
    pub intersection: f64,
    /// |A ∪ B| estimate (from the merged registers).
    pub union: f64,
    /// Domination classification of the pair.
    pub domination: Domination,
}

impl IntersectionEstimate {
    /// Jaccard similarity |A∩B| / |A∪B| — the paper's *triangle density*
    /// proxy (§5, Figure 3).
    pub fn jaccard(&self) -> f64 {
        if self.union <= 0.0 {
            0.0
        } else {
            (self.intersection / self.union).clamp(0.0, 1.0)
        }
    }
}

/// Inclusion-exclusion intersection estimate (paper Eq. 18), clamped at 0
/// from below (the paper notes the raw difference can go negative).
pub fn inclusion_exclusion(a: &Hll, b: &Hll) -> IntersectionEstimate {
    inclusion_exclusion_ref(view_of(a), view_of(b))
}

/// [`inclusion_exclusion`] over borrowed register views.
pub fn inclusion_exclusion_ref(
    a: SketchRef<'_>,
    b: SketchRef<'_>,
) -> IntersectionEstimate {
    let stats = pair_stats_ref(a, b);
    inclusion_exclusion_from_stats(&stats)
}

pub(crate) fn inclusion_exclusion_from_stats(
    stats: &PairStats,
) -> IntersectionEstimate {
    let q = stats.q;
    let est_a = ertl_estimate_from_hist(&stats.hist_a(), q);
    let est_b = ertl_estimate_from_hist(&stats.hist_b(), q);
    let est_u = ertl_estimate_from_hist(&stats.hist_union(), q);
    IntersectionEstimate {
        a_minus_b: f64::NAN,
        b_minus_a: f64::NAN,
        intersection: (est_a + est_b - est_u).max(0.0),
        union: est_u,
        domination: domination(stats),
    }
}

/// Options for the joint MLE optimizer. The defaults mirror the L2 JAX
/// artifact (`python/compile/model.py`) so both backends land on the same
/// optimum.
#[derive(Debug, Clone, Copy)]
pub struct MleOptions {
    /// Maximum number of Adam iterations.
    pub iterations: usize,
    /// Initial learning rate (decays exponentially to `lr_final`).
    pub lr_initial: f64,
    pub lr_final: f64,
    /// Early-stop once the gradient ∞-norm (normalized by the register
    /// count m, whose scale the counts carry) stays below this for two
    /// consecutive iterations (0 disables; the JAX artifact runs the fixed
    /// count — both converge to the same optimum).
    pub tolerance: f64,
}

impl Default for MleOptions {
    fn default() -> Self {
        Self {
            iterations: 150,
            lr_initial: 0.5,
            lr_final: 0.02,
            tolerance: 2e-4,
        }
    }
}

/// Compact per-k solver view of [`PairStats`]: only rows with a nonzero
/// count survive, with counts pre-cast to f64 — the §Perf hot-path layout
/// (most of the q+2 rows are empty for real sketches).
struct SolverStats {
    /// (t = 2^-min(k,q), is_k0, is_saturation, c_a_lt, c_a_gt, c_b_lt,
    ///  c_b_gt, c_eq)
    entries: Vec<(f64, bool, bool, f64, f64, f64, f64, f64)>,
}

impl SolverStats {
    fn new(stats: &PairStats) -> Self {
        let q = stats.q;
        let mut entries = Vec::with_capacity(16);
        for k in 0..=q + 1 {
            let c0 = stats.c[0][k];
            let c1 = stats.c[1][k];
            let c2 = stats.c[2][k];
            let c3 = stats.c[3][k];
            let c4 = stats.c[4][k];
            if c0 | c1 | c2 | c3 | c4 == 0 {
                continue;
            }
            entries.push((
                tk(k, q),
                k == 0,
                k == q + 1,
                c0 as f64,
                c1 as f64,
                c2 as f64,
                c3 as f64,
                c4 as f64,
            ));
        }
        Self { entries }
    }

    /// Gradient of the log-likelihood w.r.t. θ = ln λ, computed with three
    /// exponentials per entry: `ea = e^{-va·t}`, `eb`, `ex`, from which
    /// every ΔF and equal-pmf term follows by products.
    fn grad(&self, va: f64, vb: f64, vx: f64) -> [f64; 3] {
        let mut ga = 0.0;
        let mut gb = 0.0;
        let mut gx = 0.0;
        for &(t, k0, sat, c0, c1, c2, c3, c4) in &self.entries {
            if k0 {
                // every ΔF_u(0) = e^{-u}: d/du log = -1
                ga -= c0 + c1 + c4;
                gb -= c2 + c3 + c4;
                gx -= c0 + c2 + c4;
                continue;
            }
            let ea = (-va * t).exp();
            let eb = (-vb * t).exp();
            let ex = (-vx * t).exp();

            // 1 - e^{-ut}, cancellation-free for tiny ut (≈ ut·(1 - ut/2)).
            #[inline]
            fn om(ut: f64, e: f64) -> f64 {
                if ut < 1e-8 {
                    (ut * (1.0 - 0.5 * ut)).max(1e-300)
                } else {
                    1.0 - e
                }
            }

            // d log ΔF_u(k)/du given e = e^{-u·t}:
            //   mid: -t + t·e/(1-e);  saturation row: t·e/(1-e)
            let base = if sat { 0.0 } else { -t };
            if c0 != 0.0 {
                let u = va + vx;
                let e = ea * ex;
                let d = (base + t * e / om(u * t, e)) * c0;
                ga += d;
                gx += d;
            }
            if c3 != 0.0 {
                let d = (base + t * eb / om(vb * t, eb)) * c3;
                gb += d;
            }
            if c2 != 0.0 {
                let u = vb + vx;
                let e = eb * ex;
                let d = (base + t * e / om(u * t, e)) * c2;
                gb += d;
                gx += d;
            }
            if c1 != 0.0 {
                let d = (base + t * ea / om(va * t, ea)) * c1;
                ga += d;
            }
            if c4 != 0.0 {
                // equal-register pmf bracket terms from shared exps
                let a = ea * ex;
                let bv = eb * ex;
                let c = ea * eb * ex;
                let x = ex;
                let oma = om((va + vx) * t, a);
                let omb = om((vb + vx) * t, bv);
                let omxx = om(vx * t, x);
                let br = (oma * omb + c * omxx).max(1e-300);
                let dba = t * (a * omb - c * omxx);
                let dbb = t * (bv * oma - c * omxx);
                let dbx = t * (a * omb + bv * oma - c * omxx + c * x);
                ga += (base + dba / br) * c4;
                gb += (base + dbb / br) * c4;
                gx += (base + dbx / br) * c4;
            }
        }
        [ga * va, gb * vb, gx * vx]
    }
}

/// Joint Poisson MLE intersection estimate (Ertl 2017; paper §4.1).
pub fn mle_intersect(a: &Hll, b: &Hll, opts: &MleOptions) -> IntersectionEstimate {
    mle_intersect_ref(view_of(a), view_of(b), opts)
}

/// [`mle_intersect`] over borrowed register views — used by the mapped
/// query engine so TRI/JACCARD answers match the heap path bit for bit.
pub fn mle_intersect_ref(
    a: SketchRef<'_>,
    b: SketchRef<'_>,
    opts: &MleOptions,
) -> IntersectionEstimate {
    let stats = pair_stats_ref(a, b);
    mle_from_stats(&stats, opts)
}

/// MLE from precomputed statistics (the PJRT batcher and benches reuse
/// stats across estimators).
pub fn mle_from_stats(stats: &PairStats, opts: &MleOptions) -> IntersectionEstimate {
    let q = stats.q;
    let m = stats.m as f64;
    let est_a = ertl_estimate_from_hist(&stats.hist_a(), q);
    let est_b = ertl_estimate_from_hist(&stats.hist_b(), q);
    let est_u = ertl_estimate_from_hist(&stats.hist_union(), q);

    // Degenerate cases: an empty side pins the intersection at 0.
    if est_a <= 0.0 || est_b <= 0.0 {
        return IntersectionEstimate {
            a_minus_b: est_a,
            b_minus_a: est_b,
            intersection: 0.0,
            union: est_u,
            domination: domination(stats),
        };
    }

    // Initialization from inclusion-exclusion, clamped into feasibility.
    let ix = (est_a + est_b - est_u).clamp(1.0, est_a.min(est_b));
    let mut theta = [
        (est_a - ix).max(1.0).ln(),
        (est_b - ix).max(1.0).ln(),
        ix.ln(),
    ];
    let theta_max = m.ln() + 48.0;

    let solver = SolverStats::new(stats);
    let m_inv = 1.0 / m;
    let mut mom = [0.0f64; 3];
    let mut vel = [0.0f64; 3];
    let (beta1, beta2, eps) = (0.9, 0.999, 1e-8);
    let decay = (opts.lr_final / opts.lr_initial)
        .powf(1.0 / opts.iterations as f64);
    let mut lr = opts.lr_initial;
    // incremental bias-correction products (avoids powf in the loop)
    let mut b1t = 1.0f64;
    let mut b2t = 1.0f64;
    let mut calm_iters = 0u32;

    for _ in 0..opts.iterations {
        let g = solver.grad(
            theta[0].exp() * m_inv,
            theta[1].exp() * m_inv,
            theta[2].exp() * m_inv,
        );
        b1t *= beta1;
        b2t *= beta2;
        let mut g_inf = 0.0f64;
        for d in 0..3 {
            mom[d] = beta1 * mom[d] + (1.0 - beta1) * g[d];
            vel[d] = beta2 * vel[d] + (1.0 - beta2) * g[d] * g[d];
            let mhat = mom[d] / (1.0 - b1t);
            let vhat = vel[d] / (1.0 - b2t);
            theta[d] = (theta[d] + lr * mhat / (vhat.sqrt() + eps))
                .clamp(-11.0, theta_max);
            g_inf = g_inf.max(g[d].abs());
        }
        lr *= decay;
        if opts.tolerance > 0.0 {
            if g_inf < opts.tolerance * m {
                calm_iters += 1;
                if calm_iters >= 2 {
                    break;
                }
            } else {
                calm_iters = 0;
            }
        }
    }

    IntersectionEstimate {
        a_minus_b: theta[0].exp(),
        b_minus_a: theta[1].exp(),
        intersection: theta[2].exp(),
        union: est_u,
        domination: domination(stats),
    }
}

/// Log-likelihood of the Eq. 19 statistics under the Poisson model, at
/// `theta = (ln λa, ln λb, ln λx)`. Exposed for tests and benches.
pub fn log_likelihood(theta: &[f64; 3], stats: &PairStats) -> f64 {
    let m = stats.m as f64;
    let va = theta[0].exp() / m;
    let vb = theta[1].exp() / m;
    let vx = theta[2].exp() / m;
    let q = stats.q;
    let mut ll = 0.0;
    for k in 0..=q + 1 {
        let t = tk(k, q);
        let sat = k == q + 1;
        let add = |c: u32, u: f64| -> f64 {
            if c == 0 {
                0.0
            } else {
                c as f64 * log_df(u, t, k == 0, sat)
            }
        };
        ll += add(stats.c[0][k], va + vx);
        ll += add(stats.c[3][k], vb);
        ll += add(stats.c[2][k], vb + vx);
        ll += add(stats.c[1][k], va);
        let ceq = stats.c[4][k];
        if ceq != 0 {
            ll += ceq as f64 * log_pmf_eq(va, vb, vx, t, k == 0, sat);
        }
    }
    ll
}

/// Analytic gradient of [`log_likelihood`] w.r.t. θ (chain rule through
/// `v = e^θ / m` gives a clean `v·∂/∂v` form). Verified against central
/// differences in the tests.
pub fn grad_log_likelihood(theta: &[f64; 3], stats: &PairStats) -> [f64; 3] {
    let m = stats.m as f64;
    let va = theta[0].exp() / m;
    let vb = theta[1].exp() / m;
    let vx = theta[2].exp() / m;
    let q = stats.q;
    // accumulate ∂ll/∂v (per-register-rate space)
    let mut ga = 0.0;
    let mut gb = 0.0;
    let mut gx = 0.0;
    for k in 0..=q + 1 {
        let t = tk(k, q);
        let k0 = k == 0;
        let sat = k == q + 1;

        // d log ΔF_u(k) / du
        let c0 = stats.c[0][k];
        if c0 != 0 {
            let d = dlog_df(va + vx, t, k0, sat) * c0 as f64;
            ga += d;
            gx += d;
        }
        let c3 = stats.c[3][k];
        if c3 != 0 {
            gb += dlog_df(vb, t, k0, sat) * c3 as f64;
        }
        let c2 = stats.c[2][k];
        if c2 != 0 {
            let d = dlog_df(vb + vx, t, k0, sat) * c2 as f64;
            gb += d;
            gx += d;
        }
        let c1 = stats.c[1][k];
        if c1 != 0 {
            ga += dlog_df(va, t, k0, sat) * c1 as f64;
        }

        let ceq = stats.c[4][k];
        if ceq != 0 {
            let (da, db, dx) = dlog_pmf_eq(va, vb, vx, t, k0, sat);
            let w = ceq as f64;
            ga += w * da;
            gb += w * db;
            gx += w * dx;
        }
    }
    // chain rule: dll/dθ = v·m·(dll/dλ)… directly: λ = e^θ, v = λ/m,
    // dll/dθ = dll/dv · dv/dθ = dll/dv · v.
    [ga * va, gb * vb, gx * vx]
}

#[inline]
fn tk(k: usize, q: usize) -> f64 {
    if k <= q {
        (-(k as f64)).exp2()
    } else {
        (-(q as f64)).exp2()
    }
}

/// log ΔF_u(k), the stable expm1 form (see model.py `log_dF`).
#[inline]
fn log_df(u: f64, t: f64, k0: bool, sat: bool) -> f64 {
    const TINY: f64 = 1e-300;
    if k0 {
        return -u;
    }
    let ut = u * t;
    let body = (-(-ut).exp_m1()).max(TINY).ln();
    if sat {
        body
    } else {
        -ut + body
    }
}

/// d log ΔF_u(k) / du.
#[inline]
fn dlog_df(u: f64, t: f64, k0: bool, sat: bool) -> f64 {
    if k0 {
        return -1.0;
    }
    let ut = u * t;
    let e = (-ut).exp();
    // d/du log(1 - e^{-ut}) = t·e^{-ut} / (1 - e^{-ut})
    let dsat = t * e / (-(-ut).exp_m1()).max(1e-300);
    if sat {
        dsat
    } else {
        // log ΔF = -ut + log(1 - e^{-ut})
        -t + dsat
    }
}

/// log pmf of an equal register pair (see model.py bracket derivation).
#[inline]
fn log_pmf_eq(va: f64, vb: f64, vx: f64, t: f64, k0: bool, sat: bool) -> f64 {
    const TINY: f64 = 1e-300;
    let vs = va + vb + vx;
    if k0 {
        return -vs;
    }
    let br = bracket(va, vb, vx, t).max(TINY).ln();
    if sat {
        br
    } else {
        -vs * t + br
    }
}

/// B(t) = expm1(-(va+vx)t)·expm1(-(vb+vx)t) + e^{-vs·t}·(-expm1(-vx t)).
#[inline]
fn bracket(va: f64, vb: f64, vx: f64, t: f64) -> f64 {
    let ea = (-(va + vx) * t).exp_m1();
    let eb = (-(vb + vx) * t).exp_m1();
    let c = (-(va + vb + vx) * t).exp();
    ea * eb + c * (-(-vx * t).exp_m1())
}

/// Gradient of log pmf_eq w.r.t. (va, vb, vx).
#[inline]
fn dlog_pmf_eq(
    va: f64,
    vb: f64,
    vx: f64,
    t: f64,
    k0: bool,
    sat: bool,
) -> (f64, f64, f64) {
    if k0 {
        return (-1.0, -1.0, -1.0);
    }
    // A = e^{-(va+vx)t}, Bv = e^{-(vb+vx)t}, C = e^{-vs·t}, X = e^{-vx·t}
    let a = (-(va + vx) * t).exp();
    let bv = (-(vb + vx) * t).exp();
    let c = (-(va + vb + vx) * t).exp();
    let x = (-vx * t).exp();
    let br = ((1.0 - a) * (1.0 - bv) + c * (1.0 - x)).max(1e-300);
    // ∂B/∂va = t·A·(1-Bv) - t·C·(1-X); symmetric for vb;
    // ∂B/∂vx = t·A·(1-Bv) + t·Bv·(1-A) - t·C·(1-X) + t·C·X.
    let dba = t * (a * (1.0 - bv) - c * (1.0 - x));
    let dbb = t * (bv * (1.0 - a) - c * (1.0 - x));
    let dbx = t * (a * (1.0 - bv) + bv * (1.0 - a) - c * (1.0 - x) + c * x);
    if sat {
        (dba / br, dbb / br, dbx / br)
    } else {
        (-t + dba / br, -t + dbb / br, -t + dbx / br)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256ss;
    use crate::hll::{Hll, HllConfig};
    use crate::util::prop::Cases;

    fn planted(
        p: u8,
        na: u64,
        nb: u64,
        nx: u64,
        seed: u64,
    ) -> (Hll, Hll) {
        let cfg = HllConfig::new(p, 0x1717);
        let mut rng = Xoshiro256ss::new(seed);
        let mut a = Hll::new(cfg);
        let mut b = Hll::new(cfg);
        for _ in 0..nx {
            let e = rng.next_u64();
            a.insert(e);
            b.insert(e);
        }
        for _ in 0..na - nx {
            a.insert(rng.next_u64());
        }
        for _ in 0..nb - nx {
            b.insert(rng.next_u64());
        }
        (a, b)
    }

    #[test]
    fn pair_stats_partition_registers() {
        Cases::new("pair_stats_partition", 20).run(|rng| {
            let (a, b) = planted(
                7,
                1 + rng.next_below(4000),
                1 + rng.next_below(4000),
                0,
                rng.next_u64(),
            );
            let s = pair_stats(&a, &b);
            let total: u32 = s.c.iter().map(|v| v.iter().sum::<u32>()).sum();
            // every register counted exactly twice for A</B> pairs and once
            // in c^= — i.e. rows 0+1+4 sum to m, rows 2+3+4 sum to m.
            let m = s.m as u32;
            let a_side: u32 = s.c[0].iter().sum::<u32>()
                + s.c[1].iter().sum::<u32>()
                + s.c[4].iter().sum::<u32>();
            let b_side: u32 = s.c[2].iter().sum::<u32>()
                + s.c[3].iter().sum::<u32>()
                + s.c[4].iter().sum::<u32>();
            assert_eq!(a_side, m);
            assert_eq!(b_side, m);
            assert_eq!(total, 2 * m - s.c[4].iter().sum::<u32>());
        });
    }

    #[test]
    fn pair_stats_sparse_equals_dense() {
        Cases::new("pair_stats_sparse_dense", 15).run(|rng| {
            let (a, b) = planted(
                8,
                1 + rng.next_below(40),
                1 + rng.next_below(40),
                0,
                rng.next_u64(),
            );
            assert!(!a.is_dense() && !b.is_dense());
            let mut ad = a.clone();
            let mut bd = b.clone();
            ad.saturate();
            bd.saturate();
            assert_eq!(pair_stats(&a, &b), pair_stats(&ad, &bd));
            assert_eq!(pair_stats(&a, &bd), pair_stats(&ad, &b));
        });
    }

    #[test]
    fn hist_views_match_merged_sketches() {
        let (a, b) = planted(8, 2000, 1500, 400, 9);
        let s = pair_stats(&a, &b);
        assert_eq!(s.hist_a(), a.histogram());
        assert_eq!(s.hist_b(), b.histogram());
        let mut u = a.clone();
        u.merge(&b);
        assert_eq!(s.hist_union(), u.histogram());
    }

    #[test]
    fn analytic_gradient_matches_finite_differences() {
        let (a, b) = planted(6, 3000, 2500, 800, 4);
        let stats = pair_stats(&a, &b);
        let theta = [2200.0f64.ln(), 1700.0f64.ln(), 800.0f64.ln()];
        let g = grad_log_likelihood(&theta, &stats);
        let h = 1e-6;
        for d in 0..3 {
            let mut tp = theta;
            tp[d] += h;
            let mut tm = theta;
            tm[d] -= h;
            let fd = (log_likelihood(&tp, &stats)
                - log_likelihood(&tm, &stats))
                / (2.0 * h);
            assert!(
                (fd - g[d]).abs() <= 1e-4 * (1.0 + fd.abs().max(g[d].abs())),
                "dim {d}: fd={fd} analytic={}",
                g[d]
            );
        }
    }

    #[test]
    fn solver_gradient_matches_reference_gradient() {
        // the shared-exponential fast path must agree with the plain
        // analytic gradient (which itself matches finite differences)
        Cases::new("solver_grad", 20).run(|rng| {
            let (a, b) = planted(
                6,
                100 + rng.next_below(4000),
                100 + rng.next_below(4000),
                rng.next_below(100),
                rng.next_u64(),
            );
            let stats = pair_stats(&a, &b);
            let solver = SolverStats::new(&stats);
            let m = stats.m as f64;
            for _ in 0..5 {
                let theta = [
                    1.0 + rng.next_f64() * 8.0,
                    1.0 + rng.next_f64() * 8.0,
                    rng.next_f64() * 8.0,
                ];
                let fast = solver.grad(
                    theta[0].exp() / m,
                    theta[1].exp() / m,
                    theta[2].exp() / m,
                );
                let reference = grad_log_likelihood(&theta, &stats);
                for d in 0..3 {
                    assert!(
                        (fast[d] - reference[d]).abs()
                            <= 1e-6 * (1.0 + reference[d].abs()),
                        "dim {d}: fast={} ref={}",
                        fast[d],
                        reference[d]
                    );
                }
            }
        });
    }

    #[test]
    fn mle_recovers_large_intersections() {
        for (na, nb, nx) in [(3000, 3000, 1500u64), (5000, 5000, 4000)] {
            let (a, b) = planted(8, na, nb, nx, na * 31 + nx);
            let est = mle_intersect(&a, &b, &MleOptions::default());
            let rel = (est.intersection - nx as f64).abs() / nx as f64;
            assert!(rel < 0.25, "nx={nx} est={} rel={rel}", est.intersection);
            let u = (na + nb - nx) as f64;
            assert!((est.union - u).abs() / u < 0.1);
        }
    }

    #[test]
    fn mle_beats_inclusion_exclusion_on_average() {
        // Fig. 8's qualitative claim at a moderate overlap.
        let mut err_mle = 0.0;
        let mut err_ix = 0.0;
        let trials = 12;
        for s in 0..trials {
            let (a, b) = planted(8, 10_000, 10_000, 2_000, 1000 + s);
            let stats = pair_stats(&a, &b);
            let mle = mle_from_stats(&stats, &MleOptions::default());
            let ix = inclusion_exclusion_from_stats(&stats);
            err_mle += (mle.intersection - 2000.0).abs();
            err_ix += (ix.intersection - 2000.0).abs();
        }
        assert!(
            err_mle <= err_ix * 1.1,
            "mle={err_mle} ix={err_ix} (MLE should not be worse)"
        );
    }

    #[test]
    fn disjoint_sets_do_not_hallucinate() {
        let (a, b) = planted(8, 4000, 4000, 0, 77);
        let est = mle_intersect(&a, &b, &MleOptions::default());
        assert!(
            est.intersection < 0.15 * 4000.0,
            "phantom intersection {}",
            est.intersection
        );
    }

    #[test]
    fn domination_detection() {
        let cfg = HllConfig::new(8, 5);
        // B ⊂ A with |A| >> |B| ⇒ A (possibly strictly) dominates B.
        let mut rng = Xoshiro256ss::new(8);
        let mut a = Hll::new(cfg);
        let mut b = Hll::new(cfg);
        let common: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
        for &e in &common {
            a.insert(e);
            b.insert(e);
        }
        for _ in 0..100_000 {
            a.insert(rng.next_u64());
        }
        let s = pair_stats(&a, &b);
        assert!(matches!(
            domination(&s),
            Domination::ADominatesB | Domination::AStrictlyDominatesB
        ));
        // and the mirror:
        let s2 = pair_stats(&b, &a);
        assert!(matches!(
            domination(&s2),
            Domination::BDominatesA | Domination::BStrictlyDominatesA
        ));
    }

    #[test]
    fn jaccard_bounded() {
        Cases::new("jaccard", 10).run(|rng| {
            let (a, b) = planted(
                7,
                1 + rng.next_below(5000),
                1 + rng.next_below(5000),
                0,
                rng.next_u64(),
            );
            let est = mle_intersect(&a, &b, &MleOptions::default());
            let j = est.jaccard();
            assert!((0.0..=1.0).contains(&j));
        });
    }

    #[test]
    fn empty_side_yields_zero_intersection() {
        let cfg = HllConfig::new(8, 5);
        let empty = Hll::new(cfg);
        let mut full = Hll::new(cfg);
        for x in 0..1000u64 {
            full.insert(x);
        }
        let est = mle_intersect(&empty, &full, &MleOptions::default());
        assert_eq!(est.intersection, 0.0);
    }
}
