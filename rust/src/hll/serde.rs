//! Binary (de)serialization of HLL sketches — the substrate of the
//! "leave-behind, persistent query engine" property the paper emphasizes:
//! an accumulated DegreeSketch is stored to disk once and re-loaded for
//! later query sessions without another pass over the edge stream.
//!
//! Format (little-endian):
//! ```text
//! magic  u32   0x48_4C_4C_31 ("HLL1")
//! p      u8
//! seed   u64
//! mode   u8    0 = sparse, 1 = dense
//! sparse: count u32, then count × (index u16, value u8)
//! dense:  r × value u8
//! ```

use std::io::{self, Read, Write};

use super::{Hll, HllConfig, Registers};

const MAGIC: u32 = 0x484C_4C31; // "HLL1"

impl Hll {
    /// Serialize to a writer. The hash seed travels with the sketch so a
    /// reloaded instance keeps merging/intersecting consistently.
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&[self.config.p()])?;
        w.write_all(&self.config.hasher().seed().to_le_bytes())?;
        match &self.regs {
            Registers::Sparse(v) => {
                w.write_all(&[0u8])?;
                w.write_all(&(v.len() as u32).to_le_bytes())?;
                for &(j, x) in v {
                    w.write_all(&j.to_le_bytes())?;
                    w.write_all(&[x])?;
                }
            }
            Registers::Dense { regs, .. } => {
                w.write_all(&[1u8])?;
                w.write_all(regs)?;
            }
        }
        Ok(())
    }

    /// Deserialize from a reader; validates magic, p and register bounds.
    pub fn read_from<R: Read>(r: &mut R) -> io::Result<Hll> {
        let magic = read_u32(r)?;
        if magic != MAGIC {
            return Err(bad(format!("bad HLL magic {magic:#x}")));
        }
        let p = read_u8(r)?;
        if !(4..=16).contains(&p) {
            return Err(bad(format!("bad p {p}")));
        }
        let seed = read_u64(r)?;
        let config = HllConfig::new(p, seed);
        let kmax = config.kmax();
        let mode = read_u8(r)?;
        let regs = match mode {
            0 => {
                let count = read_u32(r)? as usize;
                if count > config.num_registers() {
                    return Err(bad(format!("sparse count {count} > r")));
                }
                let mut v = Vec::with_capacity(count);
                let mut prev: i32 = -1;
                for _ in 0..count {
                    let j = read_u16(r)?;
                    let x = read_u8(r)?;
                    if j as usize >= config.num_registers() {
                        return Err(bad(format!("register index {j} out of range")));
                    }
                    if (j as i32) <= prev {
                        return Err(bad("sparse indices not strictly increasing".into()));
                    }
                    if x == 0 || x > kmax {
                        return Err(bad(format!("register value {x} out of range")));
                    }
                    prev = j as i32;
                    v.push((j, x));
                }
                Registers::Sparse(v)
            }
            1 => {
                let mut d = vec![0u8; config.num_registers()];
                r.read_exact(&mut d)?;
                if d.iter().any(|&x| x > kmax) {
                    return Err(bad("dense register value out of range".into()));
                }
                // the histogram is derived state: rebuild rather than store
                let hist = super::kernels::histogram(&d, kmax);
                Registers::Dense { regs: d, hist }
            }
            other => return Err(bad(format!("bad mode {other}"))),
        };
        Ok(Hll { config, regs })
    }
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn read_u8<R: Read>(r: &mut R) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16<R: Read>(r: &mut R) -> io::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use crate::hll::{Hll, HllConfig};
    use crate::util::prop::Cases;

    #[test]
    fn round_trip_sparse_and_dense() {
        Cases::new("hll_serde_roundtrip", 20).run(|rng| {
            let mut s = Hll::new(HllConfig::new(8, rng.next_u64()));
            for _ in 0..rng.next_below(2000) {
                s.insert(rng.next_u64());
            }
            let mut buf = Vec::new();
            s.write_to(&mut buf).unwrap();
            let loaded = Hll::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(s, loaded);
        });
    }

    #[test]
    fn rejects_garbage() {
        assert!(Hll::read_from(&mut &b"nonsense"[..]).is_err());
        assert!(Hll::read_from(&mut &[][..]).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut s = Hll::new(HllConfig::new(8, 7));
        for x in 0..500u64 {
            s.insert(x);
        }
        let mut buf = Vec::new();
        s.write_to(&mut buf).unwrap();
        for cut in [1, buf.len() / 2, buf.len() - 1] {
            assert!(
                Hll::read_from(&mut &buf[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }
}
