//! Hashing and pseudorandomness substrate.
//!
//! The paper's implementation uses the non-cryptographic xxhash (Collet,
//! 2014) to simulate the random machine words HLL requires; we reimplement
//! XXH64 from the reference specification, bit-exact against the published
//! test vectors (see the module tests). Because crates.io is unreachable in
//! this environment we also carry our own PRNGs (splitmix64, xoshiro256**)
//! for the graph generators and property tests.

mod xxhash;

pub use xxhash::{xxh64, xxh64_u64, XxHash64};

/// splitmix64: the canonical 64-bit finalizer/stream PRNG (Steele et al.).
///
/// Used to seed [`Xoshiro256ss`] and as a cheap secondary mixer. This is a
/// pure function of its input; successive values are produced by stepping
/// the input by the golden-ratio increment.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A splitmix64 sequential generator (streams the golden-ratio counter).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) — the workhorse PRNG for graph
/// generation and property tests. Deterministic given the seed, independent
/// of platform.
#[derive(Debug, Clone)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    /// Seed via splitmix64 per the authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_mixes() {
        assert_eq!(splitmix64(0), splitmix64(0));
        assert_ne!(splitmix64(0), splitmix64(1));
        let mut g = SplitMix64::new(42);
        let a = g.next_u64();
        let b = g.next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256ss::new(1);
        let mut b = Xoshiro256ss::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut g = Xoshiro256ss::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(g.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut g = Xoshiro256ss::new(9);
        for _ in 0..1000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256ss::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
