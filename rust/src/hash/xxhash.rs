//! XXH64 — bit-exact reimplementation of xxHash64 (Yann Collet, 2014).
//!
//! The paper's DegreeSketch implementation hashes vertex identifiers with
//! xxhash before inserting them into HLL sketches; we do the same so the
//! sketch statistics match. Validated against the published test vectors in
//! the tests below (empty string, short strings, and a > 32-byte input that
//! exercises the four-lane stripe loop).

const PRIME64_1: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME64_2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME64_3: u64 = 0x1656_67B1_9E37_79F9;
const PRIME64_4: u64 = 0x85EB_CA77_C2B2_AE63;
const PRIME64_5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME64_2))
        .rotate_left(31)
        .wrapping_mul(PRIME64_1)
}

#[inline]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val))
        .wrapping_mul(PRIME64_1)
        .wrapping_add(PRIME64_4)
}

#[inline]
fn avalanche(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^ (h >> 32)
}

#[inline]
fn read_u64(data: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(data[i..i + 8].try_into().unwrap())
}

#[inline]
fn read_u32(data: &[u8], i: usize) -> u64 {
    u32::from_le_bytes(data[i..i + 4].try_into().unwrap()) as u64
}

/// XXH64 of an arbitrary byte slice with the given seed.
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let len = data.len();
    let mut i = 0usize;

    let mut h64: u64 = if len >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while i + 32 <= len {
            v1 = round(v1, read_u64(data, i));
            v2 = round(v2, read_u64(data, i + 8));
            v3 = round(v3, read_u64(data, i + 16));
            v4 = round(v4, read_u64(data, i + 24));
            i += 32;
        }
        let mut h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
        h
    } else {
        seed.wrapping_add(PRIME64_5)
    };

    h64 = h64.wrapping_add(len as u64);

    while i + 8 <= len {
        h64 = (h64 ^ round(0, read_u64(data, i)))
            .rotate_left(27)
            .wrapping_mul(PRIME64_1)
            .wrapping_add(PRIME64_4);
        i += 8;
    }
    if i + 4 <= len {
        h64 = (h64 ^ read_u32(data, i).wrapping_mul(PRIME64_1))
            .rotate_left(23)
            .wrapping_mul(PRIME64_2)
            .wrapping_add(PRIME64_3);
        i += 4;
    }
    while i < len {
        h64 = (h64 ^ (data[i] as u64).wrapping_mul(PRIME64_5))
            .rotate_left(11)
            .wrapping_mul(PRIME64_1);
        i += 1;
    }

    avalanche(h64)
}

/// XXH64 of a single u64 (little-endian bytes) — the vertex-id hot path.
///
/// Equivalent to `xxh64(&x.to_le_bytes(), seed)` but avoids the generic
/// dispatch: this is called once per (edge, endpoint) during accumulation.
#[inline]
pub fn xxh64_u64(x: u64, seed: u64) -> u64 {
    let mut h64 = seed.wrapping_add(PRIME64_5).wrapping_add(8);
    h64 = (h64 ^ round(0, x))
        .rotate_left(27)
        .wrapping_mul(PRIME64_1)
        .wrapping_add(PRIME64_4);
    avalanche(h64)
}

/// A seeded xxhash64 hasher handle: the `h : 2^64 → 2^64` the paper assumes
/// all processors share. Cloning preserves the seed, so every rank hashes
/// identically — a correctness requirement for merging sketches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XxHash64 {
    seed: u64,
}

impl XxHash64 {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Hash a vertex identifier.
    #[inline]
    pub fn hash_u64(&self, x: u64) -> u64 {
        xxh64_u64(x, self.seed)
    }

    /// Hash arbitrary bytes (e.g. string vertex labels at ingest time).
    #[inline]
    pub fn hash_bytes(&self, data: &[u8]) -> u64 {
        xxh64(data, self.seed)
    }
}

impl Default for XxHash64 {
    fn default() -> Self {
        Self { seed: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Published XXH64 reference vectors (seed 0).
    #[test]
    fn reference_vectors_seed0() {
        assert_eq!(xxh64(b"", 0), 0xEF46_DB37_51D8_E999);
        assert_eq!(xxh64(b"abc", 0), 0x44BC_2CF5_AD77_0999);
        // > 32 bytes: exercises the 4-lane stripe loop (python-xxhash docs
        // vector).
        assert_eq!(
            xxh64(b"Nobody inspects the spammish repetition", 0),
            0xFBCE_A83C_8A37_8BF1
        );
    }

    #[test]
    fn fast_u64_path_matches_general() {
        for seed in [0u64, 1, 0xDEAD_BEEF] {
            for x in [0u64, 1, 42, u64::MAX, 0x0123_4567_89AB_CDEF] {
                assert_eq!(xxh64_u64(x, seed), xxh64(&x.to_le_bytes(), seed));
            }
        }
    }

    #[test]
    fn all_tail_lengths_run() {
        // Exercise every tail-length branch 0..=40.
        let data: Vec<u8> = (0..40u8).collect();
        let mut seen = std::collections::HashSet::new();
        for l in 0..=40 {
            assert!(seen.insert(xxh64(&data[..l], 7)));
        }
    }

    #[test]
    fn seed_changes_hash() {
        assert_ne!(xxh64(b"abc", 0), xxh64(b"abc", 1));
        assert_ne!(xxh64_u64(5, 0), xxh64_u64(5, 1));
    }

    #[test]
    fn avalanche_quality_u64_path() {
        // Flipping one input bit should flip ~half the output bits.
        let base = xxh64_u64(0x1234_5678, 0);
        for bit in 0..64 {
            let h = xxh64_u64(0x1234_5678 ^ (1u64 << bit), 0);
            let flips = (h ^ base).count_ones();
            assert!(
                (12..=52).contains(&flips),
                "bit {bit} flipped only {flips} output bits"
            );
        }
    }
}
