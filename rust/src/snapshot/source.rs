//! Byte sources backing an open snapshot: a read-only `mmap` region on
//! 64-bit unix (N processes serving the same snapshot share one page-cache
//! copy) or a heap buffer read in full (the portable fallback, also used
//! to exercise parity in tests). Both sit behind [`SnapshotSource`] so the
//! reader never knows which one it got.
//!
//! The heap buffer is backed by a `Vec<u64>` rather than `Vec<u8>` so its
//! base pointer is 8-byte aligned — together with the format's 64-byte
//! section alignment this makes the zero-copy `&[u32]` histogram and
//! `&[(u16, u8)]` pair views valid on either source.

use std::fs::File;
use std::io::Read;
use std::path::Path;

/// Which backing a source provides (surfaced in `STATS` and `inspect`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SourceKind {
    Mmap,
    Heap,
}

impl SourceKind {
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Mmap => "mmap",
            Self::Heap => "heap",
        }
    }
}

/// Expected access pattern for an open snapshot, forwarded to the
/// kernel as an `madvise(2)` hint where the backing supports it:
/// point-query serving wants `MADV_RANDOM` (no wasted readahead on a
/// binary-searched index), full-file scans (`verify()`, open-time
/// validation) want `MADV_SEQUENTIAL` (aggressive readahead, early
/// reclaim). Purely advisory — correctness never depends on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    Normal,
    Random,
    Sequential,
}

/// A read-only byte region holding an entire snapshot file.
pub trait SnapshotSource: Send + Sync {
    fn bytes(&self) -> &[u8];
    fn kind(&self) -> SourceKind;

    /// Hint the expected access pattern. Default: no-op (heap buffers
    /// and platforms without `madvise` have nothing to tune).
    fn advise(&self, _pattern: AccessPattern) {}
}

/// Whole-file heap buffer (8-byte aligned via the `u64` backing store).
pub struct HeapSource {
    buf: Vec<u64>,
    len: usize,
}

impl HeapSource {
    pub fn open(path: &Path) -> std::io::Result<Self> {
        let mut f = File::open(path)?;
        let len = f.metadata()?.len() as usize;
        let mut buf = vec![0u64; len.div_ceil(8)];
        // SAFETY: views the u64 backing store as bytes for the read —
        // `buf` holds `len.div_ceil(8) * 8 >= len` initialized bytes,
        // u8 has no alignment requirement, and `dst` is dropped before
        // `buf` moves into the returned struct.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len)
        };
        f.read_exact(dst)?;
        Ok(Self { buf, len })
    }
}

impl SnapshotSource for HeapSource {
    fn bytes(&self) -> &[u8] {
        // SAFETY: `buf` owns at least `len` initialized bytes (see
        // `open`), and the borrow is tied to `&self`, so the slice
        // cannot outlive the allocation.
        unsafe {
            std::slice::from_raw_parts(self.buf.as_ptr() as *const u8, self.len)
        }
    }

    fn kind(&self) -> SourceKind {
        SourceKind::Heap
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    // Bound directly against the platform libc (already linked by std);
    // the `libc` crate is unavailable offline. 64-bit unix only — the
    // `off_t` width matches `i64` there.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_SHARED: c_int = 0x1;
    // advice values shared by Linux and the BSD/darwin family
    pub const MADV_NORMAL: c_int = 0;
    pub const MADV_RANDOM: c_int = 1;
    pub const MADV_SEQUENTIAL: c_int = 2;
}

/// Read-only shared file mapping. Unmapped on drop.
#[cfg(all(unix, target_pointer_width = "64"))]
pub struct MmapSource {
    ptr: *const u8,
    len: usize,
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl MmapSource {
    pub fn open(path: &Path) -> std::io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        let f = File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "cannot map an empty file",
            ));
        }
        // SAFETY: plain FFI call; a null addr + PROT_READ + MAP_SHARED
        // request over a freshly opened fd has no preconditions beyond
        // `len > 0`, checked above. The result is validated before use.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as usize == usize::MAX {
            return Err(std::io::Error::last_os_error());
        }
        // the mapping outlives `f`: POSIX keeps it valid after close
        Ok(Self {
            ptr: ptr as *const u8,
            len,
        })
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl SnapshotSource for MmapSource {
    fn bytes(&self) -> &[u8] {
        // SAFETY: `ptr` is a PROT_READ mapping of exactly `len` bytes
        // held until Drop; the borrow is tied to `&self`, and nothing
        // writes through the mapping.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    fn kind(&self) -> SourceKind {
        SourceKind::Mmap
    }

    /// `madvise(2)` the whole mapping. Failures are ignored — the hint
    /// is best-effort by contract, and a mapping that rejects advice
    /// (e.g. an exotic filesystem) still reads correctly.
    fn advise(&self, pattern: AccessPattern) {
        let advice = match pattern {
            AccessPattern::Normal => sys::MADV_NORMAL,
            AccessPattern::Random => sys::MADV_RANDOM,
            AccessPattern::Sequential => sys::MADV_SEQUENTIAL,
        };
        // SAFETY: advises over the exact `[ptr, ptr+len)` region this
        // struct mapped and still holds; madvise never invalidates the
        // mapping, and the return value is deliberately ignored.
        unsafe {
            sys::madvise(
                self.ptr as *mut std::os::raw::c_void,
                self.len,
                advice,
            );
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for MmapSource {
    fn drop(&mut self) {
        // SAFETY: unmaps exactly the region `open` mapped; Drop runs at
        // most once, and no `bytes()` borrow can outlive `self`.
        unsafe {
            sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
    }
}

// SAFETY: the region is mapped PROT_READ and never handed out mutably;
// concurrent readers from any thread are fine.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for MmapSource {}
// SAFETY: same argument as Send above — `&MmapSource` only exposes
// immutable reads of an immutable mapping.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for MmapSource {}

/// A generation-tagged atomic slot over an `Arc`'d value — the snapshot
/// swap handle behind zero-downtime `RELOAD`.
///
/// The serving tier holds a `GenSwap<QueryEngine>`: readers [`load`] the
/// current engine together with the generation number it belongs to and
/// keep serving from that `Arc` even while a writer [`swap`]s in the
/// next generation (the old mapping stays valid — and, on unix, mapped —
/// until its last reader drops it). The generation tag is what keeps
/// derived state honest across a flip: cached results recorded under
/// generation N are tagged N and simply stop matching once the slot says
/// N+1, so a swap needs no cache sweep and no connection teardown.
///
/// [`load`]: GenSwap::load
/// [`swap`]: GenSwap::swap
pub struct GenSwap<T> {
    slot: std::sync::RwLock<(std::sync::Arc<T>, u64)>,
    /// Lock-free mirror of the slot's generation, for hot-path staleness
    /// checks (cache lookups) that must not touch the lock.
    gen: std::sync::atomic::AtomicU64,
}

impl<T> GenSwap<T> {
    pub fn new(value: std::sync::Arc<T>) -> Self {
        Self {
            slot: std::sync::RwLock::new((value, 0)),
            gen: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// The current value and the generation it belongs to, as one
    /// consistent pair (never a new value with an old tag or vice versa).
    pub fn load(&self) -> (std::sync::Arc<T>, u64) {
        let g = self.slot.read().unwrap();
        (std::sync::Arc::clone(&g.0), g.1)
    }

    /// The current generation without taking the slot lock.
    pub fn generation(&self) -> u64 {
        self.gen.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Install `value` as the next generation and return its tag.
    pub fn swap(&self, value: std::sync::Arc<T>) -> u64 {
        let mut g = self.slot.write().unwrap();
        let next = g.1 + 1;
        *g = (value, next);
        self.gen
            .store(next, std::sync::atomic::Ordering::Release);
        next
    }
}

/// How [`crate::snapshot::MappedSnapshot::open_with`] should back the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapshotMode {
    /// `mmap` where supported, heap otherwise.
    #[default]
    Auto,
    /// Require `mmap`; error on platforms without it.
    Mmap,
    /// Force the read-to-heap fallback.
    Heap,
}

/// Open `path` with the requested backing.
pub fn open_source(
    path: &Path,
    mode: SnapshotMode,
) -> std::io::Result<Box<dyn SnapshotSource>> {
    #[cfg(all(unix, target_pointer_width = "64"))]
    {
        match mode {
            SnapshotMode::Heap => {}
            SnapshotMode::Mmap => {
                return Ok(Box::new(MmapSource::open(path)?));
            }
            SnapshotMode::Auto => match MmapSource::open(path) {
                Ok(m) => return Ok(Box::new(m)),
                Err(_) => {} // e.g. pseudo-filesystems: fall back to heap
            },
        }
    }
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    if mode == SnapshotMode::Mmap {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "mmap is unavailable on this platform; use SnapshotMode::Auto",
        ));
    }
    Ok(Box::new(HeapSource::open(path)?))
}

#[cfg(test)]
// Miri cannot emulate the raw poll/mmap/fork/socket syscalls these
// tests drive; the Miri CI job scopes to the pure-core suites instead.
#[cfg(not(miri))]
mod tests {
    use super::*;

    fn tmp(name: &str, data: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(name);
        std::fs::write(&p, data).unwrap();
        p
    }

    #[test]
    fn heap_source_round_trips_bytes() {
        let data: Vec<u8> = (0..777u32).map(|i| (i % 251) as u8).collect();
        let p = tmp("ds_snapshot_heap_source", &data);
        let s = HeapSource::open(&p).unwrap();
        assert_eq!(s.bytes(), &data[..]);
        assert_eq!(s.kind(), SourceKind::Heap);
        // 8-byte aligned base
        assert_eq!(s.bytes().as_ptr() as usize % 8, 0);
        std::fs::remove_file(&p).unwrap();
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn mmap_source_matches_heap() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 13) as u8).collect();
        let p = tmp("ds_snapshot_mmap_source", &data);
        let m = MmapSource::open(&p).unwrap();
        let h = HeapSource::open(&p).unwrap();
        assert_eq!(m.bytes(), h.bytes());
        assert_eq!(m.kind(), SourceKind::Mmap);
        // page alignment makes every 64-byte-aligned section u32-safe
        assert_eq!(m.bytes().as_ptr() as usize % 4096, 0);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn advise_is_safe_on_every_source_and_pattern() {
        let data: Vec<u8> = (0..8192u32).map(|i| (i % 7) as u8).collect();
        let p = tmp("ds_snapshot_advise_source", &data);
        let sources: Vec<Box<dyn SnapshotSource>> = {
            let mut v: Vec<Box<dyn SnapshotSource>> =
                vec![Box::new(HeapSource::open(&p).unwrap())];
            #[cfg(all(unix, target_pointer_width = "64"))]
            v.push(Box::new(MmapSource::open(&p).unwrap()));
            v
        };
        for s in &sources {
            for pattern in [
                AccessPattern::Sequential,
                AccessPattern::Random,
                AccessPattern::Normal,
            ] {
                s.advise(pattern); // advisory: must never fail or corrupt
            }
            assert_eq!(s.bytes(), &data[..]);
        }
        drop(sources);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn gen_swap_pairs_value_and_generation_consistently() {
        let swap = std::sync::Arc::new(GenSwap::new(std::sync::Arc::new(0u64)));
        assert_eq!(swap.generation(), 0);
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let s = std::sync::Arc::clone(&swap);
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        let (v, g) = s.load();
                        // the invariant: value and tag always travel
                        // together — generation g holds value g
                        assert_eq!(*v, g);
                    }
                })
            })
            .collect();
        for next in 1..=50u64 {
            assert_eq!(swap.swap(std::sync::Arc::new(next)), next);
        }
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(swap.generation(), 50);
        assert_eq!(*swap.load().0, 50);
    }

    #[test]
    fn auto_mode_opens_something() {
        let p = tmp("ds_snapshot_auto_source", &[1, 2, 3, 4]);
        let s = open_source(&p, SnapshotMode::Auto).unwrap();
        assert_eq!(s.bytes(), &[1, 2, 3, 4]);
        std::fs::remove_file(&p).unwrap();
    }
}
