//! Zero-copy snapshot persistence — the "leave-behind query engine" as a
//! single mappable file.
//!
//! PR 1 made each rank's accumulated store one contiguous dense arena;
//! this module serializes that shape verbatim so a query server can
//! `mmap` the file and serve borrowed register views with **O(1) load
//! cost** (map + index validation — no per-sketch deserialization, no
//! per-vertex allocation) and **one shared page-cache copy across every
//! process** mapping the same snapshot. The portable fallback reads the
//! file into an aligned heap buffer behind the same [`SnapshotSource`]
//! trait.
//!
//! # File layout (version 1, all fixed little-endian, sections 64-byte
//! aligned)
//!
//! ```text
//! [0,   64)  header
//!    [0,  8)  magic  "DSKSNAP1"
//!    [8, 12)  version           u32  = 1
//!    [12,16)  meta CRC          u32  CRC-32 of header[16,64) ++ table
//!    [16]     p                 u8   HLL prefix bits (4..=16)
//!    [17]     partitioner tag   u8   0 = round-robin, 1 = hashed
//!    [18,20)  reserved
//!    [20,24)  ranks             u32
//!    [24,32)  hash seed         u64
//!    [32,40)  partitioner seed  u64
//!    [40,48)  total vertices    u64
//!    [48,56)  file length       u64
//!    [56,64)  reserved
//! [64, 64 + 64·ranks)  section table, one 64-byte entry per rank:
//!    vertex_count, dense_count, sparse_pairs,
//!    index_off, regs_off, hists_off, pairs_off   (absolute, 64-aligned)
//!    payload CRC-32 of [index_off, pairs_end)
//! then per rank, in offset order:
//!    index   vertex_count × u64 ids (strictly increasing)
//!            vertex_count × u64 slot words:
//!              bit 63 set   → dense: low 32 bits = slot in the register
//!                             arena
//!              bit 63 clear → sparse: bits [16,63) = offset into the pair
//!                             section (in records), bits [0,16) = length
//!    regs    dense_count × 2^p register bytes (slot-major)
//!    hists   dense_count × (kmax+1) u32 register histograms
//!    pairs   sparse_pairs × 4-byte records [idx lo, idx hi, value, 0]
//! ```
//!
//! The arenas mirror [`crate::hll::SketchStore`]'s in-memory layout, so a
//! mapped vertex resolves to exactly the [`SketchRef`] a live store would
//! hand out — estimates, merges and intersections are bit-identical to
//! the heap path (property-tested in `tests/snapshot.rs`).
//!
//! Opening validates: magic/version, meta CRC, file length, section
//! bounds/alignment/ordering, index sortedness + rank ownership, slot
//! ranges, and every sparse pair record. Section payload CRCs are
//! verified by [`MappedSnapshot::verify`] (run by `snapshot inspect`),
//! keeping `open` free of full-arena scans.
//!
//! The sibling [`checkpoint`] module carries the *mid-epoch* variant of
//! persistence: per-rank, per-barrier [`CheckpointRecord`]s that the
//! comm plane's fault-tolerant epochs freeze at quiescent barriers and
//! resume from after a worker death (see `comm::socket`).
//!
//! [`SketchRef`]: crate::hll::SketchRef

pub mod checkpoint;
mod layout;
mod reader;
mod source;
mod writer;

pub use checkpoint::CheckpointRecord;
pub use layout::{MAGIC, VERSION};
pub use reader::{MappedSnapshot, RankStats};
pub use source::{
    AccessPattern, GenSwap, HeapSource, SnapshotMode, SnapshotSource,
    SourceKind,
};
pub use writer::{SnapshotStats, SnapshotWriter};
