//! Fixed-endian on-disk structures of the snapshot format: the 64-byte
//! header, the per-rank section table, and the packed vertex→slot words.
//! See the module docs of [`crate::snapshot`] for the full file layout.
//!
//! Everything is little-endian regardless of host; decode goes through
//! `from_le_bytes` so the format is readable anywhere (the *zero-copy*
//! typed views additionally require a little-endian host and degrade to
//! owned decoding otherwise — see `reader.rs`).

use anyhow::{bail, Result};

use crate::coordinator::Partitioner;

/// `"DSKSNAP1"` — DegreeSketch snapshot, format generation 1.
pub const MAGIC: [u8; 8] = *b"DSKSNAP1";
/// Bumped on any incompatible layout change.
pub const VERSION: u32 = 1;
/// Every section starts on a 64-byte boundary (cache line; also keeps the
/// `u32` histogram and pair views aligned on any source).
pub const ALIGN: usize = 64;
pub const HEADER_LEN: usize = 64;
pub const SECTION_LEN: usize = 64;

/// Round `x` up to the next [`ALIGN`] boundary.
pub fn align_up(x: usize) -> usize {
    (x + ALIGN - 1) & !(ALIGN - 1)
}

const PART_ROUND_ROBIN: u8 = 0;
const PART_HASHED: u8 = 1;

fn partitioner_tag(p: Partitioner) -> (u8, u64) {
    match p {
        Partitioner::RoundRobin => (PART_ROUND_ROBIN, 0),
        Partitioner::Hashed { seed } => (PART_HASHED, seed),
    }
}

fn partitioner_from_tag(tag: u8, seed: u64) -> Result<Partitioner> {
    match tag {
        PART_ROUND_ROBIN => Ok(Partitioner::RoundRobin),
        PART_HASHED => Ok(Partitioner::Hashed { seed }),
        other => bail!("unknown partitioner tag {other}"),
    }
}

/// Decoded snapshot header (bytes `[0, 64)` of the file).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub p: u8,
    pub partitioner: Partitioner,
    pub ranks: u32,
    pub hash_seed: u64,
    pub total_vertices: u64,
    pub file_len: u64,
}

impl Header {
    /// Encode with the given `meta_crc` (CRC-32 of header bytes `[16, 64)`
    /// plus the whole section table).
    pub fn encode(&self, meta_crc: u32) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[0..8].copy_from_slice(&MAGIC);
        b[8..12].copy_from_slice(&VERSION.to_le_bytes());
        b[12..16].copy_from_slice(&meta_crc.to_le_bytes());
        let (tag, pseed) = partitioner_tag(self.partitioner);
        b[16] = self.p;
        b[17] = tag;
        // b[18..20] reserved
        b[20..24].copy_from_slice(&self.ranks.to_le_bytes());
        b[24..32].copy_from_slice(&self.hash_seed.to_le_bytes());
        b[32..40].copy_from_slice(&pseed.to_le_bytes());
        b[40..48].copy_from_slice(&self.total_vertices.to_le_bytes());
        b[48..56].copy_from_slice(&self.file_len.to_le_bytes());
        // b[56..64] reserved
        b
    }

    /// Decode and structurally validate; returns the stored meta CRC too
    /// (verified by the caller, which has the section table in hand).
    pub fn decode(b: &[u8]) -> Result<(Header, u32)> {
        if b.len() < HEADER_LEN {
            bail!("file too short for a snapshot header ({} bytes)", b.len());
        }
        if b[0..8] != MAGIC {
            bail!("bad snapshot magic {:02x?}", &b[0..8]);
        }
        let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported snapshot version {version} (want {VERSION})");
        }
        let meta_crc = u32::from_le_bytes(b[12..16].try_into().unwrap());
        let p = b[16];
        if !(4..=16).contains(&p) {
            bail!("snapshot p {p} out of range 4..=16");
        }
        let pseed = u64::from_le_bytes(b[32..40].try_into().unwrap());
        let partitioner = partitioner_from_tag(b[17], pseed)?;
        let ranks = u32::from_le_bytes(b[20..24].try_into().unwrap());
        if ranks == 0 {
            bail!("snapshot has zero ranks");
        }
        Ok((
            Header {
                p,
                partitioner,
                ranks,
                hash_seed: u64::from_le_bytes(b[24..32].try_into().unwrap()),
                total_vertices: u64::from_le_bytes(
                    b[40..48].try_into().unwrap(),
                ),
                file_len: u64::from_le_bytes(b[48..56].try_into().unwrap()),
            },
            meta_crc,
        ))
    }
}

/// One rank's entry in the section table (64 bytes each, following the
/// header). All offsets are absolute file offsets, 64-byte aligned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RankSection {
    pub vertex_count: u64,
    pub dense_count: u64,
    pub sparse_pairs: u64,
    pub index_off: u64,
    pub regs_off: u64,
    pub hists_off: u64,
    pub pairs_off: u64,
    /// CRC-32 of the rank's payload bytes `[index_off, pairs_end)`,
    /// inter-section padding included (it is written as zeros). Checked by
    /// [`crate::snapshot::MappedSnapshot::verify`], not on every open.
    pub payload_crc: u32,
}

impl RankSection {
    pub fn encode(&self) -> [u8; SECTION_LEN] {
        let mut b = [0u8; SECTION_LEN];
        b[0..8].copy_from_slice(&self.vertex_count.to_le_bytes());
        b[8..16].copy_from_slice(&self.dense_count.to_le_bytes());
        b[16..24].copy_from_slice(&self.sparse_pairs.to_le_bytes());
        b[24..32].copy_from_slice(&self.index_off.to_le_bytes());
        b[32..40].copy_from_slice(&self.regs_off.to_le_bytes());
        b[40..48].copy_from_slice(&self.hists_off.to_le_bytes());
        b[48..56].copy_from_slice(&self.pairs_off.to_le_bytes());
        b[56..60].copy_from_slice(&self.payload_crc.to_le_bytes());
        b
    }

    pub fn decode(b: &[u8]) -> RankSection {
        debug_assert!(b.len() >= SECTION_LEN);
        RankSection {
            vertex_count: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            dense_count: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            sparse_pairs: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            index_off: u64::from_le_bytes(b[24..32].try_into().unwrap()),
            regs_off: u64::from_le_bytes(b[32..40].try_into().unwrap()),
            hists_off: u64::from_le_bytes(b[40..48].try_into().unwrap()),
            pairs_off: u64::from_le_bytes(b[48..56].try_into().unwrap()),
            payload_crc: u32::from_le_bytes(b[56..60].try_into().unwrap()),
        }
    }
}

/// Slot words: bit 63 selects the representation.
///
/// * dense — `1 << 63 | dense_slot` (low 32 bits);
/// * sparse — `pair_offset << 16 | len`, where `pair_offset` (47 bits)
///   indexes the rank's pair section in 4-byte records and `len` (16 bits,
///   ≥ 1) is the run length.
const SLOT_DENSE: u64 = 1 << 63;
/// Maximum encodable sparse pair offset (47 bits).
pub const MAX_SPARSE_OFF: u64 = (1 << 47) - 1;

pub fn encode_dense_slot(d: u32) -> u64 {
    SLOT_DENSE | d as u64
}

pub fn encode_sparse_slot(pair_off: u64, len: u16) -> u64 {
    debug_assert!(pair_off <= MAX_SPARSE_OFF);
    (pair_off << 16) | len as u64
}

/// A decoded slot word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    Dense { slot: u32 },
    Sparse { pair_off: u64, len: u16 },
}

pub fn decode_slot(word: u64) -> Result<Slot> {
    if word & SLOT_DENSE != 0 {
        let rest = word & !SLOT_DENSE;
        if rest > u32::MAX as u64 {
            bail!("dense slot word {word:#x} has nonzero reserved bits");
        }
        Ok(Slot::Dense { slot: rest as u32 })
    } else {
        Ok(Slot::Sparse {
            pair_off: word >> 16,
            len: (word & 0xFFFF) as u16,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trips() {
        for part in [Partitioner::RoundRobin, Partitioner::Hashed { seed: 99 }]
        {
            let h = Header {
                p: 12,
                partitioner: part,
                ranks: 7,
                hash_seed: 0xDEAD_BEEF,
                total_vertices: 123_456,
                file_len: 1 << 20,
            };
            let bytes = h.encode(0xABCD_1234);
            let (back, crc) = Header::decode(&bytes).unwrap();
            assert_eq!(back, h);
            assert_eq!(crc, 0xABCD_1234);
        }
    }

    #[test]
    fn header_rejects_garbage() {
        assert!(Header::decode(&[0u8; 10]).is_err());
        let h = Header {
            p: 8,
            partitioner: Partitioner::RoundRobin,
            ranks: 1,
            hash_seed: 1,
            total_vertices: 0,
            file_len: 64,
        };
        let mut bytes = h.encode(0);
        bytes[0] = b'X';
        assert!(Header::decode(&bytes).is_err());
        let mut bytes = h.encode(0);
        bytes[8] = 99; // version
        assert!(Header::decode(&bytes).is_err());
        let mut bytes = h.encode(0);
        bytes[16] = 3; // p below range
        assert!(Header::decode(&bytes).is_err());
        let mut bytes = h.encode(0);
        bytes[17] = 9; // partitioner tag
        assert!(Header::decode(&bytes).is_err());
        let mut bytes = h.encode(0);
        bytes[20..24].copy_from_slice(&0u32.to_le_bytes()); // ranks = 0
        assert!(Header::decode(&bytes).is_err());
    }

    #[test]
    fn section_round_trips() {
        let s = RankSection {
            vertex_count: 10,
            dense_count: 3,
            sparse_pairs: 21,
            index_off: 128,
            regs_off: 320,
            hists_off: 1088,
            pairs_off: 1856,
            payload_crc: 0xFEED_F00D,
        };
        assert_eq!(RankSection::decode(&s.encode()), s);
    }

    #[test]
    fn slot_words_round_trip() {
        assert_eq!(
            decode_slot(encode_dense_slot(7)).unwrap(),
            Slot::Dense { slot: 7 }
        );
        assert_eq!(
            decode_slot(encode_sparse_slot(1_000_000, 13)).unwrap(),
            Slot::Sparse {
                pair_off: 1_000_000,
                len: 13
            }
        );
        // dense word with bits set between 32 and 63 is rejected
        assert!(decode_slot(SLOT_DENSE | (1 << 40)).is_err());
    }

    #[test]
    fn align_up_is_monotone() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 64);
        assert_eq!(align_up(64), 64);
        assert_eq!(align_up(65), 128);
    }
}
