//! **Epoch checkpoint records** — the unit of fabric fault tolerance.
//!
//! A resilient (checkpointed) socket epoch periodically freezes each
//! rank's mid-epoch actor state at a driver-coordinated quiescent
//! barrier (see `comm::socket` module docs). The frozen record is this
//! module's format: a CRC'd, little-endian, self-describing blob that
//! works both as a **file** (`degreesketch worker --ckpt-dir …`, resumed
//! with `--resume <file>`) and as an **inline payload** (the process
//! backend ships records back to the driver inside CKPT acks and re-seeds
//! respawned forks from driver-held copies).
//!
//! # Record layout (version 1, all little-endian)
//!
//! ```text
//! [0,  8)  magic   "DSKCKPT1"
//! [8, 12)  version u32 = 1
//! [12,20)  epoch   u64   fabric epoch id the barrier belongs to
//! [20,28)  generation u64  recovery generation the record was taken in
//! [28,36)  barrier u64   barrier sequence number within the epoch
//! [36,40)  rank    u32
//! [40,44)  ranks   u32
//! [44,52)  pos     u64   seed input units (edges) already consumed
//! [52,60)  sent    u64   cumulative messages queued by this rank
//! [60,68)  delivered u64 cumulative messages delivered to this rank
//! [68,76)  frames_in u64 inbound frames observed (stats continuity)
//! [76,84)  bytes_in  u64 inbound frame bytes observed
//! [84]     kind_len  u8, then the FabricActor::KIND bytes
//! then     ranks × (u64 sent_seq, u64 recv_seq)   per-peer channel tokens
//! then     u64 state_len, then the WireActor::write_state bytes
//! [last 4] CRC-32 over every preceding byte
//! ```
//!
//! The channel token vector is recorded at a **drained barrier** (global
//! quiescence: every `sent_seq(i→j)` equals the matching `recv_seq(j←i)`),
//! which is exactly what lets every rank restore its own vector
//! independently and still agree with every peer. Decoding validates
//! magic, version, lengths and the trailing CRC; corruption and
//! truncation are rejected with a named error, mirroring the snapshot
//! reader's stance.

use std::path::Path;

use crate::comm::codec::{put_u32, put_u64, WireError};
use crate::util::crc32::Crc32;

/// `"DSKCKPT1"`.
pub const CKPT_MAGIC: [u8; 8] = *b"DSKCKPT1";
/// Current record format version.
pub const CKPT_VERSION: u32 = 1;

/// One rank's frozen mid-epoch state at a checkpoint barrier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointRecord {
    /// Fabric epoch id (resume rejects records from another epoch).
    pub epoch: u64,
    /// Recovery generation the record was taken in (0 = undisturbed).
    pub generation: u64,
    /// Barrier sequence number within the epoch (1, 2, …; 0 is the
    /// implicit pre-seed "checkpoint zero"). Recovery restores every
    /// rank to the **same** barrier — the last one whose records the
    /// driver saw acknowledged by all ranks — so a rank that died
    /// mid-barrier can never mix barrier states across the fabric.
    pub barrier: u64,
    pub rank: u32,
    pub ranks: u32,
    /// Seed input units (edges) consumed before the barrier.
    pub pos: u64,
    /// Cumulative messages this rank had queued at the barrier.
    pub sent_total: u64,
    /// Cumulative messages delivered to this rank at the barrier.
    pub delivered_total: u64,
    /// Inbound frame count at the barrier (stats continuity on resume).
    pub frames_in: u64,
    /// Inbound frame bytes at the barrier.
    pub bytes_in: u64,
    /// `FabricActor::KIND` of the checkpointed actor.
    pub kind: String,
    /// Per-peer `(sent_seq, recv_seq)` cumulative channel tokens
    /// (index = peer rank; the self entry is always `(0, 0)`).
    pub channels: Vec<(u64, u64)>,
    /// `WireActor::write_state` bytes at the barrier.
    pub state: Vec<u8>,
}

// Decoding rides the comm plane's little-endian primitives (one codec
// for every byte-order-sensitive read in the crate); only the error
// type is adapted to this module's String errors.
fn fail(e: WireError) -> String {
    format!("checkpoint record: {e}")
}

fn get<'a>(input: &mut &'a [u8], n: usize) -> Result<&'a [u8], String> {
    crate::comm::codec::take(input, n).map_err(fail)
}

fn get_u32(input: &mut &[u8]) -> Result<u32, String> {
    crate::comm::codec::get_u32(input).map_err(fail)
}

fn get_u64(input: &mut &[u8]) -> Result<u64, String> {
    crate::comm::codec::get_u64(input).map_err(fail)
}

impl CheckpointRecord {
    /// Serialize the record (magic + fields + trailing CRC).
    pub fn encode(&self) -> Vec<u8> {
        assert!(self.kind.len() <= u8::MAX as usize, "actor kind too long");
        assert_eq!(
            self.channels.len(),
            self.ranks as usize,
            "one channel token pair per rank"
        );
        let mut out = Vec::with_capacity(
            96 + self.kind.len() + 16 * self.channels.len() + self.state.len(),
        );
        out.extend_from_slice(&CKPT_MAGIC);
        put_u32(&mut out, CKPT_VERSION);
        put_u64(&mut out, self.epoch);
        put_u64(&mut out, self.generation);
        put_u64(&mut out, self.barrier);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.ranks);
        put_u64(&mut out, self.pos);
        put_u64(&mut out, self.sent_total);
        put_u64(&mut out, self.delivered_total);
        put_u64(&mut out, self.frames_in);
        put_u64(&mut out, self.bytes_in);
        out.push(self.kind.len() as u8);
        out.extend_from_slice(self.kind.as_bytes());
        for &(s, r) in &self.channels {
            put_u64(&mut out, s);
            put_u64(&mut out, r);
        }
        put_u64(&mut out, self.state.len() as u64);
        out.extend_from_slice(&self.state);
        let mut crc = Crc32::new();
        crc.update(&out);
        let digest = crc.finish();
        out.extend_from_slice(&digest.to_le_bytes());
        out
    }

    /// Decode (and CRC-check) a record produced by [`Self::encode`].
    pub fn decode(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < 8 + 4 + 4 {
            return Err("checkpoint record truncated".to_string());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let mut crc = Crc32::new();
        crc.update(body);
        let actual = crc.finish();
        if stored != actual {
            return Err(format!(
                "checkpoint record crc mismatch: stored {stored:#010x}, \
                 actual {actual:#010x}"
            ));
        }
        let mut input = body;
        let magic = get(&mut input, 8)?;
        if magic != CKPT_MAGIC {
            return Err(format!("bad checkpoint magic {magic:02x?}"));
        }
        let version = get_u32(&mut input)?;
        if version != CKPT_VERSION {
            return Err(format!(
                "unsupported checkpoint version {version} (expected \
                 {CKPT_VERSION})"
            ));
        }
        let epoch = get_u64(&mut input)?;
        let generation = get_u64(&mut input)?;
        let barrier = get_u64(&mut input)?;
        let rank = get_u32(&mut input)?;
        let ranks = get_u32(&mut input)?;
        if ranks == 0 || rank >= ranks {
            return Err(format!(
                "checkpoint rank {rank} outside 0..{ranks}"
            ));
        }
        if ranks as usize > 1 << 16 {
            return Err(format!("checkpoint names {ranks} ranks"));
        }
        let pos = get_u64(&mut input)?;
        let sent_total = get_u64(&mut input)?;
        let delivered_total = get_u64(&mut input)?;
        let frames_in = get_u64(&mut input)?;
        let bytes_in = get_u64(&mut input)?;
        let kind_len = get(&mut input, 1)?[0] as usize;
        let kind_bytes = get(&mut input, kind_len)?;
        let kind = std::str::from_utf8(kind_bytes)
            .map_err(|_| "non-utf8 checkpoint actor kind".to_string())?
            .to_string();
        let mut channels = Vec::with_capacity(ranks as usize);
        for _ in 0..ranks {
            let s = get_u64(&mut input)?;
            let r = get_u64(&mut input)?;
            channels.push((s, r));
        }
        let state_len = get_u64(&mut input)? as usize;
        if state_len != input.len() {
            return Err(format!(
                "checkpoint state length {state_len} does not match the \
                 {} remaining bytes",
                input.len()
            ));
        }
        let state = input.to_vec();
        Ok(Self {
            epoch,
            generation,
            barrier,
            rank,
            ranks,
            pos,
            sent_total,
            delivered_total,
            frames_in,
            bytes_in,
            kind,
            channels,
            state,
        })
    }

    /// Write the record to `path` atomically (temp file + rename), so a
    /// rank killed mid-checkpoint leaves the previous record intact.
    pub fn write_file(&self, path: &Path) -> Result<(), String> {
        write_record_bytes(path, &self.encode())
    }

    /// Read and decode a record written by [`Self::write_file`].
    pub fn read_file(path: &Path) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| {
            format!("reading checkpoint {}: {e}", path.display())
        })?;
        Self::decode(&bytes)
            .map_err(|e| format!("checkpoint {}: {e}", path.display()))
    }
}

/// Write already-encoded record bytes atomically (temp file + rename),
/// creating the checkpoint directory if needed.
pub fn write_record_bytes(path: &Path, bytes: &[u8]) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| {
                format!("creating checkpoint dir {}: {e}", dir.display())
            })?;
        }
    }
    let tmp = path.with_extension("dsc.tmp");
    std::fs::write(&tmp, bytes).map_err(|e| {
        format!("writing checkpoint {}: {e}", tmp.display())
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        format!(
            "publishing checkpoint {} -> {}: {e}",
            tmp.display(),
            path.display()
        )
    })
}

/// Canonical checkpoint file name for one rank's record at one barrier
/// of one fabric epoch. Barriers get distinct files so a recovery can
/// name the exact barrier every rank must restore to (a rank killed
/// mid-barrier may have written barrier `b` while the fabric restores
/// to `b - 1`).
pub fn checkpoint_file_name(epoch: u64, barrier: u64, rank: usize) -> String {
    format!("ckpt-e{epoch}-b{barrier}-r{rank}.dsc")
}

/// The highest barrier of `epoch` for which **every** rank in `ranks`
/// has a decodable record under `dir`, or `None` if no barrier is fully
/// covered. This is the restore target for a batched multi-rank
/// recovery when the driver's in-memory barrier bookkeeping is gone
/// (driver restart): individual ranks may have raced ahead and written
/// barrier `b + 1` before dying, but only a barrier held by the whole
/// set is safe to roll the fabric back to.
pub fn latest_common_barrier(
    dir: &Path,
    epoch: u64,
    ranks: &[usize],
) -> Option<u64> {
    let mut best: Option<u64> = None;
    let entries = std::fs::read_dir(dir).ok()?;
    let first = *ranks.first()?;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        // Scan barriers via the first rank's files, then demand the rest.
        let Some(rest) = name.strip_prefix(&format!("ckpt-e{epoch}-b"))
        else {
            continue;
        };
        let Some(barrier) = rest
            .strip_suffix(&format!("-r{first}.dsc"))
            .and_then(|b| b.parse::<u64>().ok())
        else {
            continue;
        };
        if best.is_some_and(|b| b >= barrier) {
            continue;
        }
        let covered = ranks.iter().all(|&r| {
            CheckpointRecord::read_file(
                &dir.join(checkpoint_file_name(epoch, barrier, r)),
            )
            .map(|rec| rec.rank as usize == r && rec.barrier == barrier)
            .unwrap_or(false)
        });
        if covered {
            best = Some(barrier);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CheckpointRecord {
        CheckpointRecord {
            epoch: 3,
            generation: 1,
            barrier: 6,
            rank: 2,
            ranks: 4,
            pos: 12_345,
            sent_total: 777,
            delivered_total: 654,
            frames_in: 40,
            bytes_in: 9_876,
            kind: "deg-accum".to_string(),
            channels: vec![(0, 0), (10, 11), (0, 0), (12, 13)],
            state: (0..200u32).map(|i| (i * 7) as u8).collect(),
        }
    }

    #[test]
    fn record_round_trips() {
        let rec = sample();
        let wire = rec.encode();
        assert_eq!(CheckpointRecord::decode(&wire).unwrap(), rec);
    }

    #[test]
    fn any_single_byte_corruption_is_rejected() {
        let wire = sample().encode();
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x40;
            assert!(
                CheckpointRecord::decode(&bad).is_err(),
                "corrupt byte {i} accepted"
            );
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let wire = sample().encode();
        for cut in 0..wire.len() {
            assert!(
                CheckpointRecord::decode(&wire[..cut]).is_err(),
                "truncation at {cut} accepted"
            );
        }
    }

    #[test]
    fn file_round_trip_and_missing_file_error() {
        let dir = std::env::temp_dir().join("degreesketch_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(checkpoint_file_name(3, 6, 2));
        let rec = sample();
        rec.write_file(&path).unwrap();
        assert_eq!(CheckpointRecord::read_file(&path).unwrap(), rec);
        // overwrite is atomic-replace: a second write wins cleanly
        let mut rec2 = sample();
        rec2.pos = 99;
        rec2.write_file(&path).unwrap();
        assert_eq!(CheckpointRecord::read_file(&path).unwrap().pos, 99);
        std::fs::remove_file(&path).unwrap();
        assert!(CheckpointRecord::read_file(&path).is_err());
    }

    #[test]
    fn latest_common_barrier_demands_full_rank_coverage() {
        let dir = std::env::temp_dir().join("degreesketch_ckpt_common");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let write = |barrier: u64, rank: u32| {
            let mut rec = sample();
            rec.barrier = barrier;
            rec.rank = rank;
            rec.write_file(&dir.join(checkpoint_file_name(
                rec.epoch,
                barrier,
                rank as usize,
            )))
            .unwrap();
        };
        assert_eq!(latest_common_barrier(&dir, 3, &[1, 2]), None);
        // barrier 5 held by both ranks; barrier 6 only by rank 1 (it
        // raced ahead before dying) — the safe rollback target is 5
        write(5, 1);
        write(5, 2);
        write(6, 1);
        assert_eq!(latest_common_barrier(&dir, 3, &[1, 2]), Some(5));
        assert_eq!(latest_common_barrier(&dir, 3, &[1]), Some(6));
        // a corrupt record disqualifies its barrier
        write(7, 1);
        write(7, 2);
        let p7 = dir.join(checkpoint_file_name(3, 7, 2));
        let mut bytes = std::fs::read(&p7).unwrap();
        let n = bytes.len();
        bytes[n / 2] ^= 0x10;
        std::fs::write(&p7, bytes).unwrap();
        assert_eq!(latest_common_barrier(&dir, 3, &[1, 2]), Some(5));
        // wrong epoch: nothing to restore
        assert_eq!(latest_common_barrier(&dir, 4, &[1, 2]), None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rank_and_version_sanity_checks() {
        let mut rec = sample();
        rec.rank = 9; // >= ranks
        let wire = rec.encode();
        assert!(CheckpointRecord::decode(&wire).is_err());
        // a wrong version is rejected even with a valid CRC
        let mut wire = sample().encode();
        wire[8] = 9;
        let body_len = wire.len() - 4;
        let mut crc = Crc32::new();
        crc.update(&wire[..body_len]);
        let digest = crc.finish().to_le_bytes();
        let n = wire.len();
        wire[n - 4..].copy_from_slice(&digest);
        assert!(CheckpointRecord::decode(&wire).is_err());
    }
}
