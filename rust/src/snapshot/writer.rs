//! Serialize a frozen [`DegreeSketch`] into a single snapshot file.
//!
//! The writer makes one pass over each rank's vertex-sorted shard to
//! assemble four flat arenas (index, dense registers, histograms, packed
//! sparse pairs), then lands the whole file as a handful of large
//! sequential writes — no per-sketch framing, so the reader can map it
//! back without per-sketch deserialization.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::DegreeSketch;
use crate::util::crc32::Crc32;

use super::layout::{
    align_up, encode_dense_slot, encode_sparse_slot, Header, RankSection,
    HEADER_LEN, MAX_SPARSE_OFF, SECTION_LEN,
};

/// Summary of a written snapshot (also printed by `snapshot create`).
#[derive(Debug, Clone, Copy)]
pub struct SnapshotStats {
    pub file_len: u64,
    pub vertices: u64,
    pub dense_sketches: u64,
    pub sparse_pairs: u64,
}

struct RankBuf {
    ids: Vec<u8>,
    slots: Vec<u8>,
    regs: Vec<u8>,
    hists: Vec<u8>,
    pairs: Vec<u8>,
    vertex_count: u64,
    dense_count: u64,
    sparse_pairs: u64,
}

/// Writes [`DegreeSketch`]es in the snapshot format.
pub struct SnapshotWriter;

impl SnapshotWriter {
    /// Serialize `ds` to `path` (truncating any existing file).
    pub fn write(ds: &DegreeSketch, path: &Path) -> Result<SnapshotStats> {
        let config = ds.config();
        let bins = config.kmax() as usize + 1;

        // pass 1: flatten each shard into its arenas
        let mut bufs: Vec<RankBuf> = Vec::with_capacity(ds.num_ranks());
        for (rank, shard) in ds.shards().iter().enumerate() {
            let mut b = RankBuf {
                ids: Vec::with_capacity(shard.len() * 8),
                slots: Vec::with_capacity(shard.len() * 8),
                regs: Vec::new(),
                hists: Vec::new(),
                pairs: Vec::new(),
                vertex_count: shard.len() as u64,
                dense_count: 0,
                sparse_pairs: 0,
            };
            for (v, h) in shard.iter() {
                b.ids.extend_from_slice(&v.to_le_bytes());
                let word = match h.sparse_pairs() {
                    Some(pairs) => {
                        if pairs.is_empty() {
                            bail!("rank {rank}: vertex {v} has an empty sketch");
                        }
                        if b.sparse_pairs > MAX_SPARSE_OFF {
                            bail!("rank {rank}: sparse arena exceeds 2^47 pairs");
                        }
                        let word = encode_sparse_slot(
                            b.sparse_pairs,
                            pairs.len() as u16,
                        );
                        for &(j, x) in pairs {
                            let [lo, hi] = j.to_le_bytes();
                            b.pairs.extend_from_slice(&[lo, hi, x, 0]);
                        }
                        b.sparse_pairs += pairs.len() as u64;
                        word
                    }
                    None => {
                        if b.dense_count > u32::MAX as u64 {
                            bail!("rank {rank}: more than 2^32 dense sketches");
                        }
                        let regs = h.dense_registers().expect("dense sketch");
                        let hist = h.dense_hist().expect("dense sketch");
                        debug_assert_eq!(hist.len(), bins);
                        b.regs.extend_from_slice(regs);
                        for &c in hist {
                            b.hists.extend_from_slice(&c.to_le_bytes());
                        }
                        let word = encode_dense_slot(b.dense_count as u32);
                        b.dense_count += 1;
                        word
                    }
                };
                b.slots.extend_from_slice(&word.to_le_bytes());
            }
            bufs.push(b);
        }

        // pass 2: lay out sections and CRC each rank payload
        let table_end = HEADER_LEN + ds.num_ranks() * SECTION_LEN;
        let mut pos = table_end;
        let mut sections = Vec::with_capacity(bufs.len());
        let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(bufs.len());
        for b in &bufs {
            let index_off = align_up(pos);
            let regs_off = align_up(index_off + b.ids.len() + b.slots.len());
            let hists_off = align_up(regs_off + b.regs.len());
            let pairs_off = align_up(hists_off + b.hists.len());
            let pairs_end = pairs_off + b.pairs.len();

            let mut payload =
                Vec::with_capacity(pairs_end - index_off);
            let pad_to = |payload: &mut Vec<u8>, target: usize| {
                payload.resize(target - index_off, 0);
            };
            payload.extend_from_slice(&b.ids);
            payload.extend_from_slice(&b.slots);
            pad_to(&mut payload, regs_off);
            payload.extend_from_slice(&b.regs);
            pad_to(&mut payload, hists_off);
            payload.extend_from_slice(&b.hists);
            pad_to(&mut payload, pairs_off);
            payload.extend_from_slice(&b.pairs);
            let mut crc = Crc32::new();
            crc.update(&payload);

            sections.push(RankSection {
                vertex_count: b.vertex_count,
                dense_count: b.dense_count,
                sparse_pairs: b.sparse_pairs,
                index_off: index_off as u64,
                regs_off: regs_off as u64,
                hists_off: hists_off as u64,
                pairs_off: pairs_off as u64,
                payload_crc: crc.finish(),
            });
            payloads.push(payload);
            pos = pairs_end;
        }
        let file_len = pos as u64;

        let header = Header {
            p: config.p(),
            partitioner: ds.partitioner(),
            ranks: ds.num_ranks() as u32,
            hash_seed: config.hasher().seed(),
            total_vertices: ds.num_vertices() as u64,
            file_len,
        };
        // meta CRC covers header bytes [16, 64) plus the section table
        let provisional = header.encode(0);
        let mut meta = Crc32::new();
        meta.update(&provisional[16..]);
        let table: Vec<[u8; SECTION_LEN]> =
            sections.iter().map(|s| s.encode()).collect();
        for t in &table {
            meta.update(t);
        }
        let header_bytes = header.encode(meta.finish());

        // pass 3: sequential write — header, table, rank payloads
        let f = File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::with_capacity(1 << 20, f);
        w.write_all(&header_bytes)?;
        for t in &table {
            w.write_all(t)?;
        }
        let mut written = table_end;
        for (s, payload) in sections.iter().zip(&payloads) {
            let gap = s.index_off as usize - written;
            w.write_all(&vec![0u8; gap])?;
            w.write_all(payload)?;
            written = s.index_off as usize + payload.len();
        }
        debug_assert_eq!(written as u64, file_len);
        w.flush()?;

        Ok(SnapshotStats {
            file_len,
            vertices: header.total_vertices,
            dense_sketches: sections.iter().map(|s| s.dense_count).sum(),
            sparse_pairs: sections.iter().map(|s| s.sparse_pairs).sum(),
        })
    }
}
