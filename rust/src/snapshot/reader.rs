//! Open a snapshot file and serve zero-copy sketch views out of it.
//!
//! [`MappedSnapshot::open`] maps the file (or reads it to an aligned heap
//! buffer), validates the header CRC and the vertex→slot index with flat
//! word scans — **no per-sketch deserialization, no per-vertex heap
//! allocation** — and then answers [`MappedSnapshot::get`] with borrowed
//! [`SketchRef`] views straight into the mapped arenas, compatible with
//! every SWAR kernel and estimator the heap path uses.
//!
//! Zero-copy typed views (`&[u32]` histograms, `&[(u16, u8)]` pair runs)
//! require a little-endian host whose `(u16, u8)` ABI matches the packed
//! 4-byte file records; both are probed at open and the affected section
//! silently degrades to an owned decoded copy when the probe fails, so the
//! format stays portable.

use std::path::Path;

use anyhow::{bail, Context, Result};

// the wire pair encoding IS the snapshot pair encoding: one shared
// LE/ABI probe gates both zero-copy casts
use crate::comm::codec::pair_abi_matches;
use crate::coordinator::Partitioner;
use crate::hll::{HllConfig, SketchRef};
use crate::util::crc32::crc32;

use super::layout::{
    decode_slot, Header, RankSection, Slot, HEADER_LEN, SECTION_LEN,
};
use super::source::{
    open_source, AccessPattern, SnapshotMode, SnapshotSource, SourceKind,
};

/// Read a little-endian `u64` at `off` (bounds validated by the caller).
#[inline]
fn read_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().expect("8 bytes"))
}

/// Histogram section access: borrowed from the map or decoded at open.
enum HistsView {
    Borrowed(usize),
    Owned(Vec<u32>),
}

/// Sparse-pair section access: borrowed from the map or decoded at open.
enum PairsView {
    Borrowed(usize),
    Owned(Vec<(u16, u8)>),
}

struct RankView {
    vertex_count: usize,
    dense_count: usize,
    sparse_pairs: usize,
    ids_off: usize,
    slots_off: usize,
    regs_off: usize,
    hists: HistsView,
    pairs: PairsView,
    payload_start: usize,
    payload_end: usize,
    payload_crc: u32,
}

/// Per-rank inventory line for `snapshot inspect`.
#[derive(Debug, Clone, Copy)]
pub struct RankStats {
    pub vertex_count: usize,
    pub dense_count: usize,
    pub sparse_pairs: usize,
    pub payload_bytes: usize,
}

/// An open, validated snapshot serving borrowed sketch views.
pub struct MappedSnapshot {
    source: Box<dyn SnapshotSource>,
    config: HllConfig,
    partitioner: Partitioner,
    total_vertices: u64,
    rank_views: Vec<RankView>,
}

impl MappedSnapshot {
    /// Open with the default backing (`mmap` where available).
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, SnapshotMode::Auto)
    }

    /// Open with an explicit backing mode.
    pub fn open_with(path: &Path, mode: SnapshotMode) -> Result<Self> {
        let source = open_source(path, mode)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::from_source(source)
            .with_context(|| format!("validating {}", path.display()))
    }

    fn from_source(source: Box<dyn SnapshotSource>) -> Result<Self> {
        // open-time validation is one front-to-back scan: let readahead
        // run hot, then drop to the point-query pattern for serving
        source.advise(AccessPattern::Sequential);
        let bytes = source.bytes();
        let (header, stored_crc) = Header::decode(bytes)?;
        if header.file_len != bytes.len() as u64 {
            bail!(
                "file length mismatch: header says {}, file has {} bytes \
                 (truncated or appended)",
                header.file_len,
                bytes.len()
            );
        }
        let ranks = header.ranks as usize;
        let table_end = HEADER_LEN + ranks * SECTION_LEN;
        if bytes.len() < table_end {
            bail!("file too short for a {ranks}-rank section table");
        }
        if crc32(&bytes[16..table_end]) != stored_crc {
            bail!("header/section-table CRC mismatch");
        }

        let config = HllConfig::new(header.p, header.hash_seed);
        let r = config.num_registers();
        let bins = config.kmax() as usize + 1;
        let kmax = config.kmax();
        let threshold = config.saturation_threshold();
        let file_len = bytes.len() as u64;

        let le_host = cfg!(target_endian = "little");
        let pair_abi = pair_abi_matches();

        let mut rank_views = Vec::with_capacity(ranks);
        let mut prev_end = table_end as u64;
        let mut id_total = 0u64;
        for rank in 0..ranks {
            let sec = RankSection::decode(
                &bytes[HEADER_LEN + rank * SECTION_LEN..],
            );
            let view = validate_rank(
                bytes, rank, ranks, &sec, prev_end, file_len, r, bins, kmax,
                threshold, header.partitioner, le_host, pair_abi,
            )
            .with_context(|| format!("rank {rank}"))?;
            id_total += sec.vertex_count;
            prev_end = view.payload_end as u64;
            rank_views.push(view);
        }
        if id_total != header.total_vertices {
            bail!(
                "vertex count mismatch: header says {}, sections hold {}",
                header.total_vertices,
                id_total
            );
        }

        // serving is binary-searched point lookups: readahead past the
        // probed page is wasted IO under memory pressure
        source.advise(AccessPattern::Random);
        Ok(Self {
            source,
            config,
            partitioner: header.partitioner,
            total_vertices: header.total_vertices,
            rank_views,
        })
    }

    pub fn config(&self) -> &HllConfig {
        &self.config
    }

    pub fn partitioner(&self) -> Partitioner {
        self.partitioner
    }

    pub fn num_ranks(&self) -> usize {
        self.rank_views.len()
    }

    pub fn num_vertices(&self) -> usize {
        self.total_vertices as usize
    }

    /// Number of sketches stored dense (in the register arenas).
    pub fn num_dense_sketches(&self) -> usize {
        self.rank_views.iter().map(|v| v.dense_count).sum()
    }

    /// Bytes of the backing region (the whole snapshot file). Under mmap
    /// this is shared, demand-paged address space, not private heap.
    pub fn resident_bytes(&self) -> usize {
        self.source.bytes().len()
    }

    /// `"mmap"` or `"heap"` — how the file is backed.
    pub fn mode(&self) -> &'static str {
        self.source.kind().as_str()
    }

    pub fn source_kind(&self) -> SourceKind {
        self.source.kind()
    }

    /// Per-rank inventory (for `snapshot inspect`).
    pub fn rank_stats(&self) -> Vec<RankStats> {
        self.rank_views
            .iter()
            .map(|v| RankStats {
                vertex_count: v.vertex_count,
                dense_count: v.dense_count,
                sparse_pairs: v.sparse_pairs,
                payload_bytes: v.payload_end - v.payload_start,
            })
            .collect()
    }

    /// Full payload verification: recompute every rank's section CRC.
    /// O(file size) — run by `snapshot inspect`, not on every open.
    pub fn verify(&self) -> Result<()> {
        // a full-file CRC sweep is the sequential-scan case; restore the
        // point-query hint afterwards whatever the outcome
        self.source.advise(AccessPattern::Sequential);
        let outcome = (|| {
            let bytes = self.source.bytes();
            for (rank, v) in self.rank_views.iter().enumerate() {
                let got = crc32(&bytes[v.payload_start..v.payload_end]);
                if got != v.payload_crc {
                    bail!(
                        "rank {rank}: payload CRC mismatch \
                         (stored {:#010x}, computed {got:#010x})",
                        v.payload_crc
                    );
                }
            }
            Ok(())
        })();
        self.source.advise(AccessPattern::Random);
        outcome
    }

    /// Borrowed view of `v`'s sketch, straight out of the mapped arenas.
    pub fn get(&self, v: u64) -> Option<SketchRef<'_>> {
        let rank = self.partitioner.rank_of(v, self.rank_views.len());
        let rv = &self.rank_views[rank];
        let bytes = self.source.bytes();
        let (mut lo, mut hi) = (0usize, rv.vertex_count);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if read_u64(bytes, rv.ids_off + 8 * mid) < v {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo >= rv.vertex_count || read_u64(bytes, rv.ids_off + 8 * lo) != v
        {
            return None;
        }
        self.make_ref(rv, read_u64(bytes, rv.slots_off + 8 * lo))
    }

    /// Iterate `(vertex, view)` across ranks, each rank in ascending
    /// vertex order. Entries whose slot word fails the defensive re-check
    /// (possible only if the backing file changed under a shared mapping)
    /// are skipped.
    pub fn iter(&self) -> impl Iterator<Item = (u64, SketchRef<'_>)> + '_ {
        self.rank_views.iter().flat_map(move |rv| {
            let bytes = self.source.bytes();
            (0..rv.vertex_count).filter_map(move |i| {
                let v = read_u64(bytes, rv.ids_off + 8 * i);
                let word = read_u64(bytes, rv.slots_off + 8 * i);
                Some((v, self.make_ref(rv, word)?))
            })
        })
    }

    /// Resolve a slot word to a borrowed view.
    ///
    /// Every slot word was validated at open, but a `MAP_SHARED` mapping
    /// does not freeze the underlying file — another process rewriting it
    /// in place would change the bytes we re-read here. So the decoded
    /// slot is re-checked against the (owned, open-time) rank metadata
    /// before any slice is formed: a stale/corrupt word yields `None`
    /// instead of an out-of-bounds read. (Shrinking the file under a live
    /// mapping can still SIGBUS on page access — inherent to mmap; don't
    /// rewrite live snapshots in place.)
    fn make_ref<'a>(
        &'a self,
        rv: &'a RankView,
        word: u64,
    ) -> Option<SketchRef<'a>> {
        let bytes = self.source.bytes();
        let r = self.config.num_registers();
        let bins = self.config.kmax() as usize + 1;
        match decode_slot(word).ok()? {
            Slot::Dense { slot } => {
                let d = slot as usize;
                if d >= rv.dense_count {
                    return None;
                }
                // in bounds: regs_off + dense_count·r and hists_off +
                // dense_count·bins·4 were checked against the (fixed)
                // mapping length at open
                let regs =
                    &bytes[rv.regs_off + d * r..rv.regs_off + (d + 1) * r];
                let hist = match &rv.hists {
                    // SAFETY: offset/alignment validated at open (64-byte
                    // aligned section on a ≥8-byte aligned base, LE host),
                    // `d < dense_count` re-checked above keeps the slice
                    // inside the open-validated region; u32 has no invalid
                    // bit patterns; the slice borrows from `self.source`,
                    // which outlives the return value.
                    HistsView::Borrowed(off) => unsafe {
                        let ptr =
                            bytes.as_ptr().add(off + d * bins * 4) as *const u32;
                        std::slice::from_raw_parts(ptr, bins)
                    },
                    HistsView::Owned(v) => &v[d * bins..(d + 1) * bins],
                };
                Some(SketchRef::Dense {
                    config: self.config,
                    regs,
                    hist,
                })
            }
            Slot::Sparse { pair_off, len } => {
                let len = len as usize;
                if pair_off + len as u64 > rv.sparse_pairs as u64 {
                    return None;
                }
                let po = pair_off as usize;
                let pairs = match &rv.pairs {
                    // SAFETY: the `(u16, u8)` ABI was probed at open
                    // (size 4, u16 at 0, u8 at 2, LE host), the section is
                    // 2-byte aligned, `po + len <= sparse_pairs` re-checked
                    // above keeps the slice inside the open-validated
                    // region, and the padding byte is initialized (zero)
                    // in the file. Lifetime is tied to `self.source`.
                    PairsView::Borrowed(off) => unsafe {
                        let ptr = bytes.as_ptr().add(off + 4 * po)
                            as *const (u16, u8);
                        std::slice::from_raw_parts(ptr, len)
                    },
                    PairsView::Owned(v) => &v[po..po + len],
                };
                Some(SketchRef::Sparse {
                    config: self.config,
                    pairs,
                })
            }
        }
    }
}

/// Compute `off + count * elem`, bailing on overflow or `end > limit`.
fn region_end(
    off: u64,
    count: u64,
    elem: u64,
    limit: u64,
    what: &str,
) -> Result<u64> {
    let end = count
        .checked_mul(elem)
        .and_then(|size| off.checked_add(size))
        .with_context(|| format!("{what} region overflows"))?;
    if end > limit {
        bail!("{what} region [{off}, {end}) exceeds limit {limit}");
    }
    Ok(end)
}

#[allow(clippy::too_many_arguments)]
fn validate_rank(
    bytes: &[u8],
    rank: usize,
    ranks: usize,
    sec: &RankSection,
    prev_end: u64,
    file_len: u64,
    r: usize,
    bins: usize,
    kmax: u8,
    threshold: usize,
    partitioner: Partitioner,
    le_host: bool,
    pair_abi: bool,
) -> Result<RankView> {
    for (name, off) in [
        ("index", sec.index_off),
        ("regs", sec.regs_off),
        ("hists", sec.hists_off),
        ("pairs", sec.pairs_off),
    ] {
        if off % super::layout::ALIGN as u64 != 0 {
            bail!("{name} offset {off} is not 64-byte aligned");
        }
    }
    if sec.index_off < prev_end {
        bail!(
            "index offset {} overlaps the previous section (ends {prev_end})",
            sec.index_off
        );
    }
    region_end(sec.index_off, sec.vertex_count, 16, sec.regs_off, "index")?;
    region_end(
        sec.regs_off,
        sec.dense_count,
        r as u64,
        sec.hists_off,
        "registers",
    )?;
    region_end(
        sec.hists_off,
        sec.dense_count,
        bins as u64 * 4,
        sec.pairs_off,
        "histograms",
    )?;
    let pairs_end =
        region_end(sec.pairs_off, sec.sparse_pairs, 4, file_len, "pairs")?;

    let vc = sec.vertex_count as usize;
    let dc = sec.dense_count as usize;
    let sp = sec.sparse_pairs as usize;
    let ids_off = sec.index_off as usize;
    let slots_off = ids_off + 8 * vc;
    let pairs_off = sec.pairs_off as usize;

    // flat index scan: sortedness, ownership, slot ranges, pair runs —
    // word reads only, no sketch materialization
    let mut prev: Option<u64> = None;
    let mut dense_seen = 0usize;
    let mut sparse_seen = 0usize;
    for i in 0..vc {
        let v = read_u64(bytes, ids_off + 8 * i);
        if prev.is_some_and(|p| p >= v) {
            bail!("slot index not strictly increasing at position {i}");
        }
        prev = Some(v);
        if partitioner.rank_of(v, ranks) != rank {
            bail!("vertex {v} stored on the wrong rank");
        }
        match decode_slot(read_u64(bytes, slots_off + 8 * i))? {
            Slot::Dense { slot } => {
                if slot as usize >= dc {
                    bail!("vertex {v}: dense slot {slot} >= count {dc}");
                }
                dense_seen += 1;
            }
            Slot::Sparse { pair_off, len } => {
                let len = len as usize;
                if len == 0 || len > threshold {
                    bail!(
                        "vertex {v}: sparse run length {len} outside \
                         1..={threshold}"
                    );
                }
                // bound in u64 first: a 47-bit pair_off must not truncate
                // through a usize cast on 32-bit hosts
                if pair_off + len as u64 > sec.sparse_pairs {
                    bail!(
                        "vertex {v}: pair run [{pair_off}, {}) > {sp}",
                        pair_off + len as u64
                    );
                }
                let po = pair_off as usize;
                let mut prev_idx: i64 = -1;
                for k in 0..len {
                    let rec = pairs_off + 4 * (po + k);
                    let idx =
                        u16::from_le_bytes([bytes[rec], bytes[rec + 1]]);
                    let val = bytes[rec + 2];
                    if bytes[rec + 3] != 0 {
                        bail!("vertex {v}: nonzero pair padding byte");
                    }
                    if idx as usize >= r {
                        bail!("vertex {v}: register index {idx} >= {r}");
                    }
                    if idx as i64 <= prev_idx {
                        bail!("vertex {v}: pair indices not increasing");
                    }
                    if val == 0 || val > kmax {
                        bail!("vertex {v}: register value {val} out of range");
                    }
                    prev_idx = idx as i64;
                }
                sparse_seen += len;
            }
        }
    }
    if dense_seen != dc {
        bail!("index references {dense_seen} dense slots, table says {dc}");
    }
    if sparse_seen != sp {
        bail!("index covers {sparse_seen} sparse pairs, table says {sp}");
    }

    let hists_off = sec.hists_off as usize;
    let base = bytes.as_ptr() as usize;
    let hists = if le_host && (base + hists_off) % 4 == 0 {
        HistsView::Borrowed(hists_off)
    } else {
        // portable fallback: one bulk decode, still no per-sketch work
        let mut v = Vec::with_capacity(dc * bins);
        for i in 0..dc * bins {
            let o = hists_off + 4 * i;
            v.push(u32::from_le_bytes(
                bytes[o..o + 4].try_into().expect("4 bytes"),
            ));
        }
        HistsView::Owned(v)
    };
    let pairs = if pair_abi && (base + pairs_off) % 2 == 0 {
        PairsView::Borrowed(pairs_off)
    } else {
        let mut v = Vec::with_capacity(sp);
        for i in 0..sp {
            let o = pairs_off + 4 * i;
            v.push((
                u16::from_le_bytes([bytes[o], bytes[o + 1]]),
                bytes[o + 2],
            ));
        }
        PairsView::Owned(v)
    };

    Ok(RankView {
        vertex_count: vc,
        dense_count: dc,
        sparse_pairs: sp,
        ids_off,
        slots_off,
        regs_off: sec.regs_off as usize,
        hists,
        pairs,
        payload_start: sec.index_off as usize,
        payload_end: pairs_end as usize,
        payload_crc: sec.payload_crc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_abi_probe_is_consistent_with_layout() {
        // whatever the probe reports, the fallback keeps reads correct;
        // this just asserts the probe runs and the common LE case holds
        let ok = pair_abi_matches();
        if cfg!(target_endian = "little")
            && std::mem::size_of::<(u16, u8)>() == 4
        {
            // rustc lays (u16, u8) out field-ordered on every tier-1
            // target today; if this ever changes the reader silently
            // switches to owned decoding, so the assert documents rather
            // than gates
            assert!(
                ok || std::mem::align_of::<(u16, u8)>() != 2,
                "probe disagreed with the expected tuple layout"
            );
        }
    }

    #[test]
    fn read_u64_is_little_endian() {
        let bytes = [1u8, 0, 0, 0, 0, 0, 0, 0, 0xFF];
        assert_eq!(read_u64(&bytes, 0), 1);
        assert_eq!(read_u64(&bytes, 1), 0xFF00_0000_0000_0000);
    }
}
