//! `degreesketch` — the DegreeSketch coordinator CLI.
//!
//! ```text
//! degreesketch generate   --spec rmat:18:16 --seed 1 --out g.txt
//! degreesketch accumulate --graph g.txt --ranks 8 --p 12 --out sketch.d/
//!                         [--backend sequential|threaded|process|tcp]
//!                         [--flush-threshold N] [--fixed-flush]
//!                         [--listen addr --hosts 0=h:p,1=h:p,...]
//!                         [--checkpoint N] [--checkpoint-secs M]
//!                         [--checkpoint-chunk E]
//! degreesketch worker     --connect driverhost:port --rank 0
//!                         [--deadline-secs 60] [--ckpt-dir DIR]
//!                         [--resume DIR|FILE]
//! degreesketch query      --sketch sketch.d/ deg 42
//! degreesketch serve      --sketch sketch.d/|sketch.snap --addr 127.0.0.1:7171
//!                         [--workers N] [--batch-max N]
//!                         [--cache-capacity N] [--pending-cap N]
//!                         [--idle-secs S] [--span-sample N]
//!                         [--slow-query-us US] [--access-log FILE]
//!                         [--trace-dir DIR]
//! degreesketch snapshot   create  --sketch sketch.d/ --out sketch.snap
//! degreesketch snapshot   create  --graph g.txt --ranks 8 --p 12 --out s.snap
//! degreesketch snapshot   inspect --file sketch.snap [--verify]
//! degreesketch snapshot   serve   --file sketch.snap --addr 127.0.0.1:7171
//!                                 [--mode auto|mmap|heap] [--self-check]
//!                                 [serve flags as above]
//! degreesketch loadgen    --addr 127.0.0.1:7171 --connections 1000
//!                         --requests 100000 [--threads N]
//!                         [--hot-vertices N] [--hot-fraction F]
//!                         [--live-reload] [--max-p99-ms MS]
//!                         [--out BENCH_serving.json] [--seed S]
//! degreesketch anf        --graph g.txt --ranks 8 --p 8 --max-t 5 [--exact]
//! degreesketch triangles  edge|vertex --graph g.txt --k 100 --p 12
//!                         [--intersect mle|ix|pjrt] [--exact]
//! degreesketch exact      --graph g.txt triangles|neighborhoods
//! degreesketch calibrate-beta --p 8
//! degreesketch trace      inspect <dir> [--limit N] [--json]
//! degreesketch trace      export  <dir> --format chrome [--out FILE]
//! degreesketch heatmap    <dir> [--top K]
//! degreesketch info
//! ```
//!
//! Every subcommand also honors `--config file.toml` and repeated
//! `--set section.key=value` overrides. Epoch-running subcommands
//! (`accumulate`, `anf`, `triangles`, `snapshot create --graph`) accept
//! `--backend sequential|threaded|process|tcp` (process = forked
//! workers over Unix sockets; tcp = independent worker processes over a
//! rendezvous'd TCP mesh — launch one `degreesketch worker` per rank,
//! then run the driver with `--listen` naming its registrar address and
//! `--hosts` the rank → mesh-listener map, or set `comm.listen` /
//! `comm.hosts` in the config), `--flush-threshold N` and
//! `--fixed-flush` (pin the adaptive per-destination flush thresholds),
//! plus the fault-tolerance knobs `--checkpoint N` /
//! `--checkpoint-secs M` / `--checkpoint-chunk E` (checkpointed epochs
//! on the socket backends: a SIGKILLed worker can be respawned with
//! `worker --resume <ckpt-dir>` and the epoch resumes from the last
//! barrier instead of aborting — see `comm.checkpoint_*` config keys).
//!
//! Epoch-running subcommands also accept `--trace-dir DIR` (or config
//! `telemetry.trace_dir`): the fabric streams structured events —
//! epoch lifecycle, checkpoint commits, recovery cycles, chaos faults,
//! per-range traffic heat cells — into per-rank JSONL files under DIR,
//! merged into one timeline by `degreesketch trace inspect DIR`.
//! `degreesketch heatmap DIR` rebuilds the per-epoch traffic matrices
//! (cut-edge fraction, per-rank byte skew, hot vertex ranges) from the
//! same trace, and `degreesketch trace export --format chrome` converts
//! it to Chrome trace-event JSON loadable in ui.perfetto.dev. The serve
//! tier joins the same plane: `serve`/`snapshot serve` accept
//! `--trace-dir` plus `--span-sample N` (trace every Nth query's
//! queue/kernel/flush stages), `--slow-query-us US` and
//! `--access-log FILE` (JSONL; slow queries always logged).

// Mirrors the lib crate root: undocumented `unsafe` is a hard error
// (see `tools/dslint`'s safety-comment rule for the offline twin).
#![deny(clippy::undocumented_unsafe_blocks)]

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use degreesketch::cli::Args;
use degreesketch::comm::{Backend, FaultPolicy, FlushPolicy};
use degreesketch::config::Config;
use degreesketch::coordinator::anf::{neighborhood_approximation, AnfOptions};
use degreesketch::coordinator::serve::{loadgen, ConnLimits, ServeOptions};
use degreesketch::coordinator::sketch::{
    accumulate_stream, AccumulateOptions,
};
use degreesketch::coordinator::{
    edge_triangle_heavy_hitters, server::QueryServer,
    vertex_triangle_heavy_hitters, IntersectBackend, QueryEngine,
    TriangleOptions,
};
use degreesketch::graph::csr::Csr;
use degreesketch::graph::gen::GraphSpec;
use degreesketch::graph::stream::{
    write_edge_list, EdgeStream, FileStream, MemoryStream,
};
use degreesketch::graph::{exact, Edge};
use degreesketch::hll::{fit_beta, HllConfig};
use degreesketch::runtime::{default_artifacts_dir, PjrtRuntime, PjrtService};
use degreesketch::snapshot::{MappedSnapshot, SnapshotMode};
use degreesketch::util::stats::mean_relative_error;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.subcommand.is_empty() || args.has("help") {
        print_usage();
        return Ok(());
    }
    let mut config = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::default(),
    };
    if let Some(sets) = args.get("set") {
        for spec in sets.split('\n') {
            config.set_override(spec)?;
        }
    }
    // schema-check the merged file + --set view before any subsystem
    // consumes it: unknown serve./comm./telemetry. keys and type
    // mismatches fail fast here instead of silently defaulting
    config.validate()?;
    let result = match args.subcommand.as_str() {
        "generate" => cmd_generate(&args),
        "accumulate" => cmd_accumulate(&args, &config),
        "worker" => cmd_worker(&args),
        "query" => cmd_query(&args),
        "serve" => cmd_serve(&args, &config),
        "loadgen" => cmd_loadgen(&args),
        "snapshot" => cmd_snapshot(&args, &config),
        "anf" => cmd_anf(&args, &config),
        "triangles" => cmd_triangles(&args, &config),
        "exact" => cmd_exact(&args),
        "calibrate-beta" => cmd_calibrate(&args),
        "trace" => cmd_trace(&args),
        "heatmap" => cmd_heatmap(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown subcommand {other:?} (try --help)"),
    };
    // success or failure, release any tcp fabric so remote workers exit
    // cleanly instead of waiting on a dead driver
    degreesketch::comm::tcp::shutdown_driver();
    result
}

fn print_usage() {
    println!(
        "degreesketch — distributed cardinality sketches on massive graphs\n\
         subcommands: generate accumulate worker query serve loadgen \
         snapshot anf triangles exact calibrate-beta trace heatmap info\n\
         see README.md for full usage"
    );
}

/// Load the edge stream named by `--graph file` or `--spec generator`.
fn load_edges(args: &Args) -> Result<Vec<Edge>> {
    match (args.get("graph"), args.get("spec")) {
        (Some(path), None) => Ok(FileStream::open(path)?.collect_edges()),
        (None, Some(spec)) => {
            let seed = args.get_u64("seed", 42)?;
            let spec = GraphSpec::parse(spec)
                .with_context(|| format!("bad --spec {spec:?}"))?;
            Ok(spec.generate(seed))
        }
        _ => bail!("need exactly one of --graph <file> or --spec <generator>"),
    }
}

fn backend_of(args: &Args, config: &Config) -> Result<Backend> {
    match args.get("backend") {
        Some(s) => {
            Backend::parse(s).with_context(|| format!("bad --backend {s:?}"))
        }
        None => config.backend(),
    }
}

/// Arm the tcp fabric when the chosen backend is `tcp`: bind the
/// registrar at `--listen` (or `comm.listen`), parse the rank →
/// mesh-address map from `--hosts` (or `comm.hosts`), and hand both to
/// the comm plane. The rendezvous itself runs on the first epoch, so
/// workers may be launched before or after the driver.
fn setup_comm_backend(
    args: &Args,
    config: &Config,
    backend: Backend,
    ranks: usize,
) -> Result<()> {
    let listen = args.get("listen").map(str::to_string);
    let hosts_spec = args.get("hosts").map(str::to_string);
    if backend != Backend::Tcp {
        if listen.is_some() || hosts_spec.is_some() {
            bail!("--listen/--hosts only apply to --backend tcp");
        }
        return Ok(());
    }
    let listen = listen
        .unwrap_or_else(|| config.get_str("comm.listen", "").to_string());
    if listen.is_empty() {
        bail!(
            "--backend tcp needs a registrar address: --listen host:port \
             (or comm.listen in the config)"
        );
    }
    let hosts_spec = hosts_spec
        .unwrap_or_else(|| config.get_str("comm.hosts", "").to_string());
    if hosts_spec.is_empty() {
        bail!(
            "--backend tcp needs the worker map: \
             --hosts 0=host:port,1=host:port,... (or comm.hosts)"
        );
    }
    let hosts = degreesketch::comm::tcp::parse_hosts(&hosts_spec, ranks)
        .map_err(anyhow::Error::msg)?;
    let listener = std::net::TcpListener::bind(&listen)
        .with_context(|| format!("binding tcp registrar at {listen:?}"))?;
    println!(
        "tcp fabric: registrar on {} awaiting {ranks} workers",
        listener.local_addr()?
    );
    degreesketch::comm::tcp::configure_driver(listener, hosts);
    Ok(())
}

/// The `worker` subcommand: serve one rank of a tcp fabric until the
/// driver shuts it down. `--ckpt-dir` is where resilient epochs write
/// this rank's checkpoint records; a respawned replacement passes
/// `--resume` with its predecessor's checkpoint dir (or one record
/// file) to resume the interrupted epoch.
fn cmd_worker(args: &Args) -> Result<()> {
    let connect = args.require("connect")?.to_string();
    let rank = args.get_usize("rank", usize::MAX)?;
    if rank == usize::MAX {
        bail!("worker needs --rank N (its rank in the fabric)");
    }
    let deadline =
        std::time::Duration::from_secs(args.get_u64("deadline-secs", 60)?);
    let mut opts = degreesketch::comm::tcp::WorkerOptions {
        deadline,
        ..Default::default()
    };
    if let Some(dir) = args.get("ckpt-dir") {
        opts.ckpt_dir = PathBuf::from(dir);
    }
    if let Some(src) = args.get("resume") {
        opts.resume = Some(PathBuf::from(src));
    }
    // Workers dial the driver (and, on re-mesh, each other); give them the
    // same retry pacing knobs the driver side reads from config.
    let backoff_base = args.get_u64("dial-backoff-base-ms", 25)?;
    let backoff_cap = args.get_u64("dial-backoff-cap-ms", 2000)?;
    if backoff_base == 0 || backoff_cap < backoff_base {
        bail!("--dial-backoff-cap-ms must be >= --dial-backoff-base-ms >= 1");
    }
    degreesketch::comm::rendezvous::set_dial_backoff(backoff_base, backoff_cap);
    args.finish()?;
    eprintln!("worker rank {rank}: joining fabric via {connect}");
    degreesketch::comm::tcp::run_worker_opts(
        degreesketch::coordinator::worker_dispatch(),
        &connect,
        rank,
        opts,
    )
    .map_err(anyhow::Error::msg)?;
    eprintln!("worker rank {rank}: fabric shut down, exiting");
    Ok(())
}

/// Arm the telemetry trace sink when `--trace-dir` (or config
/// `telemetry.trace_dir`) names a directory: the driver and every
/// fabric rank then stream structured events into per-rank JSONL files
/// there, merged afterwards by `degreesketch trace inspect`.
fn telemetry_of(args: &Args, config: &Config) -> Result<()> {
    let dir = args
        .get("trace-dir")
        .or_else(|| config.trace_dir())
        .map(str::to_string);
    if let Some(dir) = dir {
        degreesketch::telemetry::set_trace_dir(Path::new(&dir))
            .with_context(|| format!("arming trace dir {dir:?}"))?;
        eprintln!("telemetry: tracing fabric events under {dir}");
    }
    Ok(())
}

/// Comm-plane flush policy: `comm.*` config keys overridden by
/// `--flush-threshold N` and pinned fixed by `--fixed-flush`.
fn flush_policy_of(args: &Args, config: &Config) -> Result<FlushPolicy> {
    let mut policy = config.flush_policy()?;
    if let Some(raw) = args.get("flush-threshold") {
        let t: usize = raw
            .parse()
            .with_context(|| format!("bad --flush-threshold {raw:?}"))?;
        if t == 0 {
            bail!("--flush-threshold must be positive");
        }
        policy = if policy.adaptive {
            FlushPolicy::adaptive(t)
        } else {
            FlushPolicy::pinned(t)
        };
    }
    if args.has("fixed-flush") {
        policy = FlushPolicy::pinned(policy.threshold);
    }
    Ok(policy)
}

/// Fault-tolerance policy: `comm.*` config keys overridden by
/// `--checkpoint N` (checkpoint every N seed chunks — any nonzero value
/// makes the socket-backend epoch resilient), `--checkpoint-secs M`,
/// `--checkpoint-chunk E` (edges per seed chunk), the recovery caps
/// `--liveness-rearms` / `--max-respawns`, and the liveness probes
/// `--hb-interval-ms` / `--hb-timeout-ms`. Also installs the
/// `comm.dial_backoff_*` retry pacing into the rendezvous dialer.
fn fault_policy_of(args: &Args, config: &Config) -> Result<FaultPolicy> {
    config.apply_dial_backoff()?;
    let mut fault = config.fault_policy()?;
    if let Some(raw) = args.get("checkpoint") {
        fault.ckpt_every_chunks = raw
            .parse()
            .with_context(|| format!("bad --checkpoint {raw:?}"))?;
    }
    if let Some(raw) = args.get("checkpoint-secs") {
        fault.ckpt_secs = raw
            .parse()
            .with_context(|| format!("bad --checkpoint-secs {raw:?}"))?;
    }
    if let Some(raw) = args.get("checkpoint-chunk") {
        let chunk: u64 = raw
            .parse()
            .with_context(|| format!("bad --checkpoint-chunk {raw:?}"))?;
        if chunk == 0 {
            bail!("--checkpoint-chunk must be positive");
        }
        fault.chunk = chunk;
    }
    if let Some(n) = args.get_u64_opt("liveness-rearms")? {
        if n == 0 || n > u32::MAX as u64 {
            bail!("--liveness-rearms must be in 1..={}", u32::MAX);
        }
        fault.rearm_cap = n as u32;
    }
    if let Some(n) = args.get_u64_opt("max-respawns")? {
        if n > u32::MAX as u64 {
            bail!("--max-respawns must be <= {}", u32::MAX);
        }
        fault.max_respawns = n as u32;
    }
    if let Some(ms) = args.get_u64_opt("hb-interval-ms")? {
        fault.hb_interval_ms = ms;
    }
    if let Some(ms) = args.get_u64_opt("hb-timeout-ms")? {
        fault.hb_timeout_ms = ms;
    }
    if fault.hb_interval_ms > 0
        && fault.hb_timeout_ms > 0
        && fault.hb_timeout_ms <= fault.hb_interval_ms
    {
        bail!(
            "--hb-timeout-ms ({}) must exceed --hb-interval-ms ({})",
            fault.hb_timeout_ms,
            fault.hb_interval_ms
        );
    }
    Ok(fault)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let spec_str = args.require("spec")?.to_string();
    let seed = args.get_u64("seed", 42)?;
    let out = args.require("out")?.to_string();
    args.finish()?;
    let spec = GraphSpec::parse(&spec_str)
        .with_context(|| format!("bad --spec {spec_str:?}"))?;
    let edges = spec.generate(seed);
    write_edge_list(&out, &edges)?;
    let csr = Csr::from_edges(&edges);
    println!(
        "wrote {} ({} vertices, {} edges, type {})",
        out,
        csr.num_vertices(),
        csr.num_edges(),
        spec.type_name()
    );
    Ok(())
}

fn cmd_accumulate(args: &Args, config: &Config) -> Result<()> {
    let edges = load_edges(args)?;
    let ranks =
        args.get_usize("ranks", config.get_int("run.ranks", 4) as usize)?;
    let p = args.get_u8("p", config.get_int("hll.p", 8) as u8)?;
    let hash_seed =
        args.get_u64("hash-seed", config.get_int("hll.seed", 0x5EED) as u64)?;
    let out = args.require("out")?.to_string();
    let backend = backend_of(args, config)?;
    let flush = flush_policy_of(args, config)?;
    let fault = fault_policy_of(args, config)?;
    setup_comm_backend(args, config, backend, ranks)?;
    telemetry_of(args, config)?;
    args.finish()?;

    let stream = MemoryStream::new(edges);
    let start = std::time::Instant::now();
    let ds = accumulate_stream(
        &stream,
        ranks,
        HllConfig::new(p, hash_seed),
        AccumulateOptions {
            backend,
            partitioner: config.partitioner()?,
            flush,
            fault,
        },
    );
    let secs = start.elapsed().as_secs_f64();
    println!(
        "accumulated {} vertex sketches on {} ranks ({}) in {:.3}s \
         ({} messages, {} bytes in sketches, {} checkpoints, {} restores)",
        ds.num_vertices(),
        ranks,
        backend.name(),
        secs,
        ds.accumulation_stats.messages,
        ds.memory_bytes(),
        ds.accumulation_stats.checkpoints,
        ds.accumulation_stats.restores
    );
    let engine = QueryEngine::new(ds);
    engine.save(Path::new(&out))?;
    println!("saved to {out}");
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let dir = args.require("sketch")?.to_string();
    args.finish()?;
    let engine = QueryEngine::load(Path::new(&dir))?;
    let pos = &args.positional;
    if pos.is_empty() {
        bail!("usage: query --sketch dir deg <x> | tri <x> <y> | union <x..>");
    }
    let ids: Vec<u64> = pos[1..]
        .iter()
        .map(|s| s.parse::<u64>().context("bad vertex id"))
        .collect::<Result<_>>()?;
    match (pos[0].as_str(), ids.as_slice()) {
        ("deg", [x]) => match engine.degree(*x) {
            Some(d) => println!("deg({x}) ≈ {d:.2}"),
            None => println!("deg({x}): vertex not seen"),
        },
        ("tri", [x, y]) => match engine.intersection(*x, *y) {
            Some(est) => println!(
                "T({x},{y}) ≈ {:.2}  union ≈ {:.2}  jaccard ≈ {:.4}  domination: {:?}",
                est.intersection,
                est.union,
                est.jaccard(),
                est.domination
            ),
            None => println!("T({x},{y}): vertex not seen"),
        },
        ("union", xs) if !xs.is_empty() => match engine.union_cardinality(xs) {
            Some(u) => println!("|∪ adj| ≈ {u:.2}"),
            None => println!("union: no vertex seen"),
        },
        _ => bail!("usage: query --sketch dir deg <x> | tri <x> <y> | union <x..>"),
    }
    Ok(())
}

/// Serving-tier options: config `serve.*` keys as the base, per-run
/// flags on top.
fn serve_options_of(args: &Args, config: &Config) -> Result<ServeOptions> {
    let base = config.serve_options()?;
    Ok(ServeOptions {
        workers: args.get_usize("workers", base.workers)?,
        batch_max: args.get_usize("batch-max", base.batch_max)?,
        cache_capacity: args
            .get_usize("cache-capacity", base.cache_capacity)?,
        pending_cap: args.get_usize("pending-cap", base.pending_cap)?,
        span_sample: args.get_u64("span-sample", base.span_sample)?,
        slow_query_us: args
            .get_u64("slow-query-us", base.slow_query_us)?,
        access_log: args
            .get("access-log")
            .map(PathBuf::from)
            .or(base.access_log),
        limits: ConnLimits {
            read_timeout: std::time::Duration::from_millis(args.get_u64(
                "read-timeout-ms",
                base.limits.read_timeout.as_millis() as u64,
            )?),
            idle_cap: std::time::Duration::from_secs(
                args.get_u64("idle-secs", base.limits.idle_cap.as_secs())?,
            ),
        },
    })
}

fn print_serving(server: &QueryServer, opts: &ServeOptions) {
    println!("serving DegreeSketch queries on {}", server.addr());
    println!(
        "serving tier: {} workers, batch_max={}, cache={} entries, \
         pending_cap={}",
        opts.resolved_workers(),
        opts.batch_max,
        opts.cache_capacity,
        opts.pending_cap
    );
    println!(
        "protocol: DEG x | TRI x y | JACCARD x y | UNION x.. | \
         STATS | METRICS | RELOAD [path] | QUIT"
    );
}

fn cmd_serve(args: &Args, config: &Config) -> Result<()> {
    let dir = args.require("sketch")?.to_string();
    let addr = args.get_or("addr", "127.0.0.1:7171").to_string();
    let opts = serve_options_of(args, config)?;
    telemetry_of(args, config)?;
    args.finish()?;
    let engine = Arc::new(QueryEngine::load(Path::new(&dir))?);
    println!(
        "loaded {} vertices (backing={}, heap={}B, mapped={}B)",
        engine.num_vertices(),
        engine.backing_mode(),
        engine.heap_bytes(),
        engine.resident_bytes()
    );
    let server = QueryServer::start_with_opts(engine, &addr, opts.clone())?;
    print_serving(&server, &opts);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let defaults = loadgen::LoadgenOptions::default();
    let hot_fraction = match args.get("hot-fraction") {
        Some(s) => s
            .parse::<f64>()
            .with_context(|| format!("bad --hot-fraction {s:?}"))?,
        None => defaults.hot_fraction,
    };
    let max_p99_ms = match args.get("max-p99-ms") {
        Some(s) => Some(
            s.parse::<f64>()
                .with_context(|| format!("bad --max-p99-ms {s:?}"))?,
        ),
        None => None,
    };
    let opts = loadgen::LoadgenOptions {
        addr: args.get_or("addr", &defaults.addr).to_string(),
        connections: args.get_usize("connections", defaults.connections)?,
        requests: args.get_u64("requests", defaults.requests)?,
        threads: args.get_usize("threads", defaults.threads)?,
        hot_vertices: args.get_usize("hot-vertices", defaults.hot_vertices)?,
        hot_fraction,
        seed: args.get_u64("seed", defaults.seed)?,
        live_reload: args.has("live-reload"),
        out: args.get("out").map(PathBuf::from),
        max_p99_ms,
    };
    args.finish()?;
    println!(
        "loadgen: {} connections, {} requests against {} \
         (hot set {} @ {:.0}%{})",
        opts.connections,
        opts.requests,
        opts.addr,
        opts.hot_vertices,
        opts.hot_fraction * 100.0,
        if opts.live_reload { ", live reload at halfway" } else { "" }
    );
    let report = loadgen::run(&opts)?;
    println!(
        "done: {} ok / {} errors in {:.2}s — {:.0} qps",
        report.responses_ok,
        report.errors,
        report.elapsed.as_secs_f64(),
        report.qps
    );
    println!(
        "latency p50={}us p90={}us p99={}us; cache hit rate {:.1}% \
         ({} hits / {} misses), shed={}",
        report.p50_us,
        report.p90_us,
        report.p99_us,
        report.cache_hit_rate() * 100.0,
        report.cache_hits,
        report.cache_misses,
        report.shed
    );
    if report.reloaded {
        println!(
            "live reload: generation {} -> {}",
            report.generation_start, report.generation_end
        );
    }
    if let Some(out) = &opts.out {
        println!("wrote {}", out.display());
    }
    if report.errors > 0 {
        bail!("{} requests failed", report.errors);
    }
    Ok(())
}

fn parse_snapshot_mode(args: &Args) -> Result<SnapshotMode> {
    match args.get_or("mode", "auto") {
        "auto" => Ok(SnapshotMode::Auto),
        "mmap" => Ok(SnapshotMode::Mmap),
        "heap" => Ok(SnapshotMode::Heap),
        other => bail!("bad --mode {other:?} (auto|mmap|heap)"),
    }
}

fn cmd_snapshot(args: &Args, config: &Config) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("");
    match action {
        "create" => {
            let out = args.require("out")?.to_string();
            let stats = if let Some(dir) = args.get("sketch") {
                // migrate a legacy shard directory without re-accumulating
                let dir = dir.to_string();
                args.finish()?;
                QueryEngine::migrate_legacy(Path::new(&dir), Path::new(&out))?
            } else {
                let edges = load_edges(args)?;
                let ranks = args
                    .get_usize("ranks", config.get_int("run.ranks", 4) as usize)?;
                let p = args.get_u8("p", config.get_int("hll.p", 8) as u8)?;
                let hash_seed = args.get_u64(
                    "hash-seed",
                    config.get_int("hll.seed", 0x5EED) as u64,
                )?;
                let backend = backend_of(args, config)?;
                let flush = flush_policy_of(args, config)?;
                let fault = fault_policy_of(args, config)?;
                setup_comm_backend(args, config, backend, ranks)?;
                telemetry_of(args, config)?;
                args.finish()?;
                let ds = accumulate_stream(
                    &MemoryStream::new(edges),
                    ranks,
                    HllConfig::new(p, hash_seed),
                    AccumulateOptions {
                        backend,
                        partitioner: config.partitioner()?,
                        flush,
                        fault,
                    },
                );
                QueryEngine::new(ds).save_snapshot(Path::new(&out))?
            };
            println!(
                "wrote {out}: {} bytes, {} vertices ({} dense sketches, \
                 {} sparse pairs)",
                stats.file_len,
                stats.vertices,
                stats.dense_sketches,
                stats.sparse_pairs
            );
            Ok(())
        }
        "inspect" => {
            let file = args.require("file")?.to_string();
            let mode = parse_snapshot_mode(args)?;
            let want_verify = args.has("verify");
            args.finish()?;
            let t0 = std::time::Instant::now();
            let snap = MappedSnapshot::open_with(Path::new(&file), mode)?;
            let open_s = t0.elapsed().as_secs_f64();
            println!(
                "{file}: v{} {} bytes mode={} open={open_s:.6}s",
                degreesketch::snapshot::VERSION,
                snap.resident_bytes(),
                snap.mode()
            );
            println!(
                "p={} seed={:#x} ranks={} vertices={} dense={}",
                snap.config().p(),
                snap.config().hasher().seed(),
                snap.num_ranks(),
                snap.num_vertices(),
                snap.num_dense_sketches()
            );
            for (rank, s) in snap.rank_stats().iter().enumerate() {
                println!(
                    "  rank {rank}: vertices={} dense={} sparse_pairs={} \
                     payload={}B",
                    s.vertex_count, s.dense_count, s.sparse_pairs,
                    s.payload_bytes
                );
            }
            if want_verify {
                snap.verify()?;
                println!("payload CRCs: OK");
            }
            Ok(())
        }
        "serve" => {
            let file = args.require("file")?.to_string();
            let addr = args.get_or("addr", "127.0.0.1:7171").to_string();
            let mode = parse_snapshot_mode(args)?;
            let self_check = args.has("self-check");
            let mut opts = serve_options_of(args, config)?;
            if self_check {
                // one worker makes batch formation observable: while it
                // chews the first request, the rest of a pipelined burst
                // queues up and drains as one batch
                opts.workers = 1;
            }
            telemetry_of(args, config)?;
            args.finish()?;
            let engine = Arc::new(QueryEngine::open_snapshot_with(
                Path::new(&file),
                mode,
            )?);
            println!(
                "snapshot {} backing={} resident={}B",
                file,
                engine.backing_mode(),
                engine.resident_bytes()
            );
            let server =
                QueryServer::start_with_opts(engine, &addr, opts.clone())?;
            print_serving(&server, &opts);
            if self_check {
                self_check_serving(&server)?;
                server.stop();
                println!("self-check OK");
                return Ok(());
            }
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        other => {
            bail!("snapshot action must be create|inspect|serve, got {other:?}")
        }
    }
}

/// Read one METRICS exposition from a live server (through `# EOF`).
fn scrape_metrics(addr: std::net::SocketAddr) -> Result<String> {
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    writeln!(w, "METRICS")?;
    let mut text = String::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("server closed before # EOF in METRICS");
        }
        text.push_str(&line);
        if line.trim_end() == "# EOF" {
            break;
        }
    }
    writeln!(w, "QUIT").ok();
    Ok(text)
}

/// The value of an unlabeled series in an exposition, if present.
fn metric_value(text: &str, series: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        l.strip_prefix(series)?.strip_prefix(' ')?.trim().parse().ok()
    })
}

/// The CI serving probe: basic verbs, a valid METRICS exposition, and
/// proof that the batched path actually forms batches (>1) under a
/// pipelined burst.
fn self_check_serving(server: &QueryServer) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let addr = server.addr();
    let stream = std::net::TcpStream::connect(addr)?;
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    for probe in ["STATS", "DEG 0"] {
        writeln!(w, "{probe}")?;
        let mut resp = String::new();
        r.read_line(&mut resp)?;
        println!("self-check {probe} -> {}", resp.trim());
    }
    writeln!(w, "QUIT")?;
    let mut resp = String::new();
    r.read_line(&mut resp)?;
    println!("self-check QUIT -> {}", resp.trim());

    // The batched path: pipeline bursts of distinct queries (fresh ids
    // each round, so every one misses the cache and queues) until the
    // worker pool demonstrably drained >= 2 requests in one batch. With
    // the single self-check worker, the burst queues while the worker
    // chews its first request — a batch forms almost immediately.
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(30);
    let mut round = 0u64;
    let batch_max = loop {
        round += 1;
        let stream = std::net::TcpStream::connect(addr)?;
        let mut w = stream.try_clone()?;
        let mut r = BufReader::new(stream);
        let mut burst = String::new();
        for i in 0..32u64 {
            burst.push_str(&format!("DEG {}\n", round * 100_000 + i));
        }
        w.write_all(burst.as_bytes())?;
        w.flush()?;
        for _ in 0..32 {
            let mut line = String::new();
            if r.read_line(&mut line)? == 0 {
                bail!("server closed mid-burst");
            }
        }
        writeln!(w, "QUIT").ok();
        let text = scrape_metrics(addr)?;
        match metric_value(&text, "degreesketch_query_batch_max") {
            Some(v) if v >= 2.0 => break v,
            _ if std::time::Instant::now() > deadline => {
                bail!("batched path never formed a batch > 1")
            }
            _ => {}
        }
    };
    // full exposition check, with the batch histogram now non-empty
    let text = scrape_metrics(addr)?;
    let samples = degreesketch::telemetry::prom::check_text(&text)
        .map_err(anyhow::Error::msg)
        .context("self-check METRICS invalid")?;
    let batches = metric_value(&text, "degreesketch_query_batch_size_count")
        .unwrap_or(0.0);
    if batches < 1.0 {
        bail!("batch-size histogram empty after burst:\n{text}");
    }
    println!(
        "self-check METRICS -> {samples} samples, valid; {batches} \
         batches drained, max batch {batch_max}"
    );
    Ok(())
}

fn cmd_anf(args: &Args, config: &Config) -> Result<()> {
    let edges = load_edges(args)?;
    let ranks =
        args.get_usize("ranks", config.get_int("run.ranks", 4) as usize)?;
    let p = args.get_u8("p", config.get_int("hll.p", 8) as u8)?;
    let max_t = args.get_usize("max-t", 5)?;
    let backend = backend_of(args, config)?;
    let flush = flush_policy_of(args, config)?;
    let fault = fault_policy_of(args, config)?;
    setup_comm_backend(args, config, backend, ranks)?;
    telemetry_of(args, config)?;
    let want_exact = args.has("exact");
    args.finish()?;

    let stream = MemoryStream::new(edges.clone());
    let cfg = HllConfig::new(p, config.get_int("hll.seed", 0x5EED) as u64);
    let t0 = std::time::Instant::now();
    let ds = accumulate_stream(
        &stream,
        ranks,
        cfg,
        AccumulateOptions {
            backend,
            partitioner: config.partitioner()?,
            flush,
            fault,
        },
    );
    let accum_s = t0.elapsed().as_secs_f64();
    let shards = stream.shard(ranks);
    let res = neighborhood_approximation(
        &ds,
        &shards,
        AnfOptions {
            backend,
            max_t,
            estimator: config.estimator()?,
            keep_layers: false,
            flush,
            fault,
        },
    );
    println!("accumulation: {accum_s:.3}s");
    for (t, g) in res.global.iter().enumerate() {
        let pass_s = if t == 0 { 0.0 } else { res.pass_seconds[t - 1] };
        println!("t={} Ñ(t)={g:.1} pass={pass_s:.3}s", t + 1);
    }
    if want_exact {
        let csr = Csr::from_edges(&edges);
        let truth = exact::neighborhood_sizes(&csr, max_t);
        for t in 1..=max_t {
            let pairs: Vec<(f64, f64)> = (0..csr.num_vertices() as u32)
                .map(|v| {
                    let tr = if t == 1 {
                        csr.degree(v) as f64
                    } else {
                        truth[v as usize][t - 1] as f64
                    };
                    (tr, res.per_vertex[&csr.original_id(v)][t - 1])
                })
                .collect();
            println!("t={t} MRE={:.4}", mean_relative_error(&pairs));
        }
    }
    Ok(())
}

fn cmd_triangles(args: &Args, config: &Config) -> Result<()> {
    let mode = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("edge");
    let edges = load_edges(args)?;
    let ranks =
        args.get_usize("ranks", config.get_int("run.ranks", 4) as usize)?;
    let p = args.get_u8("p", config.get_int("hll.p", 12) as u8)?;
    let k = args.get_usize("k", config.get_int("triangles.k", 100) as usize)?;
    let backend = backend_of(args, config)?;
    let flush = flush_policy_of(args, config)?;
    let fault = fault_policy_of(args, config)?;
    let intersect_kind = args.get_or("intersect", "mle").to_string();
    let want_exact = args.has("exact");
    let discard = args.has("discard-dominated")
        || config.get_bool("triangles.discard_dominated", false);
    setup_comm_backend(args, config, backend, ranks)?;
    telemetry_of(args, config)?;
    args.finish()?;
    if matches!(backend, Backend::Process | Backend::Tcp)
        && intersect_kind == "pjrt"
    {
        bail!(
            "--intersect pjrt cannot run on --backend {} (the PJRT \
             service cannot be shared across worker processes); \
             use mle or ix",
            backend.name()
        );
    }

    // keep the PJRT service alive for the whole run
    let mut _service_keepalive: Option<PjrtService> = None;
    let intersect = match intersect_kind.as_str() {
        "mle" => IntersectBackend::default(),
        "ix" | "inclusion-exclusion" => IntersectBackend::InclusionExclusion,
        "pjrt" => {
            let service = PjrtService::start(&default_artifacts_dir())?;
            let handle = Arc::new(service.handle());
            _service_keepalive = Some(service);
            IntersectBackend::Batched {
                batch: 256,
                exec: handle,
            }
        }
        other => bail!("bad --intersect {other:?} (mle|ix|pjrt)"),
    };

    let stream = MemoryStream::new(edges.clone());
    let cfg = HllConfig::new(p, config.get_int("hll.seed", 0x5EED) as u64);
    let t0 = std::time::Instant::now();
    let ds = Arc::new(accumulate_stream(
        &stream,
        ranks,
        cfg,
        AccumulateOptions {
            backend,
            partitioner: config.partitioner()?,
            flush,
            fault,
        },
    ));
    let accum_s = t0.elapsed().as_secs_f64();
    let shards = stream.shard(ranks);
    let opts = TriangleOptions {
        backend,
        k,
        intersect,
        discard_dominated: discard,
        flush,
        fault,
    };

    println!("accumulation: {accum_s:.3}s");
    match mode {
        "edge" => {
            let res = edge_triangle_heavy_hitters(&ds, &shards, &opts);
            println!(
                "T~ = {:.1}  ({} pairs, {} dominated, {:.3}s)",
                res.global_estimate,
                res.pairs_estimated,
                res.pairs_dominated,
                res.seconds
            );
            for (est, (u, v)) in res.heavy_hitters.iter().take(k.min(20)) {
                println!("  ({u},{v})  T~ ≈ {est:.1}");
            }
            if want_exact {
                let csr = Csr::from_edges(&edges);
                println!("exact T = {}", exact::global_triangles(&csr));
            }
        }
        "vertex" => {
            let res = vertex_triangle_heavy_hitters(&ds, &shards, &opts);
            println!(
                "T~ = {:.1}  ({} pairs, {} dominated, {:.3}s)",
                res.global_estimate,
                res.pairs_estimated,
                res.pairs_dominated,
                res.seconds
            );
            for (est, v) in res.heavy_hitters.iter().take(k.min(20)) {
                println!("  v={v}  T~ ≈ {est:.1}");
            }
            if want_exact {
                let csr = Csr::from_edges(&edges);
                println!("exact T = {}", exact::global_triangles(&csr));
            }
        }
        other => bail!("triangles mode must be edge|vertex, got {other:?}"),
    }
    Ok(())
}

fn cmd_exact(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("triangles");
    let edges = load_edges(args)?;
    let max_t = args.get_usize("max-t", 5)?;
    args.finish()?;
    let csr = Csr::from_edges(&edges);
    match what {
        "triangles" => {
            println!(
                "|V|={} |E|={} T={}",
                csr.num_vertices(),
                csr.num_edges(),
                exact::global_triangles(&csr)
            );
        }
        "neighborhoods" => {
            let ns = exact::neighborhood_sizes(&csr, max_t);
            let g = exact::global_neighborhood(&ns);
            for (t, total) in g.iter().enumerate() {
                println!("t={} N(t)={total}", t + 1);
            }
        }
        other => {
            bail!("exact mode must be triangles|neighborhoods, got {other:?}")
        }
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let p = args.get_u8("p", 8)?;
    args.finish()?;
    let max_n = (1u64 << p) * 12;
    let (points, trials) = if p <= 10 { (36, 10) } else { (28, 5) };
    let c = fit_beta(p, points, trials, max_n, 0xBE7A + p as u64);
    println!(
        "({p}, [{}]),",
        c.iter()
            .map(|x| format!("{x:.9}"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!("paste into BETA_TABLE in rust/src/hll/beta.rs");
    Ok(())
}

/// `trace inspect <dir>` merges the per-rank JSONL streams a traced run
/// wrote under `--trace-dir` into one fabric timeline and prints it,
/// followed by per-kind event counts and the driver's quiescent-barrier
/// dwell times (`--json` prints the machine-readable summary instead).
/// `trace export <dir> --format chrome [--out FILE]` converts the same
/// timeline to Chrome trace-event JSON, loadable in `chrome://tracing`
/// or ui.perfetto.dev (one track per rank, one per serve worker).
fn cmd_trace(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("");
    if action != "inspect" && action != "export" {
        bail!("trace action must be inspect|export, got {action:?}");
    }
    let dir = match args.positional.get(1) {
        Some(d) => d.clone(),
        None => args.require("dir")?.to_string(),
    };
    if action == "export" {
        let format = args.get_or("format", "chrome").to_string();
        if format != "chrome" {
            bail!("trace export --format must be chrome, got {format:?}");
        }
        let out = args.get("out").map(String::from);
        args.finish()?;
        let tl = degreesketch::telemetry::Timeline::merge_dir(Path::new(&dir))
            .with_context(|| format!("merging trace streams in {dir:?}"))?;
        if tl.events.is_empty() {
            bail!("no trace events under {dir:?} (was the run traced?)");
        }
        let json = degreesketch::telemetry::export::chrome_trace(&tl);
        match out {
            Some(path) => {
                std::fs::write(&path, &json)
                    .with_context(|| format!("writing {path}"))?;
                println!(
                    "wrote {path}: {} events as Chrome trace JSON \
                     ({} bytes) — load in ui.perfetto.dev",
                    tl.events.len(),
                    json.len()
                );
            }
            None => println!("{json}"),
        }
        return Ok(());
    }
    let limit = args.get_usize("limit", 1000)?;
    let as_json = args.has("json");
    args.finish()?;
    let tl = degreesketch::telemetry::Timeline::merge_dir(Path::new(&dir))
        .with_context(|| format!("merging trace streams in {dir:?}"))?;
    if as_json {
        // machine-readable: stable key order, one JSON object, nothing else
        println!("{}", tl.summary_json());
        return Ok(());
    }
    if tl.events.is_empty() {
        bail!("no trace events under {dir:?} (was the run traced?)");
    }
    let rendered = tl.render();
    let mut shown = 0usize;
    for line in rendered.lines() {
        if shown >= limit {
            println!("... ({} more events; raise --limit)", tl.events.len() - shown);
            break;
        }
        println!("{line}");
        shown += 1;
    }
    println!(
        "-- {} events, {} malformed lines, truncated={}",
        tl.events.len(),
        tl.malformed,
        tl.truncated
    );
    for (kind, n) in tl.counts_by_kind() {
        println!("   {kind}: {n}");
    }
    let dwells = tl.barrier_dwells_us();
    if !dwells.is_empty() {
        for (i, us) in dwells.iter().enumerate() {
            println!("barrier {}: dwell {us}us", i + 1);
        }
    }
    Ok(())
}

/// `heatmap <dir>`: rebuild the per-epoch traffic matrices from the
/// `heat.cell`/`heat.epoch` events of a traced run and print, per
/// epoch: total messages/bytes, the cut-edge byte fraction, per-rank
/// byte skew, the src×dst byte matrix, and the top `--top` hottest
/// cross-rank vertex ranges.
fn cmd_heatmap(args: &Args) -> Result<()> {
    let dir = match args.positional.first() {
        Some(d) => d.clone(),
        None => args.require("dir")?.to_string(),
    };
    let top = args.get_usize("top", 8)?;
    args.finish()?;
    let tl = degreesketch::telemetry::Timeline::merge_dir(Path::new(&dir))
        .with_context(|| format!("merging trace streams in {dir:?}"))?;
    print!(
        "{}",
        degreesketch::telemetry::heatmap::render_report(&tl, top)
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    let dir: PathBuf = default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match PjrtRuntime::open(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("supported p: {:?}", rt.manifest().supported_p());
            for e in rt.manifest().entries() {
                println!(
                    "  {} kind={:?} p={} r={} batch={} ({})",
                    e.name, e.kind, e.p, e.r, e.batch, e.file
                );
            }
        }
        Err(e) => println!("artifacts unavailable: {e:#}"),
    }
    Ok(())
}
