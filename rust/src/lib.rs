//! # DegreeSketch
//!
//! A reproduction of *"DegreeSketch: Distributed Cardinality Sketches on
//! Massive Graphs with Applications"* (Benjamin W. Priest, 2020) as a
//! three-layer rust + JAX/Pallas system:
//!
//! * **Layer 3 (this crate)** — the distributed coordinator: a YGM-like
//!   buffered message-passing runtime ([`comm`]), the DegreeSketch
//!   algorithms ([`coordinator`]: accumulation, neighborhood approximation,
//!   triangle-count heavy hitters), HLL sketches ([`hll`]), graph
//!   generators + exact baselines ([`graph`]).
//! * **Layer 2/1 (python, build-time only)** — batched cardinality and
//!   joint-MLE intersection estimation lowered AOT to HLO text and executed
//!   from rust via PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! reproduced tables/figures.

// Every `unsafe` block/impl in this crate must carry a `// SAFETY:`
// comment; enforced twice — by clippy here and by `tools/dslint`'s
// safety-comment rule (which also runs offline, without a toolchain).
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod bench_util;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod hash;
pub mod hll;
pub mod metrics;
pub mod runtime;
pub mod snapshot;
pub mod telemetry;
pub mod util;
