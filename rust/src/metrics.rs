//! Compat shim over [`crate::telemetry`] for the old `--metrics`
//! surface: named counters and wall timers with the original
//! `counter k = v` / `timer k = vs` report format.
//!
//! The previous implementation took a mutex on *every* increment (and
//! its fast path re-acquired the same lock it had just released — the
//! classic check-then-act double-lock). Counters are now backed by a
//! private [`telemetry::Registry`], so an increment is one shard lookup
//! plus a relaxed atomic add, and handles can be cached for hot loops.

use crate::telemetry::{Counter, Registry, SampleValue};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// A process-wide metrics registry (cheap atomic counters + wall timers).
#[derive(Default)]
pub struct Metrics {
    counters: Registry,
    timers: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `delta` to a named counter (single lock acquisition to
    /// resolve the series, lock-free add after).
    pub fn count(&self, name: &str, delta: u64) {
        self.counters.counter(name, &[]).add(delta);
    }

    /// A cacheable handle for hot loops: increments through it touch no
    /// lock at all.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.counter(name, &[])
    }

    /// Time a closure and record its wall seconds under `name` (summed).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        let secs = start.elapsed().as_secs_f64();
        *self
            .timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0.0) += secs;
        out
    }

    /// Snapshot all counters.
    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.counters
            .snapshot()
            .into_iter()
            .filter_map(|s| match s.value {
                SampleValue::Counter(v) => Some((s.name, v)),
                _ => None,
            })
            .collect()
    }

    /// Snapshot all timers (seconds).
    pub fn timers(&self) -> BTreeMap<String, f64> {
        self.timers.lock().unwrap().clone()
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.timers() {
            out.push_str(&format!("timer   {k} = {v:.6}s\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("edges", 5);
        m.count("edges", 7);
        m.count("other", 1);
        assert_eq!(m.counters()["edges"], 12);
        assert_eq!(m.counters()["other"], 1);
    }

    #[test]
    fn timers_sum_and_return_value() {
        let m = Metrics::new();
        let x = m.time("work", || 42);
        assert_eq!(x, 42);
        m.time("work", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.timers()["work"] > 0.0);
        assert!(m.report().contains("counter") || m.report().contains("timer"));
    }

    #[test]
    fn cached_handles_and_concurrent_counts() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let c = m.counter("hot");
                for _ in 0..5_000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counters()["hot"], 20_000);
        assert!(m.report().contains("counter hot = 20000"));
    }
}
