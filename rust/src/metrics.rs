//! Lightweight metrics: named counters and timers for the coordinator's
//! observability surface (printed by the CLI with `--metrics`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A process-wide metrics registry (cheap atomic counters + wall timers).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    timers: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `delta` to a named counter.
    pub fn count(&self, name: &str, delta: u64) {
        let map = self.counters.lock().unwrap();
        if let Some(c) = map.get(name) {
            c.fetch_add(delta, Ordering::Relaxed);
            return;
        }
        drop(map);
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    /// Time a closure and record its wall seconds under `name` (summed).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        let secs = start.elapsed().as_secs_f64();
        *self
            .timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0.0) += secs;
        out
    }

    /// Snapshot all counters.
    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Snapshot all timers (seconds).
    pub fn timers(&self) -> BTreeMap<String, f64> {
        self.timers.lock().unwrap().clone()
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, v) in self.timers() {
            out.push_str(&format!("timer   {k} = {v:.6}s\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("edges", 5);
        m.count("edges", 7);
        m.count("other", 1);
        assert_eq!(m.counters()["edges"], 12);
        assert_eq!(m.counters()["other"], 1);
    }

    #[test]
    fn timers_sum_and_return_value() {
        let m = Metrics::new();
        let x = m.time("work", || 42);
        assert_eq!(x, 42);
        m.time("work", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.timers()["work"] > 0.0);
        assert!(m.report().contains("counter") || m.report().contains("timer"));
    }
}
