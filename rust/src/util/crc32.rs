//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
//! guarding snapshot headers and section payloads. Implemented here
//! because crates.io is unreachable in the build environment; slice-by-4
//! table lookups keep it fast enough to cover multi-gigabyte arenas during
//! `snapshot inspect --verify` without dominating wall-clock.

const POLY: u32 = 0xEDB8_8320;

/// Four 256-entry tables (slice-by-4), built at compile time.
const TABLES: [[u32; 256]; 4] = build_tables();

const fn build_tables() -> [[u32; 256]; 4] {
    let mut t = [[0u32; 256]; 4];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            k += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut j = 1;
    while j < 4 {
        let mut i = 0;
        while i < 256 {
            let prev = t[j - 1][i];
            t[j][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        j += 1;
    }
    t
}

/// Streaming CRC-32 state: `update` over chunks, `finish` for the digest.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        let mut chunks = bytes.chunks_exact(4);
        for c in chunks.by_ref() {
            let x = crc ^ u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            crc = TABLES[3][(x & 0xFF) as usize]
                ^ TABLES[2][((x >> 8) & 0xFF) as usize]
                ^ TABLES[1][((x >> 16) & 0xFF) as usize]
                ^ TABLES[0][(x >> 24) as usize];
        }
        for &b in chunks.remainder() {
            crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finish(self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical IEEE CRC-32 test vectors
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7) as u8).collect();
        for split in [0, 1, 3, 499, 999, 1000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32(&data), "split {split}");
        }
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        data[17] ^= 0x10;
        assert_ne!(crc32(&data), base);
    }
}
