//! Mini property-test runner (proptest is unavailable offline).
//!
//! [`Cases`] runs a closure over `n` seeded RNG streams. Failures print the
//! case seed so a failing property is reproducible with
//! `Cases::replay(name, seed)`. This deliberately has no shrinking — cases
//! are kept small instead.

use crate::hash::Xoshiro256ss;

/// A batch of seeded property-test cases.
pub struct Cases {
    name: &'static str,
    n: u64,
    base_seed: u64,
}

impl Cases {
    /// `n` cases derived from the test name (stable across runs).
    pub fn new(name: &'static str, n: u64) -> Self {
        let base_seed = crate::hash::xxh64(name.as_bytes(), 0x5EED);
        Self { name, n, base_seed }
    }

    /// Override the base seed (for replaying a reported failure).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Run the property for each case; panics with the case seed on failure.
    pub fn run<F: FnMut(&mut Xoshiro256ss)>(&self, mut property: F) {
        for i in 0..self.n {
            let seed = self.base_seed.wrapping_add(i);
            let mut rng = Xoshiro256ss::new(seed);
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| property(&mut rng)),
            );
            if let Err(err) = result {
                eprintln!(
                    "property '{}' failed at case {i} (seed {seed:#x}); \
                     replay with Cases::new(\"{}\", 1).with_seed({seed:#x})",
                    self.name, self.name
                );
                std::panic::resume_unwind(err);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        Cases::new("counter", 17).run(|_| count += 1);
        assert_eq!(count, 17);
    }

    #[test]
    fn seeds_are_stable() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        Cases::new("stable", 5).run(|rng| a.push(rng.next_u64()));
        Cases::new("stable", 5).run(|rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        Cases::new("fails", 3).run(|_| panic!("boom"));
    }
}
