//! Error metrics and summary statistics shared by tests and benches.

/// Relative error |T - E| / |T| (paper §5 "Experiments"). Returns the
/// absolute estimate when the truth is zero, matching the paper's MRE
/// convention of skipping zero-truth entries upstream.
#[inline]
pub fn relative_error(truth: f64, estimate: f64) -> f64 {
    if truth == 0.0 {
        estimate.abs()
    } else {
        (truth - estimate).abs() / truth.abs()
    }
}

/// Mean relative error over (truth, estimate) pairs with nonzero truth.
pub fn mean_relative_error(pairs: &[(f64, f64)]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for &(t, e) in pairs {
        if t != 0.0 {
            sum += relative_error(t, e);
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Summary of a sample: mean / std / min / max / percentiles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

/// Precision / recall of a predicted top-k set vs ground truth (paper §5,
/// Figure 2's one-class-classifier framing).
pub fn precision_recall<T: Eq + std::hash::Hash>(
    truth: &std::collections::HashSet<T>,
    predicted: &std::collections::HashSet<T>,
) -> (f64, f64) {
    let tp = predicted.intersection(truth).count() as f64;
    let precision = if predicted.is_empty() {
        1.0
    } else {
        tp / predicted.len() as f64
    };
    let recall = if truth.is_empty() {
        1.0
    } else {
        tp / truth.len() as f64
    };
    (precision, recall)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn relative_error_basics() {
        assert_eq!(relative_error(100.0, 90.0), 0.1);
        assert_eq!(relative_error(100.0, 110.0), 0.1);
        assert_eq!(relative_error(0.0, 3.0), 3.0);
    }

    #[test]
    fn mre_skips_zero_truth() {
        let mre = mean_relative_error(&[(0.0, 5.0), (10.0, 11.0)]);
        assert!((mre - 0.1).abs() < 1e-12);
    }

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99.0);
    }

    #[test]
    fn precision_recall_basics() {
        let truth: HashSet<u32> = [1, 2, 3, 4].into_iter().collect();
        let pred: HashSet<u32> = [3, 4, 5].into_iter().collect();
        let (p, r) = precision_recall(&truth, &pred);
        assert!((p - 2.0 / 3.0).abs() < 1e-12);
        assert!((r - 0.5).abs() < 1e-12);
    }
}
