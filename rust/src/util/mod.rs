//! Small shared utilities: the mini property-test runner, stats helpers,
//! and the CRC-32 used by the snapshot format.

pub mod crc32;
pub mod prop;
pub mod stats;
