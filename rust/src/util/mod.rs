//! Small shared utilities: the mini property-test runner and stats helpers.

pub mod prop;
pub mod stats;
