//! Configuration system: a TOML-subset file format plus CLI overrides.
//!
//! (serde/toml are unavailable offline, so we parse the subset we need:
//! `[section]` headers, `key = value` pairs with string / integer / float /
//! boolean values, `#` comments.) The CLI accepts `--config path` and any
//! `--set section.key=value` overrides.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::comm::{Backend, FaultPolicy, FlushPolicy};
use crate::coordinator::serve::{ConnLimits, ServeOptions};
use crate::coordinator::Partitioner;
use crate::hll::Estimator;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Result<Value> {
        let s = raw.trim();
        if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
            return Ok(Value::Str(s[1..s.len() - 1].to_string()));
        }
        if s == "true" {
            return Ok(Value::Bool(true));
        }
        if s == "false" {
            return Ok(Value::Bool(false));
        }
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("unparseable value {s:?} (strings need quotes)")
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A flat `section.key → value` map with typed getters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    bail!("line {}: malformed section {line:?}", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {line:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(
                key,
                Value::parse(v).with_context(|| format!("line {}", lineno + 1))?,
            );
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| path.display().to_string())
    }

    /// Apply a `section.key=value` override string (CLI `--set`).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let Some((k, v)) = spec.split_once('=') else {
            bail!("override must be key=value, got {spec:?}");
        };
        self.values.insert(k.trim().to_string(), Value::parse(v)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_int(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn get_float(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Typed convenience getters for the common coordinator knobs.
    pub fn backend(&self) -> Result<Backend> {
        let s = self.get_str("run.backend", "sequential");
        Backend::parse(s).with_context(|| format!("bad run.backend {s:?}"))
    }

    pub fn partitioner(&self) -> Result<Partitioner> {
        let s = self.get_str("run.partitioner", "round-robin");
        Partitioner::parse(s).with_context(|| format!("bad run.partitioner {s:?}"))
    }

    pub fn estimator(&self) -> Result<Estimator> {
        let s = self.get_str("hll.estimator", "ertl");
        Estimator::parse(s).with_context(|| format!("bad hll.estimator {s:?}"))
    }

    /// Comm-plane flush policy: `comm.flush_threshold` seeds the
    /// per-destination thresholds; `comm.adaptive_flush = false` pins
    /// them (the deterministic-bench escape hatch). The tcp fabric also
    /// reads `comm.listen` (registrar address) and `comm.hosts`
    /// (`"0=host:port,1=host:port,..."`) when `run.backend = "tcp"`.
    pub fn flush_policy(&self) -> Result<FlushPolicy> {
        let default = FlushPolicy::default();
        let threshold =
            self.get_int("comm.flush_threshold", default.threshold as i64);
        if threshold <= 0 {
            bail!("comm.flush_threshold must be positive, got {threshold}");
        }
        Ok(if self.get_bool("comm.adaptive_flush", default.adaptive) {
            FlushPolicy::adaptive(threshold as usize)
        } else {
            FlushPolicy::pinned(threshold as usize)
        })
    }

    /// Fault-tolerance policy for socket-backend epochs:
    /// `comm.checkpoint_interval` (checkpoint every N seed chunks; 0 =
    /// off), `comm.checkpoint_secs` (time trigger; 0 = off),
    /// `comm.checkpoint_chunk` (edges per seed chunk),
    /// `comm.liveness_rearms` (cap on control-deadline re-arms before a
    /// silent worker is declared dead) and `comm.max_respawns` (recovery
    /// generations per epoch). Liveness probing is driven by
    /// `comm.hb_interval_ms` (send a heartbeat on a mesh channel after
    /// this much idle time; 0 = off) and `comm.hb_timeout_ms` (declare a
    /// peer link stale after this much silence; 0 = off, and must exceed
    /// the interval when both are set).
    pub fn fault_policy(&self) -> Result<FaultPolicy> {
        let d = FaultPolicy::default();
        let every = self
            .get_int("comm.checkpoint_interval", d.ckpt_every_chunks as i64);
        let secs = self.get_int("comm.checkpoint_secs", d.ckpt_secs as i64);
        let chunk = self.get_int("comm.checkpoint_chunk", d.chunk as i64);
        let rearms =
            self.get_int("comm.liveness_rearms", d.rearm_cap as i64);
        let respawns =
            self.get_int("comm.max_respawns", d.max_respawns as i64);
        let hb_interval =
            self.get_int("comm.hb_interval_ms", d.hb_interval_ms as i64);
        let hb_timeout =
            self.get_int("comm.hb_timeout_ms", d.hb_timeout_ms as i64);
        if every < 0 || secs < 0 {
            bail!(
                "comm.checkpoint_interval and comm.checkpoint_secs must \
                 be >= 0"
            );
        }
        if chunk <= 0 {
            bail!("comm.checkpoint_chunk must be positive, got {chunk}");
        }
        if rearms <= 0 || rearms > u32::MAX as i64 {
            bail!(
                "comm.liveness_rearms must be in 1..={}, got {rearms}",
                u32::MAX
            );
        }
        if respawns < 0 || respawns > u32::MAX as i64 {
            bail!(
                "comm.max_respawns must be in 0..={}, got {respawns}",
                u32::MAX
            );
        }
        if hb_interval < 0 || hb_timeout < 0 {
            bail!("comm.hb_interval_ms and comm.hb_timeout_ms must be >= 0");
        }
        if hb_interval > 0 && hb_timeout > 0 && hb_timeout <= hb_interval {
            bail!(
                "comm.hb_timeout_ms ({hb_timeout}) must exceed \
                 comm.hb_interval_ms ({hb_interval})"
            );
        }
        Ok(FaultPolicy {
            ckpt_every_chunks: every as u64,
            ckpt_secs: secs as u64,
            chunk: chunk as u64,
            rearm_cap: rearms as u32,
            max_respawns: respawns as u32,
            hb_interval_ms: hb_interval as u64,
            hb_timeout_ms: hb_timeout as u64,
            chaos: None,
        })
    }

    /// Serving-tier knobs: `serve.workers` (handler threads; 0 = auto),
    /// `serve.batch_max` (keys folded into one intersect-kernel batch),
    /// `serve.cache_capacity` (hot-vertex cache entries; 0 = caching
    /// off), `serve.pending_cap` (queued requests per connection),
    /// `serve.read_timeout_ms` / `serve.idle_secs` (connection limits),
    /// `serve.span_sample` (record every Nth query span; 0 = off),
    /// `serve.slow_query_us` (always-record latency threshold; 0 = off)
    /// and `serve.access_log` (JSONL access-log path; empty = off).
    /// Zeros where allowed clamp to sane behavior rather than erroring.
    pub fn serve_options(&self) -> Result<ServeOptions> {
        let d = ServeOptions::default();
        let workers = self.get_int("serve.workers", d.workers as i64);
        let batch_max = self.get_int("serve.batch_max", d.batch_max as i64);
        let cache =
            self.get_int("serve.cache_capacity", d.cache_capacity as i64);
        let pending = self.get_int("serve.pending_cap", d.pending_cap as i64);
        let read_ms = self.get_int(
            "serve.read_timeout_ms",
            d.limits.read_timeout.as_millis() as i64,
        );
        let idle_secs =
            self.get_int("serve.idle_secs", d.limits.idle_cap.as_secs() as i64);
        if workers < 0 || batch_max <= 0 || cache < 0 {
            bail!(
                "serve.workers/cache_capacity must be >= 0 and \
                 serve.batch_max positive"
            );
        }
        if pending <= 0 || read_ms <= 0 || idle_secs <= 0 {
            bail!(
                "serve.pending_cap, serve.read_timeout_ms and \
                 serve.idle_secs must be positive"
            );
        }
        let span_sample =
            self.get_int("serve.span_sample", d.span_sample as i64);
        let slow_us =
            self.get_int("serve.slow_query_us", d.slow_query_us as i64);
        if span_sample < 0 || slow_us < 0 {
            bail!("serve.span_sample and serve.slow_query_us must be >= 0");
        }
        let access_log = match self.get_str("serve.access_log", "") {
            "" => None,
            p => Some(std::path::PathBuf::from(p)),
        };
        Ok(ServeOptions {
            workers: workers as usize,
            batch_max: batch_max as usize,
            cache_capacity: cache as usize,
            pending_cap: pending as usize,
            span_sample: span_sample as u64,
            slow_query_us: slow_us as u64,
            access_log,
            limits: ConnLimits {
                read_timeout: std::time::Duration::from_millis(read_ms as u64),
                idle_cap: std::time::Duration::from_secs(idle_secs as u64),
            },
        })
    }

    /// Telemetry knob: `telemetry.trace_dir` arms the driver-side trace
    /// sink for epoch-running subcommands — structured fabric events
    /// stream into per-rank JSONL files under that directory, merged
    /// later by `degreesketch trace inspect`. The CLI's `--trace-dir`
    /// flag overrides it; absent/empty means tracing stays off.
    pub fn trace_dir(&self) -> Option<&str> {
        match self.get_str("telemetry.trace_dir", "") {
            "" => None,
            dir => Some(dir),
        }
    }

    /// Dial-retry backoff knobs: `comm.dial_backoff_base_ms` (first
    /// retry delay; doubles per attempt) and `comm.dial_backoff_cap_ms`
    /// (ceiling on the exponential). Validates and installs them into
    /// the rendezvous dialer; returns the `(base, cap)` pair applied.
    pub fn apply_dial_backoff(&self) -> Result<(u64, u64)> {
        let base = self.get_int("comm.dial_backoff_base_ms", 25);
        let cap = self.get_int("comm.dial_backoff_cap_ms", 2000);
        if base <= 0 {
            bail!("comm.dial_backoff_base_ms must be positive, got {base}");
        }
        if cap < base {
            bail!(
                "comm.dial_backoff_cap_ms ({cap}) must be >= \
                 comm.dial_backoff_base_ms ({base})"
            );
        }
        crate::comm::rendezvous::set_dial_backoff(base as u64, cap as u64);
        Ok((base as u64, cap as u64))
    }

    /// Schema-check the infrastructure sections (`comm.*`, `serve.*`,
    /// `telemetry.*`) before any subsystem consumes them: unknown keys
    /// in those sections are rejected (a typo'd `--set serve.worker=8`
    /// used to be silently ignored and the default applied), values
    /// must carry the expected type (the typed getters silently fall
    /// back to defaults on mismatch, which hides `serve.workers="8"`),
    /// and a few knobs get upper caps that the per-subsystem builders
    /// never enforced. Called from `run()` in main.rs right after CLI
    /// overrides land, so it sees the merged file + `--set` view.
    pub fn validate(&self) -> Result<()> {
        // The schema lives inside this function so that every key
        // literal sits in the `bail`-capable arm dslint's config-parity
        // rule demands — this IS the validation arm for keys whose
        // typed builder has nothing to range-check (e.g. the string
        // knobs `comm.listen`, `comm.hosts`, `serve.access_log`,
        // `telemetry.trace_dir`).
        const INT: u8 = 0;
        const STR: u8 = 1;
        const BOOL: u8 = 2;
        const KNOWN: &[(&str, u8)] = &[
            ("comm.flush_threshold", INT),
            ("comm.adaptive_flush", BOOL),
            ("comm.checkpoint_interval", INT),
            ("comm.checkpoint_secs", INT),
            ("comm.checkpoint_chunk", INT),
            ("comm.liveness_rearms", INT),
            ("comm.max_respawns", INT),
            ("comm.hb_interval_ms", INT),
            ("comm.hb_timeout_ms", INT),
            ("comm.dial_backoff_base_ms", INT),
            ("comm.dial_backoff_cap_ms", INT),
            ("comm.listen", STR),
            ("comm.hosts", STR),
            ("serve.workers", INT),
            ("serve.batch_max", INT),
            ("serve.cache_capacity", INT),
            ("serve.pending_cap", INT),
            ("serve.read_timeout_ms", INT),
            ("serve.idle_secs", INT),
            ("serve.span_sample", INT),
            ("serve.slow_query_us", INT),
            ("serve.access_log", STR),
            ("telemetry.trace_dir", STR),
        ];
        for (key, val) in &self.values {
            let section = key.split('.').next().unwrap_or("");
            if !matches!(section, "comm" | "serve" | "telemetry") {
                continue;
            }
            let Some((_, want)) = KNOWN.iter().find(|(k, _)| *k == key)
            else {
                bail!(
                    "unknown config key `{key}` in section [{section}] \
                     (typo? known keys are listed in config.rs)"
                );
            };
            let ok = match *want {
                INT => val.as_int().is_some(),
                STR => val.as_str().is_some(),
                _ => val.as_bool().is_some(),
            };
            if !ok {
                let want_name = match *want {
                    INT => "an integer",
                    STR => "a quoted string",
                    _ => "a boolean",
                };
                bail!("config key `{key}` must be {want_name}, got {val:?}");
            }
        }
        // Upper caps the per-subsystem builders only bound from below.
        const CAPS: &[(&str, i64)] = &[
            ("serve.workers", 4096),
            ("serve.batch_max", 65536),
            ("comm.flush_threshold", 1 << 20),
        ];
        for (key, cap) in CAPS {
            let v = self.get_int(key, 0);
            if v > *cap {
                bail!("{key} = {v} exceeds the supported cap of {cap}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# DegreeSketch run configuration
[run]
ranks = 8
backend = "threads"   # or sequential
partitioner = "hash"

[hll]
p = 12
seed = 1234
estimator = "beta"

[triangles]
k = 100
discard_dominated = true
lr = 0.35

[comm]
flush_threshold = 512
adaptive_flush = false
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_int("run.ranks", 0), 8);
        assert_eq!(c.get_str("run.backend", ""), "threads");
        assert_eq!(c.get_int("hll.p", 0), 12);
        assert!(c.get_bool("triangles.discard_dominated", false));
        assert_eq!(c.get_float("triangles.lr", 0.0), 0.35);
        assert_eq!(c.backend().unwrap(), Backend::Threaded);
        assert!(matches!(
            c.partitioner().unwrap(),
            Partitioner::Hashed { .. }
        ));
        assert_eq!(c.estimator().unwrap(), Estimator::LogLogBeta);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_int("run.ranks", 4), 4);
        assert_eq!(c.backend().unwrap(), Backend::Sequential);
        assert_eq!(c.flush_policy().unwrap(), FlushPolicy::default());
    }

    #[test]
    fn comm_section_builds_flush_policy() {
        let c = Config::parse(SAMPLE).unwrap();
        let p = c.flush_policy().unwrap();
        assert_eq!(p, FlushPolicy::pinned(512));
        let mut c2 = Config::parse(SAMPLE).unwrap();
        c2.set_override("comm.adaptive_flush=true").unwrap();
        assert!(c2.flush_policy().unwrap().adaptive);
        assert_eq!(c2.flush_policy().unwrap().threshold, 512);
        c2.set_override("comm.flush_threshold=0").unwrap();
        assert!(c2.flush_policy().is_err());
    }

    #[test]
    fn serve_keys_parse_and_validate() {
        let c = Config::parse("").unwrap();
        let d = c.serve_options().unwrap();
        assert_eq!(d.batch_max, ServeOptions::default().batch_max);
        assert!(d.resolved_workers() >= 1);

        let mut c2 = Config::parse("").unwrap();
        c2.set_override("serve.workers=2").unwrap();
        c2.set_override("serve.batch_max=16").unwrap();
        c2.set_override("serve.cache_capacity=0").unwrap();
        c2.set_override("serve.idle_secs=30").unwrap();
        let o = c2.serve_options().unwrap();
        assert_eq!(o.workers, 2);
        assert_eq!(o.resolved_workers(), 2);
        assert_eq!(o.batch_max, 16);
        assert_eq!(o.cache_capacity, 0);
        assert_eq!(o.limits.idle_cap, std::time::Duration::from_secs(30));

        c2.set_override("serve.batch_max=0").unwrap();
        assert!(c2.serve_options().is_err());

        // span/access-log keys: defaults off, overrides land, negatives
        // rejected
        let c3 = Config::parse("").unwrap();
        let o3 = c3.serve_options().unwrap();
        assert_eq!(o3.span_sample, 0);
        assert_eq!(o3.slow_query_us, 0);
        assert!(o3.access_log.is_none());
        let mut c4 = Config::parse("").unwrap();
        c4.set_override("serve.span_sample=8").unwrap();
        c4.set_override("serve.slow_query_us=5000").unwrap();
        c4.set_override("serve.access_log=\"/tmp/ds_access.jsonl\"")
            .unwrap();
        let o4 = c4.serve_options().unwrap();
        assert_eq!(o4.span_sample, 8);
        assert_eq!(o4.slow_query_us, 5000);
        assert_eq!(
            o4.access_log.as_deref(),
            Some(std::path::Path::new("/tmp/ds_access.jsonl"))
        );
        c4.set_override("serve.span_sample=-1").unwrap();
        assert!(c4.serve_options().is_err());
    }

    #[test]
    fn fault_policy_keys_parse_and_validate() {
        let c = Config::parse("").unwrap();
        let d = c.fault_policy().unwrap();
        assert_eq!(d, FaultPolicy::default());
        assert!(!d.resilient());

        let mut c2 = Config::parse("").unwrap();
        c2.set_override("comm.checkpoint_interval=3").unwrap();
        c2.set_override("comm.checkpoint_chunk=128").unwrap();
        c2.set_override("comm.liveness_rearms=4").unwrap();
        c2.set_override("comm.max_respawns=1").unwrap();
        let f = c2.fault_policy().unwrap();
        assert!(f.resilient());
        assert_eq!(f.ckpt_every_chunks, 3);
        assert_eq!(f.chunk, 128);
        assert_eq!(f.rearm_cap, 4);
        assert_eq!(f.max_respawns, 1);

        c2.set_override("comm.checkpoint_chunk=0").unwrap();
        assert!(c2.fault_policy().is_err());
        let mut c3 = Config::parse("").unwrap();
        c3.set_override("comm.liveness_rearms=0").unwrap();
        assert!(c3.fault_policy().is_err());
    }

    #[test]
    fn heartbeat_keys_parse_and_validate() {
        let mut c = Config::parse("").unwrap();
        c.set_override("comm.hb_interval_ms=50").unwrap();
        c.set_override("comm.hb_timeout_ms=400").unwrap();
        let f = c.fault_policy().unwrap();
        assert_eq!(f.hb_interval_ms, 50);
        assert_eq!(f.hb_timeout_ms, 400);

        // Timeout must exceed interval when both are enabled.
        c.set_override("comm.hb_timeout_ms=50").unwrap();
        assert!(c.fault_policy().is_err());
        // ... but either alone is fine (0 disables the other side).
        c.set_override("comm.hb_timeout_ms=0").unwrap();
        assert_eq!(c.fault_policy().unwrap().hb_timeout_ms, 0);
        c.set_override("comm.hb_interval_ms=-1").unwrap();
        assert!(c.fault_policy().is_err());
    }

    #[test]
    fn dial_backoff_keys_validate() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.apply_dial_backoff().unwrap(), (25, 2000));

        let mut c2 = Config::parse("").unwrap();
        c2.set_override("comm.dial_backoff_base_ms=10").unwrap();
        c2.set_override("comm.dial_backoff_cap_ms=100").unwrap();
        assert_eq!(c2.apply_dial_backoff().unwrap(), (10, 100));

        c2.set_override("comm.dial_backoff_cap_ms=5").unwrap();
        assert!(c2.apply_dial_backoff().is_err());
        c2.set_override("comm.dial_backoff_base_ms=0").unwrap();
        assert!(c2.apply_dial_backoff().is_err());
        // Restore defaults so other tests see the stock dialer pacing.
        Config::parse("").unwrap().apply_dial_backoff().unwrap();
    }

    #[test]
    fn telemetry_trace_dir_parses_from_config() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.trace_dir(), None);
        let mut c2 = Config::parse("").unwrap();
        c2.set_override("telemetry.trace_dir=\"/tmp/trace.d\"").unwrap();
        assert_eq!(c2.trace_dir(), Some("/tmp/trace.d"));
        c2.set_override("telemetry.trace_dir=\"\"").unwrap();
        assert_eq!(c2.trace_dir(), None);
    }

    #[test]
    fn backend_process_parses_from_config() {
        let mut c = Config::parse("").unwrap();
        c.set_override("run.backend=\"process\"").unwrap();
        assert_eq!(c.backend().unwrap(), Backend::Process);
    }

    #[test]
    fn backend_tcp_and_fabric_keys_parse_from_config() {
        let mut c = Config::parse("").unwrap();
        c.set_override("run.backend=\"tcp\"").unwrap();
        c.set_override("comm.listen=\"127.0.0.1:7300\"").unwrap();
        c.set_override("comm.hosts=\"0=127.0.0.1:7301,1=127.0.0.1:7302\"")
            .unwrap();
        assert_eq!(c.backend().unwrap(), Backend::Tcp);
        assert_eq!(c.get_str("comm.listen", ""), "127.0.0.1:7300");
        assert_eq!(
            crate::comm::tcp::parse_hosts(c.get_str("comm.hosts", ""), 2)
                .unwrap(),
            vec!["127.0.0.1:7301", "127.0.0.1:7302"]
        );
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_override("run.ranks=16").unwrap();
        c.set_override("hll.estimator=\"classic\"").unwrap();
        assert_eq!(c.get_int("run.ranks", 0), 16);
        assert_eq!(c.estimator().unwrap(), Estimator::Classic);
        assert!(c.set_override("no-equals-sign").is_err());
    }

    #[test]
    fn validate_rejects_unknown_infra_keys() {
        let c = Config::parse(SAMPLE).unwrap();
        c.validate().unwrap();

        // a typo'd key in a schema'd section is an error, not a silent
        // fall-through to defaults
        let mut c2 = Config::parse("").unwrap();
        c2.set_override("serve.worker=8").unwrap();
        let err = c2.validate().unwrap_err().to_string();
        assert!(err.contains("unknown config key"), "{err}");

        // app-level sections stay open: unknown keys there are fine
        let mut c3 = Config::parse("").unwrap();
        c3.set_override("experiment.tag=\"fig7\"").unwrap();
        c3.validate().unwrap();
    }

    #[test]
    fn validate_rejects_type_mismatches_and_cap_overruns() {
        let mut c = Config::parse("").unwrap();
        c.set_override("serve.workers=\"8\"").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("must be an integer"), "{err}");

        let mut c2 = Config::parse("").unwrap();
        c2.set_override("comm.adaptive_flush=1").unwrap();
        assert!(c2.validate().is_err());

        let mut c3 = Config::parse("").unwrap();
        c3.set_override("serve.workers=100000").unwrap();
        let err = c3.validate().unwrap_err().to_string();
        assert!(err.contains("exceeds the supported cap"), "{err}");
        c3.set_override("serve.workers=4096").unwrap();
        c3.validate().unwrap();

        let mut c4 = Config::parse("").unwrap();
        c4.set_override("comm.flush_threshold=2000000").unwrap();
        assert!(c4.validate().is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed\nx = 1").is_err());
        assert!(Config::parse("justakey\n").is_err());
        assert!(Config::parse("x = unquoted string\n").is_err());
    }
}
