//! Log2-bucketed latency histogram with lock-free recording.
//!
//! A value `v` lands in bucket `64 - v.leading_zeros()` (bucket 0 is
//! reserved for `v == 0`), so bucket `i >= 1` covers `[2^(i-1), 2^i)`.
//! Quantiles are estimated by walking the cumulative counts to the
//! bucket containing the requested order statistic and interpolating
//! linearly inside it — the estimate is therefore always inside the
//! same power-of-two bucket as the exact order statistic, i.e. within a
//! factor of 2 of it (property-tested against exact sorts below).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: one for zero plus one per bit position.
pub const BUCKETS: usize = 65;

/// Which bucket a value lands in.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (the largest value it can hold).
pub fn bucket_upper(i: usize) -> u64 {
    match i {
        0 => 0,
        64 => u64::MAX,
        _ => (1u64 << i) - 1,
    }
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lower(i: usize) -> u64 {
    match i {
        0 => 0,
        _ => 1u64 << (i - 1),
    }
}

/// Lock-free log2 histogram: 65 atomic buckets plus running sum/count.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: [const { AtomicU64::new(0) }; BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    // RELAXED: buckets/sum/count are independent statistics; readers
    // tolerate a torn view across them (count is recomputed from the
    // bucket snapshot), so no cross-cell ordering is needed.
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations recorded.
    // RELAXED: statistics read; may trail in-flight observes.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values (wrapping on overflow).
    // RELAXED: statistics read; may trail in-flight observes.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// A consistent-enough copy of the bucket counts for rendering.
    // RELAXED: each bucket is read independently; "consistent enough"
    // is the documented contract — quantiles over a mid-observe
    // snapshot are off by at most the in-flight observations.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            sum: self.sum(),
            count: buckets.iter().sum(),
        }
    }

    /// Estimate the `q`-quantile (`0.0 ..= 1.0`); `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        self.snapshot().quantile(q)
    }
}

/// A point-in-time copy of a [`Histogram`]'s state.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum: u64,
    pub count: u64,
}

impl HistSnapshot {
    /// Estimate the `q`-quantile by interpolating inside the bucket
    /// that contains the `ceil(q * count)`-th smallest observation.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        // Rank of the order statistic we want, 1-based.
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if cum >= target {
                let lo = bucket_lower(i) as f64;
                let hi = bucket_upper(i) as f64;
                let frac = (target - prev) as f64 / c as f64;
                // Clamp: `hi as f64` rounds up to the next power of two
                // for i > 53, which would let the cast escape the bucket.
                let est = (lo + (hi - lo) * frac) as u64;
                return Some(est.clamp(bucket_lower(i), bucket_upper(i)));
            }
        }
        // Unreachable when count == Σ buckets, but don't panic on a
        // racy snapshot.
        Some(bucket_upper(BUCKETS - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn bucket_bounds_partition_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        for i in 0..BUCKETS {
            assert_eq!(bucket_of(bucket_lower(i)), i, "lower bound of {i}");
            assert_eq!(bucket_of(bucket_upper(i)), i, "upper bound of {i}");
        }
        // Buckets tile the domain with no gaps.
        for i in 1..BUCKETS {
            assert_eq!(bucket_upper(i - 1).wrapping_add(1), bucket_lower(i).max(1));
        }
    }

    #[test]
    fn quantile_empty_and_single() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), None);
        h.observe(42);
        let p50 = h.quantile(0.5).unwrap();
        assert_eq!(bucket_of(p50), bucket_of(42));
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 42);
    }

    /// Property: for random samples and random quantiles, the estimate
    /// lands in the same log2 bucket as the exact order statistic.
    #[test]
    fn quantile_matches_exact_bucket() {
        Cases::new("hist_quantile", 200).run(|rng| {
            let n = 1 + (rng.next_u64() % 500) as usize;
            let h = Histogram::new();
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                // Mix of magnitudes: shift a 64-bit draw by a random amount.
                let v = rng.next_u64() >> (rng.next_u64() % 64);
                h.observe(v);
                xs.push(v);
            }
            xs.sort_unstable();
            for &q in &[0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let est = h.quantile(q).unwrap();
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = xs[rank - 1];
                assert_eq!(
                    bucket_of(est),
                    bucket_of(exact),
                    "q={q} n={n} est={est} exact={exact}"
                );
            }
        });
    }

    #[test]
    fn snapshot_count_is_bucket_sum() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 7, 1 << 20, u64::MAX] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.buckets.iter().sum::<u64>(), 6);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[1], 2);
        assert_eq!(s.buckets[64], 1);
    }
}
