//! Prometheus text-format exposition for [`Registry`] snapshots.
//!
//! Counters and gauges render as plain sample lines; histograms render
//! as the standard `_bucket{le=...}`/`_sum`/`_count` family (cumulative
//! buckets on the log2 upper bounds) *plus* a summary-style
//! `<name>_quantiles{quantile="..."}` family with estimated p50/p90/p99
//! so scrapers that don't do histogram math still see latency
//! percentiles. Output ends with an OpenMetrics-style `# EOF` line,
//! which doubles as the framing terminator for the query server's
//! multi-line `METRICS` response.
//!
//! [`check_text`] is a deliberately small validator used by tests and
//! the CI scrape step: it checks name syntax, TYPE declarations,
//! label/value shape, and that histogram bucket counts are cumulative.

use super::hist::{bucket_upper, BUCKETS};
use super::{Registry, Sample, SampleValue, SeriesKind};
use std::fmt::Write as _;

/// Quantiles exported for every histogram family.
pub const QUANTILES: &[(f64, &str)] = &[(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")];

/// Render the concatenated snapshots of `registries` as Prometheus
/// text. Series names must be disjoint across registries (ours are
/// prefixed per subsystem); families are emitted in sorted name order.
pub fn render(registries: &[&Registry]) -> String {
    let mut samples: Vec<Sample> = Vec::new();
    for r in registries {
        samples.extend(r.snapshot());
    }
    samples.sort_by(|a, b| (&a.name, &a.labels, a.kind).cmp(&(&b.name, &b.labels, b.kind)));

    let mut out = String::new();
    let mut last_family: Option<(String, SeriesKind)> = None;
    for s in &samples {
        let family = (s.name.clone(), s.kind);
        if last_family.as_ref() != Some(&family) {
            let type_name = match s.kind {
                SeriesKind::Counter => "counter",
                SeriesKind::Gauge => "gauge",
                SeriesKind::Hist => "histogram",
            };
            let _ = writeln!(out, "# TYPE {} {}", s.name, type_name);
            if s.kind == SeriesKind::Hist {
                let _ = writeln!(out, "# TYPE {}_quantiles summary", s.name);
            }
            last_family = Some(family);
        }
        match &s.value {
            SampleValue::Counter(v) | SampleValue::Gauge(v) => {
                let _ = writeln!(out, "{}{} {}", s.name, labels_text(&s.labels, &[]), v);
            }
            SampleValue::Hist(h) => {
                let top = (0..BUCKETS).rev().find(|&i| h.buckets[i] != 0);
                let mut cum = 0u64;
                if let Some(top) = top {
                    for (i, &c) in h.buckets.iter().enumerate().take(top + 1) {
                        cum += c;
                        let le = bucket_upper(i).to_string();
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {}",
                            s.name,
                            labels_text(&s.labels, &[("le", &le)]),
                            cum
                        );
                    }
                }
                let _ = writeln!(
                    out,
                    "{}_bucket{} {}",
                    s.name,
                    labels_text(&s.labels, &[("le", "+Inf")]),
                    h.count
                );
                let _ = writeln!(out, "{}_sum{} {}", s.name, labels_text(&s.labels, &[]), h.sum);
                let _ = writeln!(
                    out,
                    "{}_count{} {}",
                    s.name,
                    labels_text(&s.labels, &[]),
                    h.count
                );
                for &(q, qs) in QUANTILES {
                    if let Some(v) = h.quantile(q) {
                        let _ = writeln!(
                            out,
                            "{}_quantiles{} {}",
                            s.name,
                            labels_text(&s.labels, &[("quantile", qs)]),
                            v
                        );
                    }
                }
            }
        }
    }
    out.push_str("# EOF\n");
    out
}

fn labels_text(labels: &[(String, String)], extra: &[(&str, &str)]) -> String {
    if labels.is_empty() && extra.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in labels
        .iter()
        .map(|(k, v)| (k.as_str(), v.as_str()))
        .chain(extra.iter().copied())
    {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{}=\"{}\"", k, escape_label(v));
    }
    out.push('}');
    out
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---------------------------------------------------------------------
// Minimal format checker (tests / CI scrape assertions).
// ---------------------------------------------------------------------

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Validate Prometheus text output: every sample line must parse, its
/// base family must be TYPE-declared first, and histogram `_bucket`
/// series must be cumulative in declaration order. Returns the number
/// of sample lines on success.
pub fn check_text(text: &str) -> Result<usize, String> {
    let mut declared: Vec<(String, String)> = Vec::new(); // (name, type)
    let mut samples = 0usize;
    let mut last_bucket: Option<(String, u64)> = None; // (series w/o le, cum)
    let mut saw_eof = false;
    for (no, line) in text.lines().enumerate() {
        let err = |msg: &str| Err(format!("line {}: {} in {:?}", no + 1, msg, line));
        if saw_eof {
            return err("content after # EOF");
        }
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if rest == "EOF" {
                saw_eof = true;
                continue;
            }
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut parts = decl.split_whitespace();
                let (Some(name), Some(ty), None) = (parts.next(), parts.next(), parts.next())
                else {
                    return err("malformed TYPE");
                };
                if !valid_name(name) {
                    return err("bad family name");
                }
                if !["counter", "gauge", "histogram", "summary"].contains(&ty) {
                    return err("unknown family type");
                }
                declared.push((name.to_string(), ty.to_string()));
            }
            continue; // other comments are fine
        }
        // Sample line: name[{labels}] value
        let (series, value) = line.rsplit_once(' ').ok_or_else(|| {
            format!("line {}: no value in {:?}", no + 1, line)
        })?;
        if value.parse::<f64>().is_err() && value != "+Inf" && value != "NaN" {
            return err("unparsable value");
        }
        let (name, labels) = match series.split_once('{') {
            Some((n, rest)) => {
                let Some(body) = rest.strip_suffix('}') else {
                    return err("unterminated label set");
                };
                (n, Some(body))
            }
            None => (series, None),
        };
        if !valid_name(name) {
            return err("bad metric name");
        }
        let mut le: Option<String> = None;
        if let Some(body) = labels {
            for pair in split_label_pairs(body) {
                let Some((k, v)) = pair.split_once('=') else {
                    return err("label without =");
                };
                if !valid_name(k) {
                    return err("bad label name");
                }
                if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
                    return err("unquoted label value");
                }
                if k == "le" {
                    le = Some(v[1..v.len() - 1].to_string());
                }
            }
        }
        // The family must be declared: exact name, or a histogram/summary
        // suffix of a declared family.
        let family_ok = declared.iter().any(|(n, ty)| {
            name == n
                || (ty == "histogram"
                    && ["_bucket", "_sum", "_count"]
                        .iter()
                        .any(|sfx| name == format!("{n}{sfx}")))
        });
        if !family_ok {
            return err("sample for undeclared family");
        }
        // Cumulative-bucket check, per contiguous bucket run.
        if name.ends_with("_bucket") {
            let base = series.replace(",le=", ",\0le=").replace("{le=", "{\0le=");
            let base = base.split('\0').next().unwrap_or("").to_string();
            let v: u64 = value.parse().map_err(|_| {
                format!("line {}: non-integer bucket count in {:?}", no + 1, line)
            })?;
            if le.is_none() {
                return err("_bucket without le label");
            }
            if let Some((prev_base, prev_cum)) = &last_bucket {
                if *prev_base == base && v < *prev_cum {
                    return err("bucket counts not cumulative");
                }
            }
            last_bucket = Some((base, v));
        } else {
            last_bucket = None;
        }
        samples += 1;
    }
    if !saw_eof {
        return Err("missing # EOF terminator".to_string());
    }
    Ok(samples)
}

/// Split a label body on commas that sit between pairs (label values
/// are quoted and may contain escaped quotes, but never raw commas in
/// our output; this keeps the checker honest about quoting anyway).
fn split_label_pairs(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_quotes = false;
    let mut escaped = false;
    for (i, c) in body.char_indices() {
        match c {
            '\\' if in_quotes => escaped = !escaped,
            '"' if !escaped => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(&body[start..i]);
                start = i + 1;
            }
            _ => escaped = false,
        }
    }
    if start < body.len() {
        out.push(&body[start..]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    #[test]
    fn renders_and_validates() {
        let r = Registry::new();
        r.counter("degreesketch_queries_total", &[("kind", "deg")]).add(3);
        r.counter("degreesketch_queries_total", &[("kind", "tri")]).add(1);
        r.gauge("degreesketch_snapshot_resident", &[]).set(42);
        let h = r.histogram("degreesketch_query_latency_us", &[("kind", "deg")]);
        for v in [3u64, 5, 9, 120, 4000] {
            h.observe(v);
        }
        let text = render(&[&r]);
        let n = check_text(&text).expect("valid exposition");
        assert!(n >= 8, "expected a rich sample set, got {n}:\n{text}");
        assert!(text.contains("# TYPE degreesketch_query_latency_us histogram"));
        assert!(text.contains("degreesketch_query_latency_us_bucket{kind=\"deg\",le=\"+Inf\"} 5"));
        assert!(text.contains("degreesketch_query_latency_us_count{kind=\"deg\"} 5"));
        assert!(text.contains("quantile=\"0.5\""));
        assert!(text.contains("quantile=\"0.99\""));
        assert!(text.ends_with("# EOF\n"));
    }

    #[test]
    fn two_registries_concatenate() {
        let a = Registry::new();
        let b = Registry::new();
        a.counter("degreesketch_server_requests_total", &[]).add(1);
        b.counter("degreesketch_fabric_restores_total", &[]).add(2);
        let text = render(&[&a, &b]);
        check_text(&text).unwrap();
        assert!(text.contains("degreesketch_server_requests_total 1"));
        assert!(text.contains("degreesketch_fabric_restores_total 2"));
    }

    #[test]
    fn checker_rejects_malformed_output() {
        assert!(check_text("no eof at all\n").is_err());
        assert!(check_text("undeclared_metric 5\n# EOF\n").is_err());
        assert!(check_text("# TYPE m counter\nm not_a_number\n# EOF\n").is_err());
        assert!(check_text("# TYPE m counter\nm{l=unquoted} 3\n# EOF\n").is_err());
        assert!(check_text(
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"3\"} 2\n# EOF\n"
        )
        .is_err());
        assert!(check_text("# TYPE ok counter\nok 1\n# EOF\n").is_ok());
    }

    #[test]
    fn empty_registry_is_still_wellformed() {
        let text = render(&[&Registry::new()]);
        assert_eq!(check_text(&text), Ok(0));
        assert_eq!(text, "# EOF\n");
    }
}
