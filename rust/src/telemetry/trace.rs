//! Structured fabric trace events: JSONL encoding, parsing, and the
//! cross-rank timeline merge behind `degreesketch trace inspect`.
//!
//! Every event carries a monotonic per-process timestamp (`t_us`,
//! microseconds since the first telemetry call in that process), the
//! emitting rank (`-1` for the driver), and a per-emitter sequence
//! number. Clocks are *not* synchronized across processes, so the merge
//! aligns each rank's stream on its `epoch.start` event and orders by
//! the resulting relative time; ties break by `(rank, seq)` so the
//! merged timeline is deterministic regardless of file read order.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;
use std::sync::OnceLock;
use std::time::Instant;

/// Microseconds since the process's telemetry epoch (monotonic).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_micros() as u64
}

/// One structured trace record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic microseconds in the emitting process.
    pub t_us: u64,
    /// Emitting rank; `-1` is the driver.
    pub rank: i64,
    /// Per-emitter sequence number (total order within one stream).
    pub seq: u64,
    /// Dotted event kind, e.g. `"ckpt.commit"` or `"chaos.drop"`.
    pub kind: String,
    /// Flat numeric payload, insertion-ordered.
    pub fields: Vec<(String, u64)>,
}

impl TraceEvent {
    /// Render as one JSONL line (no trailing newline). Kinds and field
    /// keys are internal dotted identifiers, so no string escaping is
    /// needed; `escape_json` guards against future misuse anyway.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::with_capacity(64);
        let _ = write!(
            s,
            "{{\"t_us\":{},\"rank\":{},\"seq\":{},\"kind\":\"{}\"",
            self.t_us,
            self.rank,
            self.seq,
            escape_json(&self.kind)
        );
        if !self.fields.is_empty() {
            s.push_str(",\"f\":{");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                let _ = write!(s, "\"{}\":{}", escape_json(k), v);
            }
            s.push('}');
        }
        s.push('}');
        s
    }

    /// Parse one line produced by [`to_jsonl`]. This is a parser for
    /// our own flat format, not a general JSON reader; unknown keys are
    /// rejected so format drift fails loudly.
    pub fn parse_jsonl(line: &str) -> Option<TraceEvent> {
        let line = line.trim();
        let inner = line.strip_prefix('{')?.strip_suffix('}')?;
        let mut ev = TraceEvent {
            t_us: 0,
            rank: 0,
            seq: 0,
            kind: String::new(),
            fields: Vec::new(),
        };
        let mut rest = inner;
        let mut saw_kind = false;
        while !rest.is_empty() {
            rest = rest.trim_start_matches(',');
            let key_end = rest.find("\":")?;
            let key = rest.strip_prefix('"')?.get(..key_end - 1)?;
            rest = &rest[key_end + 2..];
            match key {
                "t_us" | "rank" | "seq" => {
                    let end = rest.find(',').unwrap_or(rest.len());
                    let num = &rest[..end];
                    match key {
                        "t_us" => ev.t_us = num.parse().ok()?,
                        "rank" => ev.rank = num.parse().ok()?,
                        _ => ev.seq = num.parse().ok()?,
                    }
                    rest = &rest[end..];
                }
                "kind" => {
                    let body = rest.strip_prefix('"')?;
                    let end = body.find('"')?;
                    ev.kind = body[..end].to_string();
                    saw_kind = true;
                    rest = &body[end + 1..];
                }
                "f" => {
                    let body = rest.strip_prefix('{')?;
                    let end = body.find('}')?;
                    for pair in body[..end].split(',').filter(|p| !p.is_empty()) {
                        let (k, v) = pair.split_once(':')?;
                        let k = k.strip_prefix('"')?.strip_suffix('"')?;
                        ev.fields.push((k.to_string(), v.parse().ok()?));
                    }
                    rest = &body[end + 1..];
                }
                _ => return None,
            }
        }
        if saw_kind {
            Some(ev)
        } else {
            None
        }
    }
}

fn escape_json(s: &str) -> String {
    if s.chars().all(|c| c != '"' && c != '\\' && c >= ' ') {
        return s.to_string();
    }
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if c < ' ' => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// One event in a merged timeline, with its rank-aligned relative time.
#[derive(Debug, Clone)]
pub struct MergedEvent {
    /// Microseconds since the emitting rank's `epoch.start` (events
    /// before it get 0).
    pub t_rel: u64,
    pub event: TraceEvent,
}

/// A fabric-wide timeline assembled from per-rank JSONL files.
#[derive(Debug, Default)]
pub struct Timeline {
    pub events: Vec<MergedEvent>,
    /// Lines that failed to parse (surfaced, not silently dropped).
    pub malformed: usize,
    /// Truncated trailing lines (a worker killed mid-write leaves a
    /// partial final record; tolerated and counted, never merged).
    pub truncated: usize,
}

impl Timeline {
    /// Merge all `*.jsonl` streams under `dir` (the layout written by
    /// the driver sink: `driver.jsonl` plus `rank-<r>.jsonl`).
    pub fn merge_dir(dir: &Path) -> std::io::Result<Timeline> {
        let mut streams: Vec<Vec<TraceEvent>> = Vec::new();
        let mut malformed = 0usize;
        let mut truncated = 0usize;
        let mut names: Vec<_> = fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
            .collect();
        names.sort();
        for path in names {
            let text = fs::read_to_string(&path)?;
            // A stream whose file does not end in '\n' was cut off
            // mid-record (worker killed mid-write); its final line is
            // expected to be partial and must not poison the merge.
            let tail_is_partial = !text.is_empty() && !text.ends_with('\n');
            let mut lines: Vec<&str> =
                text.lines().filter(|l| !l.trim().is_empty()).collect();
            let tail = if tail_is_partial { lines.pop() } else { None };
            let mut stream = Vec::new();
            for line in lines {
                match TraceEvent::parse_jsonl(line) {
                    Some(ev) => stream.push(ev),
                    None => malformed += 1,
                }
            }
            if let Some(tail) = tail {
                // A partial tail that still parses (e.g. the write lost
                // only the newline) is kept; otherwise it counts as
                // truncated, not malformed.
                match TraceEvent::parse_jsonl(tail) {
                    Some(ev) => stream.push(ev),
                    None => truncated += 1,
                }
            }
            streams.push(stream);
        }
        let mut tl = Self::merge_streams(streams, malformed);
        tl.truncated = truncated;
        Ok(tl)
    }

    /// Deterministic merge: align each stream on its first
    /// `epoch.start`, then sort by `(t_rel, rank, seq)`.
    pub fn merge_streams(streams: Vec<Vec<TraceEvent>>, malformed: usize) -> Timeline {
        let mut events = Vec::new();
        for stream in streams {
            let base = stream
                .iter()
                .find(|e| e.kind == "epoch.start")
                .map(|e| e.t_us)
                .unwrap_or_else(|| stream.iter().map(|e| e.t_us).min().unwrap_or(0));
            for ev in stream {
                events.push(MergedEvent {
                    t_rel: ev.t_us.saturating_sub(base),
                    event: ev,
                });
            }
        }
        events.sort_by_key(|m| (m.t_rel, m.event.rank, m.event.seq));
        Timeline { events, malformed, truncated: 0 }
    }

    /// Count events per kind (for summaries and assertions).
    pub fn counts_by_kind(&self) -> BTreeMap<String, u64> {
        let mut out = BTreeMap::new();
        for m in &self.events {
            *out.entry(m.event.kind.clone()).or_insert(0u64) += 1;
        }
        out
    }

    /// Dwell times of the driver's quiescent barriers: microseconds
    /// between each `barrier.begin` and the next `barrier.end`, paired
    /// in driver-sequence order.
    pub fn barrier_dwells_us(&self) -> Vec<u64> {
        let mut driver: Vec<&TraceEvent> = self
            .events
            .iter()
            .map(|m| &m.event)
            .filter(|e| e.rank == -1)
            .collect();
        driver.sort_by_key(|e| e.seq);
        let mut dwells = Vec::new();
        let mut open: Option<u64> = None;
        for ev in driver {
            match ev.kind.as_str() {
                "barrier.begin" => open = Some(ev.t_us),
                "barrier.end" => {
                    if let Some(t0) = open.take() {
                        dwells.push(ev.t_us.saturating_sub(t0));
                    }
                }
                _ => {}
            }
        }
        dwells
    }

    /// Machine-readable summary for `trace inspect --json`: event and
    /// skip counts, per-kind counts (sorted by kind), and barrier dwell
    /// times in driver order. Key order is fixed so CI assertions can be
    /// structural.
    pub fn summary_json(&self) -> String {
        let mut out = String::with_capacity(256);
        let _ = write!(
            out,
            "{{\"events\":{},\"malformed\":{},\"truncated\":{},\"counts\":{{",
            self.events.len(),
            self.malformed,
            self.truncated
        );
        for (i, (kind, n)) in self.counts_by_kind().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\":{}", escape_json(kind), n);
        }
        out.push_str("},\"barrier_dwells_us\":[");
        for (i, d) in self.barrier_dwells_us().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{d}");
        }
        out.push_str("]}");
        out
    }

    /// Render the merged timeline as human-readable text (the body of
    /// `trace inspect`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for m in &self.events {
            let who = if m.event.rank < 0 {
                "driver".to_string()
            } else {
                format!("rank{}", m.event.rank)
            };
            let _ = write!(out, "{:>10}us {:>8} {}", m.t_rel, who, m.event.kind);
            for (k, v) in &m.event.fields {
                let _ = write!(out, " {k}={v}");
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn ev(t_us: u64, rank: i64, seq: u64, kind: &str, fields: &[(&str, u64)]) -> TraceEvent {
        TraceEvent {
            t_us,
            rank,
            seq,
            kind: kind.to_string(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    #[test]
    fn jsonl_round_trip() {
        let e = ev(123, 2, 7, "ckpt.commit", &[("barrier", 3), ("gen", 1)]);
        let line = e.to_jsonl();
        assert_eq!(TraceEvent::parse_jsonl(&line), Some(e));
        let bare = ev(0, -1, 0, "epoch.start", &[]);
        assert_eq!(TraceEvent::parse_jsonl(&bare.to_jsonl()), Some(bare));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(TraceEvent::parse_jsonl("not json"), None);
        assert_eq!(TraceEvent::parse_jsonl("{\"t_us\":1}"), None); // no kind
        assert_eq!(TraceEvent::parse_jsonl("{\"bogus\":1,\"kind\":\"x\"}"), None);
    }

    #[test]
    fn merge_aligns_on_epoch_start_and_is_deterministic() {
        // Rank 0's clock starts 1000us "later" than rank 1's; alignment
        // on epoch.start must interleave their steps correctly.
        let r0 = vec![
            ev(1000, 0, 0, "epoch.start", &[]),
            ev(1010, 0, 1, "step.chunk", &[("pos", 1)]),
        ];
        let r1 = vec![
            ev(5, 1, 0, "epoch.start", &[]),
            ev(20, 1, 1, "step.chunk", &[("pos", 1)]),
        ];
        let a = Timeline::merge_streams(vec![r0.clone(), r1.clone()], 0);
        let b = Timeline::merge_streams(vec![r1, r0], 0);
        let kinds_a: Vec<_> = a.events.iter().map(|m| (m.t_rel, m.event.rank)).collect();
        let kinds_b: Vec<_> = b.events.iter().map(|m| (m.t_rel, m.event.rank)).collect();
        assert_eq!(kinds_a, kinds_b);
        assert_eq!(kinds_a, vec![(0, 0), (0, 1), (10, 0), (15, 1)]);
    }

    /// Property: merging randomly shuffled copies of the same streams
    /// yields the identical timeline.
    #[test]
    fn merge_is_order_invariant() {
        Cases::new("trace_merge_determinism", 50).run(|rng| {
            let ranks = 2 + (rng.next_u64() % 3) as i64;
            let mut streams = Vec::new();
            for r in 0..ranks {
                let base = rng.next_u64() % 10_000;
                let n = 1 + (rng.next_u64() % 20) as u64;
                let mut s = vec![ev(base, r, 0, "epoch.start", &[])];
                for i in 1..n {
                    s.push(ev(
                        base + i * (1 + rng.next_u64() % 50),
                        r,
                        i,
                        "step.chunk",
                        &[("i", i)],
                    ));
                }
                streams.push(s);
            }
            let reference = Timeline::merge_streams(streams.clone(), 0);
            rng.shuffle(&mut streams);
            let shuffled = Timeline::merge_streams(streams, 0);
            let key = |t: &Timeline| {
                t.events
                    .iter()
                    .map(|m| (m.t_rel, m.event.rank, m.event.seq))
                    .collect::<Vec<_>>()
            };
            assert_eq!(key(&reference), key(&shuffled));
        });
    }

    #[test]
    fn merge_dir_tolerates_truncated_trailing_line() {
        let dir = std::env::temp_dir().join(format!(
            "dsk-trace-trunc-{}-{}",
            std::process::id(),
            now_us()
        ));
        fs::create_dir_all(&dir).unwrap();
        let good = ev(10, 0, 0, "epoch.start", &[]).to_jsonl();
        let good2 = ev(20, 0, 1, "step.chunk", &[("pos", 1)]).to_jsonl();
        // Simulate a worker killed mid-write: full line, then a partial
        // record with no trailing newline.
        fs::write(
            dir.join("rank-0.jsonl"),
            format!("{good}\n{good2}\n{{\"t_us\":30,\"ra"),
        )
        .unwrap();
        fs::write(
            dir.join("driver.jsonl"),
            format!("{}\n", ev(5, -1, 0, "epoch.start", &[]).to_jsonl()),
        )
        .unwrap();
        let tl = Timeline::merge_dir(&dir).unwrap();
        assert_eq!(tl.truncated, 1);
        assert_eq!(tl.malformed, 0);
        assert_eq!(tl.events.len(), 3);
        // A garbage line in the *middle* still counts as malformed.
        fs::write(
            dir.join("rank-1.jsonl"),
            format!("not json\n{good}\n"),
        )
        .unwrap();
        let tl = Timeline::merge_dir(&dir).unwrap();
        assert_eq!(tl.malformed, 1);
        assert_eq!(tl.truncated, 1);
        // A complete final line merely missing its newline is kept.
        fs::write(dir.join("rank-2.jsonl"), good.clone()).unwrap();
        let tl = Timeline::merge_dir(&dir).unwrap();
        assert_eq!(tl.truncated, 1);
        assert_eq!(tl.events.len(), 5);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_json_is_stable_and_parseable() {
        let driver = vec![
            ev(10, -1, 0, "epoch.start", &[]),
            ev(100, -1, 1, "barrier.begin", &[("barrier", 1)]),
            ev(150, -1, 2, "barrier.end", &[("barrier", 1)]),
            ev(160, -1, 3, "step.chunk", &[("pos", 2)]),
        ];
        let mut tl = Timeline::merge_streams(vec![driver], 2);
        tl.truncated = 1;
        let json = tl.summary_json();
        assert_eq!(
            json,
            "{\"events\":4,\"malformed\":2,\"truncated\":1,\"counts\":{\
             \"barrier.begin\":1,\"barrier.end\":1,\"epoch.start\":1,\
             \"step.chunk\":1},\"barrier_dwells_us\":[50]}"
        );
        // Structurally valid JSON by the export-layer parser.
        assert!(crate::telemetry::export::parse_json(&json).is_ok());
    }

    #[test]
    fn barrier_dwells_pair_begin_end() {
        let driver = vec![
            ev(10, -1, 0, "epoch.start", &[]),
            ev(100, -1, 1, "barrier.begin", &[("barrier", 1)]),
            ev(150, -1, 2, "barrier.end", &[("barrier", 1)]),
            ev(200, -1, 3, "barrier.begin", &[("barrier", 2)]),
            ev(280, -1, 4, "barrier.end", &[("barrier", 2)]),
        ];
        let tl = Timeline::merge_streams(vec![driver], 0);
        assert_eq!(tl.barrier_dwells_us(), vec![50, 80]);
    }
}
