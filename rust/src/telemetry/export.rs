//! Chrome/Perfetto trace-event export for merged timelines.
//!
//! `degreesketch trace export --format chrome` turns the per-rank JSONL
//! streams under a trace dir into one Chrome trace-event JSON array —
//! the format ui.perfetto.dev and chrome://tracing load directly — so
//! any fabric run becomes a flamegraph-style timeline.
//!
//! Track model: one process (`pid` 0, named `degreesketch`), one thread
//! per emitter. `tid` is `rank + 1` (driver −1 → 0, rank *r* → *r*+1,
//! serve worker *w* → 1001+*w*), each named by an `"M"` metadata event.
//! Every trace event becomes an `"i"` instant carrying its fields as
//! args; additionally, driver `barrier.begin`/`end` pairs and
//! `serve.span` records (which carry their own stage durations) become
//! `"X"` complete slices, the spans with nested queue/kernel/flush
//! children so the serve pipeline reads as a flame.
//!
//! [`parse_json`] is a dependency-free JSON reader used by the unit
//! tests to round-trip the export (and by `trace inspect --json`
//! consumers who want a sanity check); it is a validator, not a general
//! JSON library.

use std::fmt::Write as _;

use super::trace::{MergedEvent, Timeline, TraceEvent};

/// Serve-tier span track offset: serve worker `w` logs as rank
/// `SERVE_TRACK_BASE + w` in the trace stream.
pub const SERVE_TRACK_BASE: i64 = 1000;

fn tid_of(rank: i64) -> i64 {
    rank + 1
}

fn track_name(rank: i64) -> String {
    if rank < 0 {
        "driver".to_string()
    } else if rank >= SERVE_TRACK_BASE {
        format!("serve worker {}", rank - SERVE_TRACK_BASE)
    } else {
        format!("rank {rank}")
    }
}

fn field(ev: &TraceEvent, name: &str) -> u64 {
    ev.fields
        .iter()
        .find(|(k, _)| k == name)
        .map(|&(_, v)| v)
        .unwrap_or(0)
}

fn push_args(out: &mut String, ev: &TraceEvent) {
    out.push_str(",\"args\":{");
    for (i, (k, v)) in ev.fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\":{}", escape(k), v);
    }
    out.push('}');
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if c < ' ' => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn push_event(out: &mut String, first: &mut bool, body: String) {
    if !*first {
        out.push(',');
    }
    *first = false;
    out.push('\n');
    out.push_str(&body);
}

fn instant(me: &MergedEvent) -> String {
    let ev = &me.event;
    let mut s = format!(
        "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\"pid\":0,\"tid\":{}",
        escape(&ev.kind),
        me.t_rel,
        tid_of(ev.rank)
    );
    push_args(&mut s, ev);
    s.push('}');
    s
}

fn complete(name: &str, ts: u64, dur: u64, tid: i64, ev: Option<&TraceEvent>) -> String {
    let mut s = format!(
        "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur},\"pid\":0,\"tid\":{tid}",
        escape(name)
    );
    if let Some(ev) = ev {
        push_args(&mut s, ev);
    }
    s.push('}');
    s
}

/// Render a merged timeline as a Chrome trace-event JSON array.
pub fn chrome_trace(tl: &Timeline) -> String {
    let mut out = String::with_capacity(4096 + tl.events.len() * 128);
    out.push('[');
    let mut first = true;

    // Process + thread metadata, one thread per distinct emitter rank.
    push_event(
        &mut out,
        &mut first,
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"args\":{\"name\":\"degreesketch\"}}"
            .to_string(),
    );
    let mut ranks: Vec<i64> = tl.events.iter().map(|m| m.event.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in &ranks {
        push_event(
            &mut out,
            &mut first,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\"args\":{{\"name\":\"{}\"}}}}",
                tid_of(*r),
                escape(&track_name(*r))
            ),
        );
    }

    // Driver barrier dwells as complete slices.
    let mut open_barrier: Option<u64> = None;
    for me in &tl.events {
        let ev = &me.event;
        if ev.rank == -1 {
            match ev.kind.as_str() {
                "barrier.begin" => open_barrier = Some(me.t_rel),
                "barrier.end" => {
                    if let Some(t0) = open_barrier.take() {
                        push_event(
                            &mut out,
                            &mut first,
                            complete(
                                "barrier",
                                t0,
                                me.t_rel.saturating_sub(t0),
                                tid_of(-1),
                                Some(ev),
                            ),
                        );
                    }
                }
                _ => {}
            }
        }
    }

    for me in &tl.events {
        let ev = &me.event;
        if ev.kind == "serve.span" {
            // Span records are stamped at completion and carry stage
            // durations; lay the slice back from the stamp and nest the
            // stages sequentially from its start.
            let total = field(ev, "total_us");
            let start = me.t_rel.saturating_sub(total);
            push_event(
                &mut out,
                &mut first,
                complete("serve.span", start, total, tid_of(ev.rank), Some(ev)),
            );
            let mut cursor = start;
            let mut left = total;
            for stage in ["queue_us", "kernel_us", "flush_us"] {
                let dur = field(ev, stage).min(left);
                if dur > 0 {
                    push_event(
                        &mut out,
                        &mut first,
                        complete(
                            stage.trim_end_matches("_us"),
                            cursor,
                            dur,
                            tid_of(ev.rank),
                            None,
                        ),
                    );
                    cursor += dur;
                    left -= dur;
                }
            }
        } else {
            push_event(&mut out, &mut first, instant(me));
        }
    }
    out.push_str("\n]\n");
    out
}

// ---------------------------------------------------------------------------
// Minimal JSON reader (round-trip validation; no serde in this tree).
// ---------------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse a complete JSON document. Errors carry the byte offset.
pub fn parse_json(text: &str) -> Result<Json, String> {
    let b = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                let val = parse_value(b, pos)?;
                members.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(members));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => {
            if b[*pos..].starts_with(b"true") {
                *pos += 4;
                Ok(Json::Bool(true))
            } else {
                Err(format!("bad literal at byte {}", *pos))
            }
        }
        Some(b'f') => {
            if b[*pos..].starts_with(b"false") {
                *pos += 5;
                Ok(Json::Bool(false))
            } else {
                Err(format!("bad literal at byte {}", *pos))
            }
        }
        Some(b'n') => {
            if b[*pos..].starts_with(b"null") {
                *pos += 4;
                Ok(Json::Null)
            } else {
                Err(format!("bad literal at byte {}", *pos))
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "short \\u escape".to_string())?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                            16,
                        )
                        .map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(&c) => {
                // Collect the full UTF-8 sequence starting here.
                let start = *pos;
                let len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let end = (start + len).min(b.len());
                out.push_str(
                    std::str::from_utf8(&b[start..end]).map_err(|_| "invalid utf-8")?,
                );
                *pos = end;
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{text}' at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::super::trace::{Timeline, TraceEvent};
    use super::*;

    fn ev(t_us: u64, rank: i64, seq: u64, kind: &str, fields: &[(&str, u64)]) -> TraceEvent {
        TraceEvent {
            t_us,
            rank,
            seq,
            kind: kind.to_string(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    #[test]
    fn json_parser_handles_basics() {
        let v = parse_json(r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null}"#).unwrap();
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\"y"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_num(), Some(-3.0));
        assert_eq!(v.get("c"), Some(&Json::Bool(true)));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert!(parse_json("{\"a\":1").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn chrome_export_round_trips_with_tracks_and_span() {
        let streams = vec![
            vec![
                ev(10, -1, 0, "epoch.start", &[("ranks", 2)]),
                ev(100, -1, 1, "barrier.begin", &[("barrier", 1)]),
                ev(160, -1, 2, "barrier.end", &[("barrier", 1)]),
            ],
            vec![
                ev(12, 0, 0, "epoch.start", &[]),
                ev(40, 0, 1, "flush.grow", &[("to", 1)]),
            ],
            vec![ev(15, 1, 0, "epoch.start", &[])],
            vec![ev(
                500,
                SERVE_TRACK_BASE,
                0,
                "serve.span",
                &[
                    ("kind", 0),
                    ("queue_us", 30),
                    ("kernel_us", 50),
                    ("flush_us", 10),
                    ("total_us", 100),
                ],
            )],
        ];
        let tl = Timeline::merge_streams(streams, 0);
        let json = chrome_trace(&tl);
        let doc = parse_json(&json).expect("valid chrome trace JSON");
        let arr = doc.as_arr().expect("top-level array");
        // Track metadata: driver, rank 0, rank 1, serve worker 0.
        let names: Vec<&str> = arr
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        assert!(names.contains(&"driver"), "{names:?}");
        assert!(names.contains(&"rank 0"));
        assert!(names.contains(&"rank 1"));
        assert!(names.contains(&"serve worker 0"));
        // Every non-metadata event has name/ph/ts/pid/tid.
        for e in arr {
            assert!(e.get("name").is_some());
            let ph = e.get("ph").and_then(Json::as_str).unwrap();
            if ph != "M" {
                assert!(e.get("ts").and_then(Json::as_num).is_some());
                assert!(e.get("tid").and_then(Json::as_num).is_some());
            }
            assert!(e.get("pid").and_then(Json::as_num).is_some());
        }
        // Barrier dwell became an X slice of the right duration.
        let barrier = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("barrier"))
            .expect("barrier slice");
        assert_eq!(barrier.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(barrier.get("dur").and_then(Json::as_num), Some(60.0));
        // The serve span produced a parent X plus nested stage slices on
        // the serve worker track.
        let span = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("serve.span"))
            .expect("serve span slice");
        assert_eq!(span.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(span.get("dur").and_then(Json::as_num), Some(100.0));
        assert_eq!(
            span.get("tid").and_then(Json::as_num),
            Some((SERVE_TRACK_BASE + 1) as f64)
        );
        for stage in ["queue", "kernel", "flush"] {
            assert!(
                arr.iter().any(|e| {
                    e.get("name").and_then(Json::as_str) == Some(stage)
                        && e.get("ph").and_then(Json::as_str) == Some("X")
                }),
                "missing stage slice {stage}"
            );
        }
    }

    #[test]
    fn empty_timeline_exports_valid_json() {
        let tl = Timeline::default();
        let doc = parse_json(&chrome_trace(&tl)).unwrap();
        // Still a valid array with the process metadata record.
        assert_eq!(doc.as_arr().unwrap().len(), 1);
    }
}
