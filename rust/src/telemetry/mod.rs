//! # Telemetry plane
//!
//! Fabric-wide observability in four pieces:
//!
//! * [`Registry`] — sharded, label-aware metric series: lock-free
//!   atomic counters and gauges plus log2-bucketed [`hist::Histogram`]s.
//!   Series lookup takes one shard lock; every subsequent increment on
//!   the returned handle is a single atomic op (this replaces the old
//!   `metrics.rs` mutex-per-increment map, which survives only as a
//!   compat shim over this registry).
//! * [`trace`] — structured span/event records written as per-rank
//!   JSONL under `--trace-dir`, merged into one fabric timeline by
//!   `degreesketch trace inspect`.
//! * [`wire`] — the TELEM codec leg: CRC'd, generation-qualified
//!   delta blobs piggybacked on REPORT/STATE frames so workers ship
//!   telemetry to the driver without new protocol round trips.
//! * [`prom`] — Prometheus text exposition for the query server's
//!   `METRICS` verb, with estimated quantiles per histogram.
//! * [`heatmap`] — the workload introspection layer: a lock-free
//!   `[src × dst × vertex-range]` traffic accumulator sampled at every
//!   outbox flush, shipped as `heat.cell` events on the TELEM leg,
//!   folded into a per-epoch `TrafficMatrix` (cut-edge fraction, byte
//!   skew, hot ranges) behind `degreesketch heatmap`.
//! * [`export`] — Chrome/Perfetto trace-event JSON conversion of a
//!   merged timeline (`degreesketch trace export --format chrome`):
//!   one track per rank plus one per serve worker.
//!
//! ## Routing model
//!
//! The free functions [`count`] and [`event`] are callable from any
//! layer and route by context. A fabric worker (forked process, spawned
//! `worker` binary, or in-process test thread) calls [`begin_worker`]
//! at epoch start, which installs a *thread-local* recording context:
//! counts and events buffer locally, and the socket layer drains them
//! with [`take_delta`] whenever a REPORT or STATE frame leaves for the
//! driver. Everything else (driver, sequential/threaded backends, the
//! query server) records straight into the process-global [`registry`]
//! and — when a trace dir is armed via [`set_trace_dir`] — the driver
//! JSONL stream. Thread-locals keep in-process multi-rank tests honest:
//! each simulated rank records into its own context with no cross-talk.

pub mod export;
pub mod heatmap;
pub mod hist;
pub mod prom;
pub mod trace;
pub mod wire;

pub use hist::Histogram;
pub use trace::{Timeline, TraceEvent};
pub use wire::TelemDelta;

use crate::comm::codec::WireError;
use crate::hash::xxh64;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Cap on buffered worker events between two delta ships; overflow is
/// counted in `TelemDelta::dropped` rather than growing without bound.
const EVENT_RING_CAP: usize = 8192;

const SHARDS: usize = 16;

/// What a series measures (part of its identity: the same name with a
/// different kind is a distinct series, so a misuse can't panic).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SeriesKind {
    Counter,
    Gauge,
    Hist,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct FullKey {
    kind: SeriesKind,
    name: String,
    labels: Vec<(String, String)>,
}

#[derive(Debug)]
enum Series {
    Counter(AtomicU64),
    Gauge(AtomicU64),
    Hist(Histogram),
}

/// A counter handle: cloneable, increments are single atomic adds.
#[derive(Clone)]
pub struct Counter(Arc<Series>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    // RELAXED: metric counters order nothing; scrapes tolerate lag.
    pub fn add(&self, delta: u64) {
        if let Series::Counter(v) = &*self.0 {
            v.fetch_add(delta, Ordering::Relaxed);
        }
    }
    // RELAXED: metric read; see add.
    pub fn get(&self) -> u64 {
        match &*self.0 {
            Series::Counter(v) => v.load(Ordering::Relaxed),
            _ => 0,
        }
    }
}

/// A gauge handle: last-write-wins point-in-time value.
#[derive(Clone)]
pub struct Gauge(Arc<Series>);

impl Gauge {
    // RELAXED: last-write-wins metric value; scrapes tolerate lag.
    pub fn set(&self, v: u64) {
        if let Series::Gauge(g) = &*self.0 {
            g.store(v, Ordering::Relaxed);
        }
    }
    /// Raise to `v` if it exceeds the current value.
    // RELAXED: fetch_max's atomicity alone keeps the high-water mark;
    // no other data hangs off it.
    pub fn raise(&self, v: u64) {
        if let Series::Gauge(g) = &*self.0 {
            g.fetch_max(v, Ordering::Relaxed);
        }
    }
    // RELAXED: metric read; see set.
    pub fn get(&self) -> u64 {
        match &*self.0 {
            Series::Gauge(g) => g.load(Ordering::Relaxed),
            _ => 0,
        }
    }
}

/// A histogram handle; see [`hist::Histogram`] for bucket semantics.
#[derive(Clone)]
pub struct HistHandle(Arc<Series>);

impl HistHandle {
    pub fn observe(&self, v: u64) {
        if let Series::Hist(h) = &*self.0 {
            h.observe(v);
        }
    }
    pub fn quantile(&self, q: f64) -> Option<u64> {
        match &*self.0 {
            Series::Hist(h) => h.quantile(q),
            _ => None,
        }
    }
    pub fn count(&self) -> u64 {
        match &*self.0 {
            Series::Hist(h) => h.count(),
            _ => 0,
        }
    }
}

/// One exported sample in a registry snapshot.
#[derive(Debug, Clone)]
pub struct Sample {
    pub kind: SeriesKind,
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: SampleValue,
}

#[derive(Debug, Clone)]
pub enum SampleValue {
    Counter(u64),
    Gauge(u64),
    Hist(hist::HistSnapshot),
}

/// Sharded series store: one lock per shard on lookup, atomics after.
pub struct Registry {
    shards: [Mutex<HashMap<FullKey, Arc<Series>>>; SHARDS],
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            shards: std::array::from_fn(|_| Mutex::new(HashMap::new())),
        }
    }

    fn series(&self, kind: SeriesKind, name: &str, labels: &[(&str, &str)]) -> Arc<Series> {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        let key = FullKey {
            kind,
            name: name.to_string(),
            labels,
        };
        let shard = (xxh64(name.as_bytes(), 0x7E1E) as usize) % SHARDS;
        let mut map = self.shards[shard].lock().unwrap();
        map.entry(key)
            .or_insert_with(|| {
                Arc::new(match kind {
                    SeriesKind::Counter => Series::Counter(AtomicU64::new(0)),
                    SeriesKind::Gauge => Series::Gauge(AtomicU64::new(0)),
                    SeriesKind::Hist => Series::Hist(Histogram::new()),
                })
            })
            .clone()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        Counter(self.series(SeriesKind::Counter, name, labels))
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        Gauge(self.series(SeriesKind::Gauge, name, labels))
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> HistHandle {
        HistHandle(self.series(SeriesKind::Hist, name, labels))
    }

    /// Snapshot every series, sorted by `(name, labels, kind)` so the
    /// exposition output is deterministic.
    // RELAXED: scrape-time reads of independent metric cells; the shard
    // mutex pins the series map, not the values, and a scrape that
    // trails in-flight increments is correct by contract.
    pub fn snapshot(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            for (key, series) in map.iter() {
                let value = match &**series {
                    Series::Counter(v) => SampleValue::Counter(v.load(Ordering::Relaxed)),
                    Series::Gauge(v) => SampleValue::Gauge(v.load(Ordering::Relaxed)),
                    Series::Hist(h) => SampleValue::Hist(h.snapshot()),
                };
                out.push(Sample {
                    kind: key.kind,
                    name: key.name.clone(),
                    labels: key.labels.clone(),
                    value,
                });
            }
        }
        out.sort_by(|a, b| {
            (&a.name, &a.labels, a.kind).cmp(&(&b.name, &b.labels, b.kind))
        });
        out
    }
}

/// The process-global registry (driver/server-side series; worker
/// deltas merge into it with a `rank` label on arrival).
pub fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------
// Worker-side recording context (thread-local).
// ---------------------------------------------------------------------

struct WorkerCtx {
    rank: usize,
    seq: u64,
    events: Vec<TraceEvent>,
    dropped: u64,
    counters: BTreeMap<String, u64>,
}

thread_local! {
    static WORKER: RefCell<Option<WorkerCtx>> = const { RefCell::new(None) };
}

/// Install a fresh worker recording context on this thread. Called at
/// the top of every fabric worker epoch; forked children inherit the
/// parent's thread-locals, so this also resets any driver-side state
/// they were born with.
pub fn begin_worker(rank: usize) {
    WORKER.with(|w| {
        *w.borrow_mut() = Some(WorkerCtx {
            rank,
            seq: 0,
            events: Vec::new(),
            dropped: 0,
            counters: BTreeMap::new(),
        });
    });
}

/// Tear down the worker context (end of epoch); later records route to
/// the process-global side again.
pub fn end_worker() {
    WORKER.with(|w| *w.borrow_mut() = None);
}

/// True when this thread is recording as a fabric worker.
pub fn worker_active() -> bool {
    WORKER.with(|w| w.borrow().is_some())
}

/// Drain this worker's buffered telemetry into an encoded TELEM blob
/// stamped with `gen`; `None` when there is nothing to ship (or no
/// worker context is active).
pub fn take_delta(gen: u16) -> Option<Vec<u8>> {
    WORKER.with(|w| {
        let mut b = w.borrow_mut();
        let ctx = b.as_mut()?;
        if ctx.events.is_empty() && ctx.counters.is_empty() && ctx.dropped == 0 {
            return None;
        }
        let delta = TelemDelta {
            gen,
            counters: std::mem::take(&mut ctx.counters).into_iter().collect(),
            events: std::mem::take(&mut ctx.events),
            dropped: std::mem::take(&mut ctx.dropped),
        };
        Some(delta.encode())
    })
}

// ---------------------------------------------------------------------
// Driver-side trace sink.
// ---------------------------------------------------------------------

struct Sink {
    dir: PathBuf,
    driver: File,
    rank_files: HashMap<usize, File>,
    /// Lazily opened `serve.jsonl` stream for serve-tier span records.
    serve: Option<File>,
    /// Highest generation accepted per rank this epoch; stale blobs
    /// (from a rolled-back worker's pre-recovery life) are dropped.
    last_gen: HashMap<usize, u16>,
    seq: u64,
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);
static SINK_ACTIVE: AtomicBool = AtomicBool::new(false);

/// Arm the driver trace sink: creates `dir` and starts `driver.jsonl`
/// (truncating any previous run's stream).
pub fn set_trace_dir(dir: &Path) -> std::io::Result<()> {
    fs::create_dir_all(dir)?;
    let driver = File::create(dir.join("driver.jsonl"))?;
    let mut guard = SINK.lock().unwrap();
    *guard = Some(Sink {
        dir: dir.to_path_buf(),
        driver,
        rank_files: HashMap::new(),
        serve: None,
        last_gen: HashMap::new(),
        seq: 0,
    });
    SINK_ACTIVE.store(true, Ordering::Release);
    Ok(())
}

/// The armed trace dir, if any.
pub fn trace_dir() -> Option<PathBuf> {
    SINK.lock().unwrap().as_ref().map(|s| s.dir.clone())
}

/// Cheap check for call sites that want to skip event formatting when
/// nothing is recording on this thread or in this process.
pub fn enabled() -> bool {
    worker_active() || SINK_ACTIVE.load(Ordering::Acquire)
}

/// Record a driver-side trace event (rank `-1`); no-op without an
/// armed sink.
pub fn driver_event(kind: &str, fields: &[(&str, u64)]) {
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        let ev = TraceEvent {
            t_us: trace::now_us(),
            rank: -1,
            seq: sink.seq,
            kind: kind.to_string(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        sink.seq += 1;
        let _ = writeln!(sink.driver, "{}", ev.to_jsonl());
    }
}

/// Record a serve-tier span/event on worker track `track` (written to
/// `serve.jsonl` as rank `SERVE_TRACK_BASE + track`, so the timeline
/// merge and the Chrome export give each serve worker its own track).
/// No-op without an armed sink.
pub fn serve_event(track: usize, kind: &str, fields: &[(&str, u64)]) {
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        if sink.serve.is_none() {
            sink.serve = File::create(sink.dir.join("serve.jsonl")).ok();
        }
        let Some(file) = sink.serve.as_mut() else {
            return;
        };
        let ev = TraceEvent {
            t_us: trace::now_us(),
            rank: export::SERVE_TRACK_BASE + track as i64,
            seq: sink.seq,
            kind: kind.to_string(),
            fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        };
        sink.seq += 1;
        let _ = writeln!(file, "{}", ev.to_jsonl());
    }
}

/// Driver marks the start of a fabric epoch: resets per-rank generation
/// floors (each epoch restarts its own generation sequence) and emits
/// the `epoch.start` anchor the timeline merge aligns on.
pub fn driver_epoch_start(ranks: u64, gen: u16) {
    let mut guard = SINK.lock().unwrap();
    if let Some(sink) = guard.as_mut() {
        sink.last_gen.clear();
    }
    drop(guard);
    driver_event("epoch.start", &[("ranks", ranks), ("gen", gen as u64)]);
}

/// Ingest a worker's TELEM blob received on `rank`'s channel: verify
/// CRC, drop stale generations, append events to `rank-<r>.jsonl`, and
/// merge counter deltas into the global registry under a `rank` label.
pub fn ingest_remote(rank: usize, blob: &[u8]) -> Result<(), WireError> {
    let mut input = blob;
    let delta = TelemDelta::decode(&mut input)?;
    {
        let mut guard = SINK.lock().unwrap();
        if let Some(sink) = guard.as_mut() {
            let floor = sink.last_gen.entry(rank).or_insert(delta.gen);
            if delta.gen < *floor {
                return Ok(()); // stale pre-recovery delta
            }
            *floor = delta.gen;
            let dir = sink.dir.clone();
            let file = match sink.rank_files.entry(rank) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    let f = File::create(dir.join(format!("rank-{rank}.jsonl")))
                        .map_err(|e| WireError::Invalid(format!("trace sink io: {e}")))?;
                    e.insert(f)
                }
            };
            for ev in &delta.events {
                let mut ev = ev.clone();
                ev.rank = rank as i64;
                // Heat cells are also folded into the driver-side epoch
                // accumulator (they still land in the rank stream so the
                // heatmap CLI can replay them from disk later).
                if ev.kind == "heat.cell" {
                    let f = |name: &str| {
                        ev.fields
                            .iter()
                            .find(|(k, _)| k == name)
                            .map(|&(_, v)| v)
                            .unwrap_or(0)
                    };
                    heatmap::fold_remote_cell(
                        f("src"),
                        f("dst"),
                        f("range"),
                        f("msgs"),
                        f("bytes"),
                        f("k"),
                    );
                }
                let _ = writeln!(file, "{}", ev.to_jsonl());
            }
        }
    }
    let rank_label = rank.to_string();
    for (name, d) in &delta.counters {
        registry().counter(name, &[("rank", &rank_label)]).add(*d);
    }
    if delta.dropped > 0 {
        registry()
            .counter("degreesketch_trace_events_dropped_total", &[("rank", &rank_label)])
            .add(delta.dropped);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Context-routed free functions — the API the fabric layers call.
// ---------------------------------------------------------------------

/// Increment a (label-less) counter. Worker threads buffer the delta
/// for the next TELEM ship; everything else lands in [`registry`].
pub fn count(name: &str, delta: u64) {
    let routed = WORKER.with(|w| {
        if let Some(ctx) = w.borrow_mut().as_mut() {
            *ctx.counters.entry(name.to_string()).or_insert(0) += delta;
            true
        } else {
            false
        }
    });
    if !routed {
        registry().counter(name, &[]).add(delta);
    }
}

/// Record a structured trace event. Worker threads buffer it (bounded
/// by [`EVENT_RING_CAP`]); the driver writes it to `driver.jsonl` when
/// a trace dir is armed; otherwise it is dropped.
pub fn event(kind: &str, fields: &[(&str, u64)]) {
    let routed = WORKER.with(|w| {
        if let Some(ctx) = w.borrow_mut().as_mut() {
            if ctx.events.len() >= EVENT_RING_CAP {
                ctx.dropped += 1;
            } else {
                let ev = TraceEvent {
                    t_us: trace::now_us(),
                    rank: ctx.rank as i64,
                    seq: ctx.seq,
                    kind: kind.to_string(),
                    fields: fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
                };
                ctx.seq += 1;
                ctx.events.push(ev);
            }
            true
        } else {
            false
        }
    });
    if !routed {
        driver_event(kind, fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("requests_total", &[("kind", "deg")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same (name, labels) resolves to the same series.
        assert_eq!(r.counter("requests_total", &[("kind", "deg")]).get(), 5);
        // Different labels are a different series.
        assert_eq!(r.counter("requests_total", &[("kind", "tri")]).get(), 0);
        let g = r.gauge("resident", &[]);
        g.set(7);
        g.raise(3);
        assert_eq!(g.get(), 7);
        g.raise(11);
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn label_order_is_canonicalized() {
        let r = Registry::new();
        r.counter("x", &[("a", "1"), ("b", "2")]).add(2);
        assert_eq!(r.counter("x", &[("b", "2"), ("a", "1")]).get(), 2);
    }

    #[test]
    fn kind_mismatch_is_a_distinct_series_not_a_panic() {
        let r = Registry::new();
        r.counter("dual", &[]).add(3);
        let g = r.gauge("dual", &[]);
        assert_eq!(g.get(), 0);
        g.set(9);
        assert_eq!(r.counter("dual", &[]).get(), 3);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let r = Registry::new();
        r.counter("b_metric", &[]).add(1);
        r.counter("a_metric", &[("rank", "1")]).add(2);
        r.counter("a_metric", &[("rank", "0")]).add(3);
        r.histogram("lat", &[]).observe(100);
        let snap = r.snapshot();
        let names: Vec<_> = snap
            .iter()
            .map(|s| (s.name.as_str(), s.labels.clone()))
            .collect();
        assert_eq!(
            names,
            vec![
                ("a_metric", vec![("rank".into(), "0".into())]),
                ("a_metric", vec![("rank".into(), "1".into())]),
                ("b_metric", vec![]),
                ("lat", vec![]),
            ]
        );
    }

    #[test]
    fn concurrent_increments_do_not_lose_counts() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let c = r.counter("hot", &[]);
                for _ in 0..10_000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("hot", &[]).get(), 80_000);
    }

    #[test]
    fn worker_context_buffers_and_ships() {
        std::thread::spawn(|| {
            begin_worker(3);
            assert!(worker_active());
            count("degreesketch_test_ships_total", 2);
            event("epoch.start", &[("gen", 0)]);
            event("step.chunk", &[("pos", 10)]);
            let blob = take_delta(1).expect("delta");
            let mut input = &blob[..];
            let delta = TelemDelta::decode(&mut input).unwrap();
            assert_eq!(delta.gen, 1);
            assert_eq!(
                delta.counters,
                vec![("degreesketch_test_ships_total".to_string(), 2)]
            );
            assert_eq!(delta.events.len(), 2);
            assert_eq!(delta.events[0].kind, "epoch.start");
            // Drained: nothing further to ship.
            assert!(take_delta(1).is_none());
            end_worker();
            assert!(!worker_active());
        })
        .join()
        .unwrap();
    }

    #[test]
    fn ingest_drops_stale_generations_once_armed() {
        let dir = std::env::temp_dir().join(format!(
            "dsk-telem-test-{}",
            std::process::id()
        ));
        set_trace_dir(&dir).unwrap();
        driver_epoch_start(2, 0);
        let fresh = TelemDelta {
            gen: 2,
            counters: vec![("degreesketch_test_ingest_total".into(), 5)],
            events: vec![],
            dropped: 0,
        };
        ingest_remote(9, &fresh.encode()).unwrap();
        let stale = TelemDelta {
            gen: 1,
            counters: vec![("degreesketch_test_ingest_total".into(), 100)],
            events: vec![],
            dropped: 0,
        };
        ingest_remote(9, &stale.encode()).unwrap();
        assert_eq!(
            registry()
                .counter("degreesketch_test_ingest_total", &[("rank", "9")])
                .get(),
            5
        );
        // Corrupt blobs are rejected before any state changes.
        let mut bad = fresh.encode();
        bad[6] ^= 0x40;
        assert!(ingest_remote(9, &bad).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
