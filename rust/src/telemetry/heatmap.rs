//! Per-range traffic heatmap: who sends how much, about which vertices.
//!
//! `CommStats` says *how many* bytes each rank moved; this module says
//! *which vertex ranges* those bytes were about, so a placement pass can
//! move hot ranges off overloaded ranks. The plane has four pieces:
//!
//! - [`HeatGrid`]: a lock-free `[src × dst × (2^k + 1)]` message/byte
//!   accumulator (`RANGES_LOG2 = 4` → 16 hashed vertex ranges plus one
//!   "unattributed" lane for messages with no vertex, e.g. control fans).
//!   One process-global grid is armed per traced epoch; samplers add to it
//!   with relaxed atomics, so the hot path is a handful of fetch-adds per
//!   flushed batch.
//! - [`HeatSampler`]: the per-worker recording handle installed at the
//!   `flush_outbox` funnel. It classifies each message via the actor's
//!   `heat_vertex` hook, buckets by `range_of`, and books `n ×
//!   size_of::<M>()` bytes — the same estimate `batch_bytes_estimate`
//!   uses, so grid totals reconcile exactly with `CommStats` on the
//!   in-memory backends. `HeatSampler::new` returns `None` when no grid is
//!   armed: untraced runs pay one atomic load per flush site.
//! - Shipping: socket-backend workers drain their local grid into
//!   `heat.cell` trace events (src, dst, range, msgs, bytes, k, epoch)
//!   just before the reliable STATE telemetry leg; the driver's
//!   `ingest_remote` recognises the kind and folds cells into a
//!   process-global accumulator via [`fold_remote_cell`] (cells whose `k`
//!   differs from ours are diverted to the unattributed lane rather than
//!   misbinned). In-memory backends skip the wire: the driver drains the
//!   shared grid directly at epoch end.
//! - Fold: [`epoch_end`] merges grid + remote cells into a
//!   [`TrafficMatrix`], emits per-cell `heat.cell` driver events plus one
//!   `heat.epoch` summary (totals, cut-edge per-mille, skew per-mille, and
//!   the `CommStats` byte total for reconciliation), and returns the
//!   integer-only [`HeatSummary`] that rides `CommStats::heat`.
//!
//! `degreesketch heatmap <trace-dir>` replays the events through
//! [`render_report`] to print per-epoch matrices, cut fraction, byte skew
//! and top-K hot ranges.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::hash::xxhash::xxh64_u64;

use super::trace::Timeline;

/// log2 of the number of hashed vertex ranges tracked per (src, dst) cell.
pub const RANGES_LOG2: u64 = 4;
/// Number of hashed vertex ranges (`2^RANGES_LOG2`).
pub const RANGES: usize = 1 << RANGES_LOG2;
/// Lanes per cell: `RANGES` hashed ranges plus one unattributed lane
/// (index `RANGES`) for messages that carry no vertex.
pub const LANES: usize = RANGES + 1;

/// Seed for the range hash. Fixed so every rank — and every process
/// incarnation — buckets a vertex identically.
const HEAT_SEED: u64 = 0x4845_4154; // "HEAT"

/// Hash a vertex id into its heat range `[0, RANGES)`.
pub fn range_of(v: u64) -> usize {
    (xxh64_u64(v, HEAT_SEED) as usize) & (RANGES - 1)
}

/// One non-zero accumulator cell, as drained from a grid or folded from a
/// remote `heat.cell` event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    pub src: usize,
    pub dst: usize,
    /// Range lane, `RANGES` = unattributed.
    pub lane: usize,
    pub msgs: u64,
    pub bytes: u64,
}

/// Lock-free `[src × dst × LANES]` message/byte accumulator.
pub struct HeatGrid {
    ranks: usize,
    msgs: Vec<AtomicU64>,
    bytes: Vec<AtomicU64>,
}

impl HeatGrid {
    pub fn new(ranks: usize) -> Self {
        let n = ranks * ranks * LANES;
        let mut msgs = Vec::with_capacity(n);
        let mut bytes = Vec::with_capacity(n);
        for _ in 0..n {
            msgs.push(AtomicU64::new(0));
            bytes.push(AtomicU64::new(0));
        }
        HeatGrid { ranks, msgs, bytes }
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    fn idx(&self, src: usize, dst: usize, lane: usize) -> usize {
        (src * self.ranks + dst) * LANES + lane
    }

    /// Relaxed accumulate; out-of-range coordinates are dropped (a sampler
    /// built for a different fleet size must not scribble).
    // RELAXED: per-cell traffic tallies with no inter-cell invariant;
    // drain() swaps each cell independently, so increments never need
    // to be ordered against each other.
    pub fn add(&self, src: usize, dst: usize, lane: usize, msgs: u64, bytes: u64) {
        if src >= self.ranks || dst >= self.ranks || lane >= LANES {
            return;
        }
        let i = self.idx(src, dst, lane);
        self.msgs[i].fetch_add(msgs, Ordering::Relaxed);
        self.bytes[i].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Atomically swap every cell to zero and return the non-empty ones.
    /// Safe against concurrent `add`: each counter is drained exactly once.
    // RELAXED: the swap's atomicity (not its ordering) is what "drained
    // exactly once" relies on; a concurrent add landing after the swap
    // simply counts toward the next epoch.
    pub fn drain(&self) -> Vec<Cell> {
        let mut out = Vec::new();
        for src in 0..self.ranks {
            for dst in 0..self.ranks {
                for lane in 0..LANES {
                    let i = self.idx(src, dst, lane);
                    let m = self.msgs[i].swap(0, Ordering::Relaxed);
                    let b = self.bytes[i].swap(0, Ordering::Relaxed);
                    if m != 0 || b != 0 {
                        out.push(Cell { src, dst, lane, msgs: m, bytes: b });
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Process-global grid + remote fold accumulator.
// ---------------------------------------------------------------------------

static GRID: Mutex<Option<Arc<HeatGrid>>> = Mutex::new(None);
static ARMED: AtomicBool = AtomicBool::new(false);
/// Cells folded from remote workers' `heat.cell` events (socket backends).
static FOLD: Mutex<Vec<Cell>> = Mutex::new(Vec::new());
/// Driver-side epoch counter labelling locally drained cells.
static DRIVER_EPOCH: AtomicU64 = AtomicU64::new(0);

/// Arm the global grid for `ranks` ranks. Keeps an existing grid of the
/// same size (it is drained to zero at every epoch end, and in-process
/// worker threads may arm concurrently with the driver).
pub fn arm(ranks: usize) {
    let mut g = GRID.lock().unwrap();
    match g.as_ref() {
        Some(grid) if grid.ranks() == ranks => {}
        _ => *g = Some(Arc::new(HeatGrid::new(ranks))),
    }
    ARMED.store(true, Ordering::Release);
}

/// Drop the global grid (tests; production grids stay armed and drained).
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    *GRID.lock().unwrap() = None;
    FOLD.lock().unwrap().clear();
}

/// Fast check used by flush paths before building a sampler.
pub fn is_armed() -> bool {
    ARMED.load(Ordering::Acquire)
}

fn grid() -> Option<Arc<HeatGrid>> {
    if !is_armed() {
        return None;
    }
    GRID.lock().unwrap().clone()
}

/// Per-worker recording handle installed at the outbox flush funnel.
pub struct HeatSampler<M> {
    src: usize,
    grid: Arc<HeatGrid>,
    classify: fn(&M) -> Option<u64>,
}

impl<M> HeatSampler<M> {
    /// `None` when no grid is armed — the untraced fast path.
    pub fn new(src: usize, classify: fn(&M) -> Option<u64>) -> Option<Self> {
        grid().map(|grid| HeatSampler { src, grid, classify })
    }

    /// Test/driver constructor bound to an explicit grid.
    pub fn with_grid(src: usize, grid: Arc<HeatGrid>, classify: fn(&M) -> Option<u64>) -> Self {
        HeatSampler { src, grid, classify }
    }

    /// Record one shipped batch. Books `batch.len() × size_of::<M>()`
    /// bytes — identical to `batch_bytes_estimate`, so grid totals match
    /// `CommStats` exactly wherever stats use the in-memory estimate.
    pub fn record(&self, to: usize, batch: &[M]) {
        if batch.is_empty() {
            return;
        }
        let mut lanes = [0u64; LANES];
        for msg in batch {
            let lane = match (self.classify)(msg) {
                Some(v) => range_of(v),
                None => RANGES,
            };
            lanes[lane] += 1;
        }
        let per = std::mem::size_of::<M>() as u64;
        for (lane, &n) in lanes.iter().enumerate() {
            if n != 0 {
                self.grid.add(self.src, to, lane, n, n * per);
            }
        }
    }
}

/// Fold one remote `heat.cell` into the driver-side accumulator. Cells
/// recorded under a different range count (`k != RANGES_LOG2`, e.g. a
/// version-skewed worker) are diverted whole into the unattributed lane so
/// they are counted but never misbinned.
pub fn fold_remote_cell(src: u64, dst: u64, lane: u64, msgs: u64, bytes: u64, k: u64) {
    let lane = if k == RANGES_LOG2 && (lane as usize) < LANES {
        lane as usize
    } else {
        RANGES
    };
    FOLD.lock().unwrap().push(Cell {
        src: src as usize,
        dst: dst as usize,
        lane,
        msgs,
        bytes,
    });
}

/// Drain the worker-local view of the global grid into `heat.cell` trace
/// events labelled with `epoch`. Socket-backend workers call this right
/// before the STATE-leg `take_delta` (the reliable TELEM leg; REPORT is
/// lossy), and MUST call it outside any `WorkerCtx` borrow — it emits
/// events through `telemetry::event`.
pub fn flush_to_events(epoch: u64) {
    let Some(grid) = grid() else { return };
    for c in grid.drain() {
        super::event(
            "heat.cell",
            &[
                ("src", c.src as u64),
                ("dst", c.dst as u64),
                ("range", c.lane as u64),
                ("msgs", c.msgs),
                ("bytes", c.bytes),
                ("k", RANGES_LOG2),
                ("epoch", epoch),
            ],
        );
    }
}

/// Driver-side: arm the grid for a traced epoch and return its label.
// RELAXED: the epoch label is a monotonic tag taken by the single
// driver thread; nothing synchronizes on it.
pub fn epoch_begin(ranks: usize) -> u64 {
    arm(ranks);
    FOLD.lock().unwrap().clear();
    DRIVER_EPOCH.fetch_add(1, Ordering::Relaxed)
}

/// Driver-side epoch close: drain the local grid (in-memory backends) and
/// the remote fold (socket backends), emit `heat.cell` driver events for
/// locally drained cells plus one `heat.epoch` summary carrying both the
/// matrix byte total and `comm_bytes` (the `CommStats` total) so the
/// reconciliation is recorded in the timeline itself. Returns the summary
/// for `CommStats::heat`.
pub fn epoch_end(epoch: u64, comm_bytes: u64) -> Option<HeatSummary> {
    if !is_armed() {
        return None;
    }
    let mut cells = grid().map(|g| g.drain()).unwrap_or_default();
    // Locally drained cells have not been through the event stream yet;
    // remote cells were written to rank files by ingest_remote.
    for c in &cells {
        super::driver_event(
            "heat.cell",
            &[
                ("src", c.src as u64),
                ("dst", c.dst as u64),
                ("range", c.lane as u64),
                ("msgs", c.msgs),
                ("bytes", c.bytes),
                ("k", RANGES_LOG2),
                ("epoch", epoch),
            ],
        );
    }
    cells.append(&mut std::mem::take(&mut *FOLD.lock().unwrap()));
    let matrix = TrafficMatrix::from_cells(&cells);
    let summary = matrix.summary();
    super::driver_event(
        "heat.epoch",
        &[
            ("epoch", epoch),
            ("ranks", matrix.ranks as u64),
            ("msgs", summary.msgs),
            ("bytes", summary.bytes),
            ("cut_pm", summary.cut_per_mille),
            ("skew_pm", summary.skew_per_mille),
            ("comm_bytes", comm_bytes),
        ],
    );
    Some(summary)
}

// ---------------------------------------------------------------------------
// Driver-side aggregation.
// ---------------------------------------------------------------------------

/// Dense `[src × dst × LANES]` fold of an epoch's heat cells.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrafficMatrix {
    pub ranks: usize,
    msgs: Vec<u64>,
    bytes: Vec<u64>,
}

/// Integer-only epoch summary (per-mille fractions keep `CommStats: Eq`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HeatSummary {
    pub msgs: u64,
    pub bytes: u64,
    /// Cross-rank (src ≠ dst) byte fraction, per mille.
    pub cut_per_mille: u64,
    /// Per-source-rank byte skew: max/mean, per mille (1000 = balanced).
    pub skew_per_mille: u64,
}

impl TrafficMatrix {
    pub fn new(ranks: usize) -> Self {
        TrafficMatrix {
            ranks,
            msgs: vec![0; ranks * ranks * LANES],
            bytes: vec![0; ranks * ranks * LANES],
        }
    }

    /// Build from drained cells; rank count is inferred from coordinates.
    pub fn from_cells(cells: &[Cell]) -> Self {
        let ranks = cells
            .iter()
            .map(|c| c.src.max(c.dst) + 1)
            .max()
            .unwrap_or(0);
        let mut m = TrafficMatrix::new(ranks);
        for c in cells {
            m.add_cell(c);
        }
        m
    }

    pub fn add_cell(&mut self, c: &Cell) {
        if c.src >= self.ranks || c.dst >= self.ranks || c.lane >= LANES {
            return;
        }
        let i = (c.src * self.ranks + c.dst) * LANES + c.lane;
        self.msgs[i] += c.msgs;
        self.bytes[i] += c.bytes;
    }

    pub fn cell(&self, src: usize, dst: usize, lane: usize) -> (u64, u64) {
        let i = (src * self.ranks + dst) * LANES + lane;
        (self.msgs[i], self.bytes[i])
    }

    /// (msgs, bytes) summed over lanes for one (src, dst) pair.
    pub fn pair_total(&self, src: usize, dst: usize) -> (u64, u64) {
        let base = (src * self.ranks + dst) * LANES;
        let m = self.msgs[base..base + LANES].iter().sum();
        let b = self.bytes[base..base + LANES].iter().sum();
        (m, b)
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Bytes crossing ranks (src ≠ dst).
    pub fn cut_bytes(&self) -> u64 {
        let mut cut = 0;
        for s in 0..self.ranks {
            for d in 0..self.ranks {
                if s != d {
                    cut += self.pair_total(s, d).1;
                }
            }
        }
        cut
    }

    pub fn cut_per_mille(&self) -> u64 {
        let total = self.total_bytes();
        if total == 0 {
            0
        } else {
            self.cut_bytes() * 1000 / total
        }
    }

    /// Bytes sent by rank `src`, all destinations.
    pub fn rank_out_bytes(&self, src: usize) -> u64 {
        (0..self.ranks).map(|d| self.pair_total(src, d).1).sum()
    }

    /// max/mean per-source-rank outbound bytes, per mille. 1000 means
    /// perfectly balanced; 0 when there is no traffic.
    pub fn skew_per_mille(&self) -> u64 {
        if self.ranks == 0 {
            return 0;
        }
        let per: Vec<u64> = (0..self.ranks).map(|s| self.rank_out_bytes(s)).collect();
        let total: u64 = per.iter().sum();
        if total == 0 {
            return 0;
        }
        let max = *per.iter().max().unwrap();
        max * 1000 * self.ranks as u64 / total
    }

    /// Top-`k` hashed ranges by cross-rank bytes, descending, ties by
    /// range index. The unattributed lane is excluded — it names no
    /// vertices a placement pass could move.
    pub fn top_ranges(&self, k: usize) -> Vec<(usize, u64)> {
        let mut per = vec![0u64; RANGES];
        for s in 0..self.ranks {
            for d in 0..self.ranks {
                if s == d {
                    continue;
                }
                let base = (s * self.ranks + d) * LANES;
                for (r, slot) in per.iter_mut().enumerate() {
                    *slot += self.bytes[base + r];
                }
            }
        }
        let mut ranked: Vec<(usize, u64)> = per.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(k);
        ranked.retain(|&(_, b)| b != 0);
        ranked
    }

    pub fn summary(&self) -> HeatSummary {
        HeatSummary {
            msgs: self.total_msgs(),
            bytes: self.total_bytes(),
            cut_per_mille: self.cut_per_mille(),
            skew_per_mille: self.skew_per_mille(),
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-dir replay for `degreesketch heatmap`.
// ---------------------------------------------------------------------------

fn field(ev: &super::trace::TraceEvent, name: &str) -> u64 {
    ev.fields
        .iter()
        .find(|(k, _)| k == name)
        .map(|&(_, v)| v)
        .unwrap_or(0)
}

/// Rebuild per-epoch traffic matrices from a merged timeline and render
/// the heatmap report: matrix, cut fraction, skew, top-K hot ranges, and
/// the recorded `heat.epoch` reconciliation numbers.
pub fn render_report(tl: &Timeline, top_k: usize) -> String {
    // Group heat.cell events by their own epoch label (worker generations
    // and driver counters are independent sequences; each labels a
    // coherent pass).
    let mut cells: BTreeMap<u64, Vec<Cell>> = BTreeMap::new();
    let mut summaries: BTreeMap<u64, Vec<(u64, u64, u64, u64, u64)>> = BTreeMap::new();
    for me in &tl.events {
        let ev = &me.event;
        if ev.kind == "heat.cell" {
            cells.entry(field(ev, "epoch")).or_default().push(Cell {
                src: field(ev, "src") as usize,
                dst: field(ev, "dst") as usize,
                lane: if field(ev, "k") == RANGES_LOG2 {
                    (field(ev, "range") as usize).min(RANGES)
                } else {
                    RANGES
                },
                msgs: field(ev, "msgs"),
                bytes: field(ev, "bytes"),
            });
        } else if ev.kind == "heat.epoch" {
            summaries.entry(field(ev, "epoch")).or_default().push((
                field(ev, "bytes"),
                field(ev, "comm_bytes"),
                field(ev, "cut_pm"),
                field(ev, "skew_pm"),
                field(ev, "msgs"),
            ));
        }
    }
    if cells.is_empty() && summaries.is_empty() {
        return "no heat events in trace (run with --trace-dir)\n".to_string();
    }
    let mut out = String::new();
    for (epoch, group) in &cells {
        let m = TrafficMatrix::from_cells(group);
        let s = m.summary();
        out.push_str(&format!(
            "epoch {epoch}: ranks={} msgs={} bytes={} cut={}.{}% skew={}.{:03}x\n",
            m.ranks,
            s.msgs,
            s.bytes,
            s.cut_per_mille / 10,
            s.cut_per_mille % 10,
            s.skew_per_mille / 1000,
            s.skew_per_mille % 1000,
        ));
        out.push_str("  bytes src\\dst");
        for d in 0..m.ranks {
            out.push_str(&format!(" {d:>10}"));
        }
        out.push('\n');
        for src in 0..m.ranks {
            out.push_str(&format!("  {src:>13}"));
            for d in 0..m.ranks {
                out.push_str(&format!(" {:>10}", m.pair_total(src, d).1));
            }
            out.push('\n');
        }
        let hot = m.top_ranges(top_k);
        if !hot.is_empty() {
            out.push_str("  hot ranges (cut bytes):");
            for (r, b) in hot {
                out.push_str(&format!(" r{r:02}={b}"));
            }
            out.push('\n');
        }
    }
    for (epoch, recs) in &summaries {
        for (bytes, comm_bytes, cut_pm, skew_pm, msgs) in recs {
            let verdict = if bytes == comm_bytes {
                "exact"
            } else {
                "estimate"
            };
            out.push_str(&format!(
                "heat.epoch {epoch}: msgs={msgs} matrix_bytes={bytes} comm_bytes={comm_bytes} ({verdict}) cut_pm={cut_pm} skew_pm={skew_pm}\n",
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::trace::{MergedEvent, TraceEvent};
    use super::*;

    fn cell(src: usize, dst: usize, lane: usize, msgs: u64, bytes: u64) -> Cell {
        Cell { src, dst, lane, msgs, bytes }
    }

    #[test]
    fn range_of_is_deterministic_and_bounded() {
        for v in [0u64, 1, 7, 1 << 40, u64::MAX] {
            let r = range_of(v);
            assert!(r < RANGES);
            assert_eq!(r, range_of(v));
        }
        // The hash actually spreads: 256 consecutive ids hit many ranges.
        let mut seen = [false; RANGES];
        for v in 0..256u64 {
            seen[range_of(v)] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= RANGES / 2);
    }

    #[test]
    fn grid_accumulates_and_drain_zeroes() {
        let g = HeatGrid::new(3);
        g.add(0, 2, 5, 4, 64);
        g.add(0, 2, 5, 1, 16);
        g.add(2, 0, RANGES, 7, 7);
        g.add(9, 0, 0, 1, 1); // out of range: dropped
        let mut cells = g.drain();
        cells.sort_by_key(|c| (c.src, c.dst, c.lane));
        assert_eq!(
            cells,
            vec![cell(0, 2, 5, 5, 80), cell(2, 0, RANGES, 7, 7)]
        );
        assert!(g.drain().is_empty());
    }

    #[test]
    fn sampler_classifies_and_books_size_of_bytes() {
        let g = std::sync::Arc::new(HeatGrid::new(2));
        // Messages are (vertex, payload); odd vertices unattributed.
        fn classify(m: &(u64, u64)) -> Option<u64> {
            if m.0 % 2 == 0 {
                Some(m.0)
            } else {
                None
            }
        }
        let s = HeatSampler::with_grid(1, g.clone(), classify);
        s.record(0, &[(2, 9), (2, 9), (3, 9)]);
        let cells = g.drain();
        let total_msgs: u64 = cells.iter().map(|c| c.msgs).sum();
        let total_bytes: u64 = cells.iter().map(|c| c.bytes).sum();
        assert_eq!(total_msgs, 3);
        assert_eq!(total_bytes, 3 * std::mem::size_of::<(u64, u64)>() as u64);
        let unattributed: u64 = cells
            .iter()
            .filter(|c| c.lane == RANGES)
            .map(|c| c.msgs)
            .sum();
        assert_eq!(unattributed, 1);
        let attributed = cells.iter().find(|c| c.lane == range_of(2)).unwrap();
        assert_eq!((attributed.src, attributed.dst, attributed.msgs), (1, 0, 2));
    }

    #[test]
    fn matrix_cut_skew_and_top_ranges() {
        let cells = vec![
            cell(0, 0, 1, 10, 1000), // local
            cell(0, 1, 2, 10, 3000), // cut
            cell(1, 0, 3, 10, 1000), // cut
            cell(1, 1, 2, 10, 1000), // local
        ];
        let m = TrafficMatrix::from_cells(&cells);
        assert_eq!(m.ranks, 2);
        assert_eq!(m.total_bytes(), 6000);
        assert_eq!(m.cut_bytes(), 4000);
        assert_eq!(m.cut_per_mille(), 666);
        // rank0 sends 4000, rank1 sends 2000; max/mean = 4000/3000.
        assert_eq!(m.skew_per_mille(), 1333);
        assert_eq!(m.top_ranges(2), vec![(2, 3000), (3, 1000)]);
        let s = m.summary();
        assert_eq!(s.msgs, 40);
        assert_eq!(s.cut_per_mille, 666);
    }

    #[test]
    fn fold_diverts_k_mismatch_to_unattributed() {
        // Pure-function check via TrafficMatrix (the global FOLD is
        // exercised by the e2e suite): mimic fold_remote_cell's lane rule.
        let lane_for = |lane: u64, k: u64| -> usize {
            if k == RANGES_LOG2 && (lane as usize) < LANES {
                lane as usize
            } else {
                RANGES
            }
        };
        assert_eq!(lane_for(3, RANGES_LOG2), 3);
        assert_eq!(lane_for(3, RANGES_LOG2 + 1), RANGES);
        assert_eq!(lane_for(99, RANGES_LOG2), RANGES);
    }

    #[test]
    fn global_arm_sampler_fold_epoch_roundtrip() {
        // Serialise against other tests touching the global grid.
        disarm();
        let epoch = epoch_begin(2);
        assert!(is_armed());
        let s = HeatSampler::new(0, |v: &u64| Some(*v)).expect("armed grid");
        s.record(1, &[4u64, 4, 4]);
        fold_remote_cell(1, 0, 0, 2, 16, RANGES_LOG2);
        fold_remote_cell(1, 0, 0, 1, 8, 99); // k mismatch -> unattributed
        let sum = epoch_end(epoch, 24 + 3 * 8).expect("summary");
        assert_eq!(sum.msgs, 6);
        assert_eq!(sum.bytes, 3 * 8 + 16 + 8);
        // Everything crosses ranks here.
        assert_eq!(sum.cut_per_mille, 1000);
        // Grid + fold fully drained.
        let again = epoch_end(epoch, 0).expect("armed");
        assert_eq!(again.msgs, 0);
        disarm();
    }

    #[test]
    fn render_report_rebuilds_matrix_from_events() {
        let mk = |kind: &str, fields: Vec<(&str, u64)>| MergedEvent {
            t_rel: 0,
            event: TraceEvent {
                t_us: 0,
                rank: -1,
                seq: 0,
                kind: kind.to_string(),
                fields: fields
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            },
        };
        let tl = Timeline {
            events: vec![
                mk(
                    "heat.cell",
                    vec![
                        ("src", 0),
                        ("dst", 1),
                        ("range", 2),
                        ("msgs", 5),
                        ("bytes", 80),
                        ("k", RANGES_LOG2),
                        ("epoch", 7),
                    ],
                ),
                mk(
                    "heat.epoch",
                    vec![
                        ("epoch", 7),
                        ("ranks", 2),
                        ("msgs", 5),
                        ("bytes", 80),
                        ("cut_pm", 1000),
                        ("skew_pm", 2000),
                        ("comm_bytes", 80),
                    ],
                ),
            ],
            malformed: 0,
            truncated: 0,
        };
        let report = render_report(&tl, 4);
        assert!(report.contains("epoch 7: ranks=2 msgs=5 bytes=80"), "{report}");
        assert!(report.contains("cut=100.0%"), "{report}");
        assert!(report.contains("hot ranges (cut bytes): r02=80"), "{report}");
        assert!(report.contains("matrix_bytes=80 comm_bytes=80 (exact)"), "{report}");
    }

    #[test]
    fn empty_timeline_renders_hint() {
        let tl = Timeline { events: vec![], malformed: 0, truncated: 0 };
        assert!(render_report(&tl, 4).contains("no heat events"));
    }
}
