//! The TELEM codec leg: worker→driver telemetry deltas piggybacked on
//! REPORT and STATE frames.
//!
//! A delta blob is self-delimiting and self-checking so it can ride as
//! an optional trailing extension of an existing frame payload:
//!
//! ```text
//! "DTEL" | ver u8 | gen u16 | n_counters u32 | {name_len u16, name, delta u64}*
//!        | n_events u32 | {t_us u64, seq u64, kind_len u16, kind,
//!                          n_fields u8, {key_len u16, key, val u64}*}*
//!        | dropped u64 | crc32 u32
//! ```
//!
//! The CRC covers every preceding byte and is verified *first*, so any
//! single byte flip anywhere in the blob is rejected before parsing
//! (property-tested below). `gen` carries the worker's fabric
//! generation; the driver sink drops blobs from stale generations so a
//! rolled-back worker cannot double-count its pre-recovery deltas.

use super::trace::TraceEvent;
use crate::comm::codec::{get_u32, get_u64, put_u32, put_u64, WireError};
use crate::util::crc32::crc32;

const MAGIC: &[u8; 4] = b"DTEL";
const VERSION: u8 = 1;

/// Defensive parse caps — a corrupt length field must not allocate.
const MAX_COUNTERS: u32 = 4096;
const MAX_EVENTS: u32 = 1 << 17;
const MAX_NAME: u16 = 256;
const MAX_FIELDS: u8 = 16;

/// One worker telemetry delta: counter increments since the last ship
/// plus buffered trace events.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TelemDelta {
    /// Fabric generation the delta was recorded under.
    pub gen: u16,
    /// `(metric name, increment)` pairs.
    pub counters: Vec<(String, u64)>,
    /// Trace events buffered since the last ship.
    pub events: Vec<TraceEvent>,
    /// Events discarded because the ring was full.
    pub dropped: u64,
}

impl TelemDelta {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.events.is_empty() && self.dropped == 0
    }

    /// Encode to a self-checking blob.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&self.gen.to_le_bytes());
        put_u32(&mut out, self.counters.len() as u32);
        for (name, delta) in &self.counters {
            put_str(&mut out, name);
            put_u64(&mut out, *delta);
        }
        put_u32(&mut out, self.events.len() as u32);
        for ev in &self.events {
            put_u64(&mut out, ev.t_us);
            put_u64(&mut out, ev.seq);
            put_str(&mut out, &ev.kind);
            out.push(ev.fields.len().min(MAX_FIELDS as usize) as u8);
            for (k, v) in ev.fields.iter().take(MAX_FIELDS as usize) {
                put_str(&mut out, k);
                put_u64(&mut out, *v);
            }
        }
        put_u64(&mut out, self.dropped);
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Decode a blob, consuming `input` exactly. The CRC is verified
    /// over the whole slice before any field is trusted.
    pub fn decode(input: &mut &[u8]) -> Result<TelemDelta, WireError> {
        let buf = *input;
        if buf.len() < MAGIC.len() + 1 + 2 + 4 + 4 + 8 + 4 {
            return Err(WireError::Truncated);
        }
        let (body, crc_bytes) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        let actual = crc32(body);
        if actual != stored {
            return Err(WireError::BadCrc { stored, actual });
        }
        let mut rest = body;
        if rest[..4] != *MAGIC {
            return Err(WireError::Invalid("telem magic".into()));
        }
        rest = &rest[4..];
        if rest[0] != VERSION {
            return Err(WireError::Invalid("telem version".into()));
        }
        let gen = u16::from_le_bytes([rest[1], rest[2]]);
        rest = &rest[3..];
        let mut out = TelemDelta {
            gen,
            ..Default::default()
        };
        let n_counters = get_u32(&mut rest)?;
        if n_counters > MAX_COUNTERS {
            return Err(WireError::Invalid("telem counter count".into()));
        }
        for _ in 0..n_counters {
            let name = get_str(&mut rest)?;
            let delta = get_u64(&mut rest)?;
            out.counters.push((name, delta));
        }
        let n_events = get_u32(&mut rest)?;
        if n_events > MAX_EVENTS {
            return Err(WireError::Invalid("telem event count".into()));
        }
        for _ in 0..n_events {
            let t_us = get_u64(&mut rest)?;
            let seq = get_u64(&mut rest)?;
            let kind = get_str(&mut rest)?;
            if rest.is_empty() {
                return Err(WireError::Truncated);
            }
            let n_fields = rest[0];
            rest = &rest[1..];
            if n_fields > MAX_FIELDS {
                return Err(WireError::Invalid("telem field count".into()));
            }
            let mut fields = Vec::with_capacity(n_fields as usize);
            for _ in 0..n_fields {
                let k = get_str(&mut rest)?;
                let v = get_u64(&mut rest)?;
                fields.push((k, v));
            }
            out.events.push(TraceEvent {
                t_us,
                // Rank is assigned by the driver sink from the channel
                // the blob arrived on — the wire doesn't carry it.
                rank: 0,
                seq,
                kind,
                fields,
            });
        }
        out.dropped = get_u64(&mut rest)?;
        if !rest.is_empty() {
            return Err(WireError::Invalid("telem trailing bytes".into()));
        }
        *input = &[];
        Ok(out)
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = &s.as_bytes()[..s.len().min(MAX_NAME as usize)];
    out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn get_str(input: &mut &[u8]) -> Result<String, WireError> {
    if input.len() < 2 {
        return Err(WireError::Truncated);
    }
    let len = u16::from_le_bytes([input[0], input[1]]) as usize;
    if len > MAX_NAME as usize {
        return Err(WireError::Invalid("telem name length".into()));
    }
    let rest = &input[2..];
    if rest.len() < len {
        return Err(WireError::Truncated);
    }
    let s = std::str::from_utf8(&rest[..len])
        .map_err(|_| WireError::Invalid("telem name utf8".into()))?
        .to_string();
    *input = &rest[len..];
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn sample(gen: u16) -> TelemDelta {
        TelemDelta {
            gen,
            counters: vec![
                ("degreesketch_chaos_faults_total".into(), 3),
                ("degreesketch_fabric_hb_stale_ms".into(), 1200),
            ],
            events: vec![
                TraceEvent {
                    t_us: 10,
                    rank: 0,
                    seq: 0,
                    kind: "epoch.start".into(),
                    fields: vec![],
                },
                TraceEvent {
                    t_us: 55,
                    rank: 0,
                    seq: 1,
                    kind: "ckpt.store".into(),
                    fields: vec![("barrier".into(), 2), ("bytes".into(), 9000)],
                },
            ],
            dropped: 1,
        }
    }

    #[test]
    fn round_trip() {
        for d in [TelemDelta::default(), sample(0), sample(7)] {
            let blob = d.encode();
            let mut input = &blob[..];
            let back = TelemDelta::decode(&mut input).expect("decode");
            assert!(input.is_empty());
            assert_eq!(back.gen, d.gen);
            assert_eq!(back.counters, d.counters);
            assert_eq!(back.dropped, d.dropped);
            assert_eq!(back.events.len(), d.events.len());
            for (a, b) in back.events.iter().zip(&d.events) {
                assert_eq!((a.t_us, a.seq, &a.kind, &a.fields), (b.t_us, b.seq, &b.kind, &b.fields));
            }
        }
    }

    /// Every single byte flip anywhere in the blob must be rejected.
    #[test]
    fn any_byte_flip_is_rejected() {
        let blob = sample(3).encode();
        for i in 0..blob.len() {
            for bit in [0x01u8, 0x80] {
                let mut bad = blob.clone();
                bad[i] ^= bit;
                let mut input = &bad[..];
                assert!(
                    TelemDelta::decode(&mut input).is_err(),
                    "flip at byte {i} bit {bit:#x} was accepted"
                );
            }
        }
    }

    #[test]
    fn truncation_is_rejected() {
        let blob = sample(1).encode();
        for cut in 0..blob.len() {
            let mut input = &blob[..cut];
            assert!(TelemDelta::decode(&mut input).is_err(), "cut at {cut}");
        }
    }

    /// Random structurally-valid deltas survive the round trip.
    #[test]
    fn round_trip_fuzz() {
        Cases::new("telem_wire_round_trip", 100).run(|rng| {
            let mut d = TelemDelta {
                gen: (rng.next_u64() & 0xFFFF) as u16,
                dropped: rng.next_u64() % 100,
                ..Default::default()
            };
            for i in 0..(rng.next_u64() % 8) {
                d.counters.push((format!("metric_{i}"), rng.next_u64()));
            }
            for i in 0..(rng.next_u64() % 8) {
                let mut fields = Vec::new();
                for j in 0..(rng.next_u64() % 4) {
                    fields.push((format!("k{j}"), rng.next_u64()));
                }
                d.events.push(TraceEvent {
                    t_us: rng.next_u64() % 1_000_000,
                    rank: 0,
                    seq: i,
                    kind: format!("kind.{}", rng.next_u64() % 10),
                    fields,
                });
            }
            let blob = d.encode();
            let mut input = &blob[..];
            let back = TelemDelta::decode(&mut input).unwrap();
            assert_eq!(back, d);
        });
    }
}
