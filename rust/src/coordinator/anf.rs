//! **Algorithm 2**: local t-neighborhood size estimation — the distributed
//! HyperANF generalization.
//!
//! Starting from an accumulated `D¹` (Algorithm 1), each pass `t` builds
//! `Dᵗ[x] = Dᵗ⁻¹[x] ∪̃ ⋃̃_{y: xy∈E} Dᵗ⁻¹[y]` by re-streaming σ: processor
//! `P` reads `uv` and sends an EDGE message to `f(u)` (and `f(v)`); on
//! EDGE `(x, y)` the owner forwards `Dᵗ⁻¹[x]` as a SKETCH message to
//! `f(y)`, which merges it into `Dᵗ[y]`. After each pass,
//! `Ñ(x,t) = |Dᵗ[x]|` and `Ñ(t) = Σ_x Ñ(x,t)` is REDUCEd globally
//! (Theorem 1 gives the bias/variance guarantees).
//!
//! The working layers `Dᵗ⁻¹`/`Dᵗ` are arena-backed [`SketchStore`]s:
//! cloning a layer between passes is a contiguous memcpy instead of
//! thousands of per-sketch allocations, and when `f(y)` is the local rank
//! the SKETCH "message" is a **borrowed register view** merged straight
//! from `Dᵗ⁻¹`'s arena into `Dᵗ`'s — no `Hll` clone, no queue round trip.
//! Cross-rank forwards are **batched per destination rank**: EDGE targets
//! buffer locally and flush as FAN messages grouped by source vertex, so
//! a vertex whose sketch feeds many neighbors on one rank materializes
//! (and ships) once per flush instead of once per edge.
//!
//! Semantics note (matches the paper's construction): `D¹[x]` sketches the
//! *adjacency set* of `x`, so `Ñ(x,1)` estimates `d(x)`; for `t ≥ 2`,
//! `Dᵗ[x]` covers every vertex within distance `t` **including** `x`
//! itself (x enters through any neighbor's adjacency sketch), i.e.
//! `Ñ(x,t) ≈ N(x,t)` of Eq. 1.

use std::collections::HashMap;

use crate::comm::codec::{
    decode_hll, encode_hll_into, get_u32, get_u64, get_u8, put_u32, put_u64,
    put_u8,
};
use crate::comm::{
    codec, run_epoch_wire_full, Actor, Backend, CommStats, FabricActor,
    FaultPolicy, FlushPolicy, Outbox, WireActor, WireError, WireMsg,
};
use crate::graph::stream::{EdgeStream, MemoryStream};
use crate::graph::VertexId;
use crate::hll::{Estimator, Hll, SketchStore};

use super::partition::Partitioner;
use super::sketch::{DegreeSketch, Shard};

/// Result of the t-neighborhood estimation.
#[derive(Debug, Clone)]
pub struct AnfResult {
    /// `estimates[x] = [Ñ(x,1), …, Ñ(x,k)]`.
    pub per_vertex: HashMap<VertexId, Vec<f64>>,
    /// `global[t-1] = Ñ(t)` (the REDUCE of line 19).
    pub global: Vec<f64>,
    /// Wall-clock seconds per pass `t = 2..=k` (Figure 4's series).
    pub pass_seconds: Vec<f64>,
    /// Comm stats per pass.
    pub pass_stats: Vec<CommStats>,
}

/// Options for Algorithm 2.
#[derive(Debug, Clone, Copy)]
pub struct AnfOptions {
    pub backend: Backend,
    /// Maximum neighborhood degree `k` (passes run for t = 2..=k).
    pub max_t: usize,
    pub estimator: Estimator,
    /// Keep all `Dᵗ` layers? (The paper notes they can be stored for later
    /// use; we keep only the live layer unless asked.)
    pub keep_layers: bool,
    /// Comm-plane flush policy (ignored by the sequential backend).
    pub flush: FlushPolicy,
    /// Fault-tolerance policy (socket backends): each pass becomes a
    /// checkpointed epoch that survives worker death. Default: off.
    pub fault: FaultPolicy,
}

impl Default for AnfOptions {
    fn default() -> Self {
        Self {
            backend: Backend::Sequential,
            max_t: 5,
            estimator: Estimator::default(),
            keep_layers: false,
            flush: FlushPolicy::default(),
            fault: FaultPolicy::default(),
        }
    }
}

/// Cross-rank EDGE targets buffered per destination before a FAN flush.
const ANF_FAN_BATCH: usize = 1024;

/// Algorithm 2's message alphabet (public so the comm-plane property
/// tests can round-trip it through the wire codec).
#[derive(Debug, Clone, PartialEq)]
pub enum AnfMsg {
    /// EDGE (x, y): deliver to f(x); owner forwards its sketch to f(y).
    Edge(VertexId, VertexId),
    /// FAN (Dᵗ⁻¹[x], targets): merge the carried sketch into every
    /// Dᵗ[y] at the destination rank. Cross-rank only — rank-local
    /// forwards merge borrowed views without materializing — and grouped
    /// by source vertex, so `x`'s registers ship once per flush however
    /// many of its neighbors live on the destination.
    Fan(Hll, Vec<VertexId>),
}

const ANF_TAG_EDGE: u8 = 0;
const ANF_TAG_FAN: u8 = 1;

impl WireMsg for AnfMsg {
    fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            AnfMsg::Edge(x, y) => {
                put_u8(buf, ANF_TAG_EDGE);
                put_u64(buf, *x);
                put_u64(buf, *y);
            }
            AnfMsg::Fan(sketch, targets) => {
                put_u8(buf, ANF_TAG_FAN);
                encode_hll_into(sketch, buf);
                put_u32(buf, targets.len() as u32);
                for &t in targets {
                    put_u64(buf, t);
                }
            }
        }
    }

    fn decode(input: &mut &[u8]) -> Result<Self, WireError> {
        match get_u8(input)? {
            ANF_TAG_EDGE => Ok(AnfMsg::Edge(get_u64(input)?, get_u64(input)?)),
            ANF_TAG_FAN => {
                let sketch = decode_hll(input)?;
                let n = get_u32(input)? as usize;
                let mut targets = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    targets.push(get_u64(input)?);
                }
                Ok(AnfMsg::Fan(sketch, targets))
            }
            other => Err(WireError::Invalid(format!("bad AnfMsg tag {other}"))),
        }
    }
}

struct AnfActor {
    rank: usize,
    ranks: usize,
    partitioner: Partitioner,
    substream: MemoryStream,
    /// Dᵗ⁻¹ (read-only this pass).
    prev: SketchStore,
    /// Dᵗ (starts as a clone of prev — Alg. 2 line 23).
    next: SketchStore,
    /// Per-destination-rank buffers of pending `(x, y)` forwards.
    fwd: Vec<Vec<(VertexId, VertexId)>>,
}

impl AnfActor {
    /// Flush one destination's buffer: group by source vertex and emit
    /// one FAN per source (one sketch materialization per group).
    fn flush_fwd(&mut self, dst: usize, out: &mut Outbox<AnfMsg>) {
        let mut buf = std::mem::take(&mut self.fwd[dst]);
        if buf.is_empty() {
            return;
        }
        buf.sort_unstable();
        let mut i = 0;
        while i < buf.len() {
            let x = buf[i].0;
            let mut targets = Vec::new();
            while i < buf.len() && buf[i].0 == x {
                targets.push(buf[i].1);
                i += 1;
            }
            let sketch = self
                .prev
                .get(x)
                .expect("buffered forwards only for present sketches")
                .to_hll();
            out.send(dst, AnfMsg::Fan(sketch, targets));
        }
        // hand the (now empty) allocation back for reuse
        buf.clear();
        self.fwd[dst] = buf;
    }
}

impl Actor for AnfActor {
    type Msg = AnfMsg;

    fn seed(&mut self, out: &mut Outbox<AnfMsg>) {
        let ranks = self.ranks;
        let part = self.partitioner;
        self.substream.for_each(&mut |(u, v)| {
            if u == v {
                return;
            }
            out.send(part.rank_of(u, ranks), AnfMsg::Edge(u, v));
            out.send(part.rank_of(v, ranks), AnfMsg::Edge(v, u));
        });
    }

    fn on_message(&mut self, msg: AnfMsg, out: &mut Outbox<AnfMsg>) {
        match msg {
            AnfMsg::Edge(x, y) => {
                // forward Dᵗ⁻¹[x] to y's owner
                if let Some(view) = self.prev.get(x) {
                    let dst = self.partitioner.rank_of(y, self.ranks);
                    if dst == self.rank {
                        // zero-copy: merge the borrowed view in place
                        self.next.merge_ref(y, view);
                    } else {
                        self.fwd[dst].push((x, y));
                        if self.fwd[dst].len() >= ANF_FAN_BATCH {
                            self.flush_fwd(dst, out);
                        }
                    }
                }
            }
            AnfMsg::Fan(sk, targets) => {
                // Dᵗ[y] ∪̃= Dᵗ⁻¹[x] for every grouped target
                for y in targets {
                    self.next.merge_hll(y, &sk);
                }
            }
        }
    }

    fn on_idle(&mut self, out: &mut Outbox<AnfMsg>) {
        // quiescence: drain the partial per-rank buffers
        for dst in 0..self.ranks {
            self.flush_fwd(dst, out);
        }
    }

    fn heat_vertex(msg: &AnfMsg) -> Option<u64> {
        match msg {
            // EDGE routes on f(x)
            AnfMsg::Edge(x, _) => Some(*x),
            // a FAN's targets all share one destination rank, so any
            // target names the range; use the first
            AnfMsg::Fan(_, targets) => targets.first().copied(),
        }
    }
}

impl WireActor for AnfActor {
    fn write_state(&self, buf: &mut Vec<u8>) {
        // the pass only mutates Dᵗ; on_idle drained the fan buffers
        debug_assert!(self.fwd.iter().all(Vec::is_empty));
        codec::encode_store_into(&self.next, buf);
    }

    fn read_state(&mut self, input: &mut &[u8]) -> Result<(), WireError> {
        self.next = codec::decode_store(*self.next.config(), input)?;
        // read_state must land the actor exactly in the written state:
        // a checkpoint rollback applies it to a mid-epoch actor whose
        // fan buffers may hold post-barrier forwards
        for buf in &mut self.fwd {
            buf.clear();
        }
        Ok(())
    }
}

/// seed_state leg: one ANF pass's inputs are the rank/partition
/// context, this rank's substream, and the previous layer `Dᵗ⁻¹`
/// (shipped once — the worker clones it into `Dᵗ`, exactly as the
/// driver-side constructor does).
impl FabricActor for AnfActor {
    const KIND: &'static str = "anf-pass";

    fn write_seed(&self, buf: &mut Vec<u8>) {
        codec::put_u64(buf, self.rank as u64);
        codec::put_u64(buf, self.ranks as u64);
        self.partitioner.encode_into(buf);
        codec::encode_config_into(self.prev.config(), buf);
        codec::encode_edges_into(self.substream.edges(), buf);
        codec::encode_store_into(&self.prev, buf);
    }

    fn read_seed(input: &mut &[u8]) -> Result<Self, WireError> {
        let rank = codec::get_u64(input)? as usize;
        let ranks = codec::get_u64(input)? as usize;
        if ranks == 0 || rank >= ranks {
            return Err(WireError::Invalid(format!(
                "seed rank {rank} outside 0..{ranks}"
            )));
        }
        let partitioner = super::Partitioner::decode(input)?;
        let config = codec::decode_config(input)?;
        let edges = codec::decode_edges(input)?;
        let prev = codec::decode_store(config, input)?;
        Ok(Self {
            rank,
            ranks,
            partitioner,
            substream: MemoryStream::new(edges),
            next: prev.clone(),
            prev,
            fwd: vec![Vec::new(); ranks],
        })
    }

    fn input_len(&self) -> usize {
        self.substream.edges().len()
    }

    fn seed_range(
        &mut self,
        start: usize,
        end: usize,
        out: &mut Outbox<AnfMsg>,
    ) {
        let ranks = self.ranks;
        let part = self.partitioner;
        for &(u, v) in &self.substream.edges()[start..end] {
            if u == v {
                continue;
            }
            out.send(part.rank_of(u, ranks), AnfMsg::Edge(u, v));
            out.send(part.rank_of(v, ranks), AnfMsg::Edge(v, u));
        }
    }
}

/// Register Algorithm 2's actor kind on a tcp worker dispatch.
pub(crate) fn register_fabric(
    dispatch: crate::comm::tcp::WorkerDispatch,
) -> crate::comm::tcp::WorkerDispatch {
    dispatch.register::<AnfActor>()
}

/// Rehydrate a frozen shard into a mutable arena store.
fn store_from_shard(shard: &Shard, config: crate::hll::HllConfig) -> SketchStore {
    let mut store = SketchStore::new(config);
    for (v, h) in shard.iter() {
        store.merge_hll(v, h);
    }
    store
}

/// **Algorithm 2** — run `max_t - 1` sketch-propagation passes over the
/// (pre-sharded) stream and collect per-vertex and global estimates.
pub fn neighborhood_approximation(
    d1: &DegreeSketch,
    substreams: &[MemoryStream],
    opts: AnfOptions,
) -> AnfResult {
    assert_eq!(
        substreams.len(),
        d1.num_ranks(),
        "substream count must match DegreeSketch rank count"
    );
    assert!(opts.max_t >= 1);
    let ranks = d1.num_ranks();
    let part = d1.partitioner();
    let config = *d1.config();

    let mut per_vertex: HashMap<VertexId, Vec<f64>> = HashMap::new();
    let mut global = Vec::with_capacity(opts.max_t);
    let mut pass_seconds = Vec::new();
    let mut pass_stats = Vec::new();

    // t = 1: estimates straight from D¹ (computation context, lines 17-19).
    let mut layer: Vec<SketchStore> = d1
        .shards()
        .iter()
        .map(|s| store_from_shard(s, config))
        .collect();
    record_estimates(&layer, opts.estimator, &mut per_vertex, &mut global);

    // Flush-policy warm start: pass t+1's per-destination thresholds
    // are seeded from pass t's observed CommStats instead of re-learning
    // from the default every pass (empty = no seeds yet; the sequential
    // backend ignores them, so bit-determinism is unaffected).
    let mut flush_seeds: Vec<usize> = Vec::new();
    for _t in 2..=opts.max_t {
        let start = std::time::Instant::now();
        // Dᵗ ← Dᵗ⁻¹ (line 23), then the message-passing pass.
        let mut actors: Vec<AnfActor> = layer
            .into_iter()
            .zip(substreams.iter().cloned())
            .enumerate()
            .map(|(rank, (prev, substream))| AnfActor {
                rank,
                ranks,
                partitioner: part,
                substream,
                next: prev.clone(),
                prev,
                fwd: vec![Vec::new(); ranks],
            })
            .collect();
        let stats = run_epoch_wire_full(
            opts.backend,
            &mut actors,
            opts.flush,
            &flush_seeds,
            opts.fault,
        );
        layer = actors.into_iter().map(|a| a.next).collect();
        pass_seconds.push(start.elapsed().as_secs_f64());
        if opts.flush.adaptive {
            flush_seeds = opts.flush.seeds_from_stats(&stats);
        }
        pass_stats.push(stats);
        record_estimates(&layer, opts.estimator, &mut per_vertex, &mut global);
    }

    AnfResult {
        per_vertex,
        global,
        pass_seconds,
        pass_stats,
    }
}

fn record_estimates(
    layer: &[SketchStore],
    estimator: Estimator,
    per_vertex: &mut HashMap<VertexId, Vec<f64>>,
    global: &mut Vec<f64>,
) {
    // Ñ(x,t) per vertex; Ñ(t) as the REDUCE sum. Vertices are visited in
    // sorted order so the floating-point sum is identical across backends
    // (hash iteration order would otherwise perturb the last ulp).
    let mut sum = 0.0;
    for store in layer {
        for v in store.vertices_sorted() {
            let est = store
                .estimate_with(v, estimator)
                .expect("vertex present in layer");
            per_vertex.entry(v).or_default().push(est);
            sum += est;
        }
    }
    global.push(sum);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::csr::Csr;
    use crate::graph::exact;
    use crate::graph::gen::{karate, GraphSpec};
    use crate::graph::Edge;
    use crate::hll::HllConfig;

    fn run_anf(
        edges: Vec<Edge>,
        ranks: usize,
        p: u8,
        max_t: usize,
        backend: Backend,
    ) -> AnfResult {
        let stream = MemoryStream::new(edges);
        let cfg = HllConfig::new(p, 0xA2F);
        let ds = accumulate_stream(
            &stream,
            ranks,
            cfg,
            AccumulateOptions {
                backend,
                ..Default::default()
            },
        );
        let shards = stream.shard(ranks);
        neighborhood_approximation(
            &ds,
            &shards,
            AnfOptions {
                backend,
                max_t,
                ..Default::default()
            },
        )
    }

    #[test]
    fn karate_neighborhoods_match_bfs() {
        let edges = karate::edges();
        let csr = Csr::from_edges(&edges);
        let truth = exact::neighborhood_sizes(&csr, 4);
        let res = run_anf(edges, 3, 12, 4, Backend::Sequential);
        for v in 0..csr.num_vertices() as u32 {
            let id = csr.original_id(v);
            let est = &res.per_vertex[&id];
            // t = 1 estimates degree; t >= 2 estimates N(x,t) incl. source.
            let d = csr.degree(v) as f64;
            assert!(
                (est[0] - d).abs() <= d * 0.2 + 1.0,
                "deg v={v}: est={} truth={d}",
                est[0]
            );
            for t in 2..=4 {
                let tr = truth[v as usize][t - 1] as f64;
                assert!(
                    (est[t - 1] - tr).abs() <= tr * 0.2 + 1.5,
                    "v={v} t={t}: est={} truth={tr}",
                    est[t - 1]
                );
            }
        }
        // global Ñ(t) tracks Σ N(x,t)
        let g_truth = exact::global_neighborhood(&truth);
        for t in 2..=4 {
            let tr = g_truth[t - 1] as f64;
            assert!(
                (res.global[t - 1] - tr).abs() <= tr * 0.1,
                "t={t}: {} vs {tr}",
                res.global[t - 1]
            );
        }
    }

    #[test]
    fn backends_agree_exactly_on_anf() {
        let edges = GraphSpec::parse("er:200:600").unwrap().generate(3);
        let a = run_anf(edges.clone(), 4, 8, 3, Backend::Sequential);
        let b = run_anf(edges.clone(), 4, 8, 3, Backend::Threaded);
        let c = run_anf(edges, 4, 8, 3, Backend::Process);
        // merges commute, so sketches (hence estimates) match exactly —
        // even when every cross-rank sketch rode a socket frame
        assert_eq!(a.global, b.global);
        assert_eq!(a.global, c.global);
        for (v, ests) in &a.per_vertex {
            assert_eq!(ests, &b.per_vertex[v], "vertex {v}");
            assert_eq!(ests, &c.per_vertex[v], "process vertex {v}");
        }
    }

    #[test]
    fn warm_started_passes_match_sequential_exactly() {
        // aggressive adaptive thresholds make pass 2+ start from pass 1's
        // learned per-destination seeds; semantics must be unchanged
        let edges = GraphSpec::parse("ba:300:4").unwrap().generate(9);
        let run = |backend: Backend| {
            let stream = MemoryStream::new(edges.clone());
            let cfg = HllConfig::new(8, 0x3A2F);
            let flush = FlushPolicy {
                threshold: 4,
                adaptive: true,
                min: 2,
                max: 256,
            };
            let ds = accumulate_stream(
                &stream,
                4,
                cfg,
                AccumulateOptions {
                    backend,
                    flush,
                    ..Default::default()
                },
            );
            let shards = stream.shard(4);
            neighborhood_approximation(
                &ds,
                &shards,
                AnfOptions {
                    backend,
                    max_t: 4,
                    flush,
                    ..Default::default()
                },
            )
        };
        let seq = run(Backend::Sequential);
        let thr = run(Backend::Threaded);
        assert_eq!(seq.global, thr.global);
        for (v, ests) in &seq.per_vertex {
            assert_eq!(ests, &thr.per_vertex[v], "vertex {v}");
        }
    }

    #[test]
    fn estimates_are_monotone_in_t() {
        let edges = GraphSpec::parse("ba:300:3").unwrap().generate(1);
        let res = run_anf(edges, 2, 10, 4, Backend::Sequential);
        for (v, ests) in &res.per_vertex {
            for w in ests.windows(2) {
                // union can only grow; estimator is monotone in registers
                assert!(
                    w[1] >= w[0] - 1e-9,
                    "vertex {v}: {ests:?} not monotone"
                );
            }
        }
    }

    #[test]
    fn disconnected_components_stay_bounded() {
        // two disjoint triangles: N(x,t) = 3 forever
        let edges = vec![(0, 1), (1, 2), (0, 2), (10, 11), (11, 12), (10, 12)];
        let res = run_anf(edges, 2, 12, 5, Backend::Sequential);
        for (v, ests) in &res.per_vertex {
            let last = *ests.last().unwrap();
            assert!(
                (last - 3.0).abs() < 0.5,
                "vertex {v} escaped its component: {ests:?}"
            );
        }
    }

    #[test]
    fn fan_batching_sends_fewer_sketch_messages_than_edges() {
        // cross-rank sketch traffic is grouped per (destination, source):
        // total deliveries must be well below EDGE count + one-per-edge
        let edges = GraphSpec::parse("ba:400:6").unwrap().generate(5);
        let m = edges.len() as u64;
        let res = run_anf(edges, 4, 8, 2, Backend::Sequential);
        let msgs = res.pass_stats[0].messages;
        // 2m EDGE seeds; the old path added ~1 SKETCH per cross-rank edge
        // (~1.5m at 4 ranks), the fanned path collapses most of them
        assert!(
            msgs < 2 * m + m,
            "fan batching regressed: {msgs} messages for m={m}"
        );
        assert!(msgs > 2 * m, "cross-rank fans must still flow");
    }

    #[test]
    fn single_rank_never_materializes_messages() {
        // with one rank every SKETCH forward is rank-local; the pass must
        // still be correct and carry zero cross-rank sketch traffic beyond
        // the EDGE seeds
        let edges = karate::edges();
        let m = edges.len() as u64;
        let res = run_anf(edges, 1, 10, 2, Backend::Sequential);
        assert_eq!(res.pass_stats[0].messages, 2 * m); // EDGE only
        assert!(res.global[1] >= res.global[0]);
    }
}
