//! The distributed DegreeSketch dictionary `D` and **Algorithm 1**
//! (single-pass accumulation).
//!
//! Each rank owns a shard: a map from vertex id to that vertex's HLL
//! sketch of its adjacency set. Accumulation streams edges: processor `P`
//! reads `uv` from its substream σ_P and sends `(u, v)` to `f(u)` and
//! `(v, u)` to `f(v)`; the owner INSERTs the opposite endpoint into the
//! vertex's sketch. One pass, `O(ε⁻² n log log n)` total space — the
//! semi-streaming property.
//!
//! The hot path is arena-backed: each rank accumulates into a
//! [`SketchStore`] (contiguous registers, pooled sparse buffers, one
//! shared config) and batches incoming `(x, y)` messages so sparse
//! insertions amortize into sorted-run merges. Because register max
//! commutes, the result is bit-identical to the per-sketch reference path
//! ([`accumulate_reference`], kept for parity tests and perf baselines).
//! After the epoch each store freezes into an immutable [`Shard`] —
//! vertex-sorted, contiguous, borrowable `&Hll`s — which the query
//! engine, ANF and triangle algorithms read.

use std::collections::HashMap;

use crate::comm::{
    codec, run_epoch_with, run_epoch_wire_full, Actor, Backend, CommStats,
    FabricActor, FaultPolicy, FlushPolicy, Outbox, WireActor, WireError,
    WireMsg,
};
use crate::graph::stream::{EdgeStream, MemoryStream};
use crate::graph::{Edge, VertexId};
use crate::hll::{Estimator, Hll, HllConfig, SketchStore};

use super::partition::Partitioner;

/// Messages buffered per rank before a grouped arena merge.
const ACCUM_BATCH: usize = 4096;

/// Algorithm 1's computation context, shared by the store-backed and
/// reference actors so parity tests compare storage layouts against the
/// exact same message stream: read σ_P, send `(u, v)` to `f(u)` and
/// `(v, u)` to `f(v)`, dropping self-loops (paper §5 casts them away).
fn seed_edges(
    substream: &MemoryStream,
    partitioner: Partitioner,
    ranks: usize,
    out: &mut Outbox<Edge>,
) {
    substream.for_each(&mut |(u, v)| {
        if u == v {
            return;
        }
        out.send(partitioner.rank_of(u, ranks), (u, v));
        out.send(partitioner.rank_of(v, ranks), (v, u));
    });
}

/// One rank's frozen shard: vertex-sorted sketches in one contiguous
/// vector plus a flat id → position index.
#[derive(Debug, Clone, Default)]
pub struct Shard {
    index: HashMap<VertexId, u32>,
    entries: Vec<(VertexId, Hll)>,
}

impl Shard {
    /// Freeze an accumulation store (sorts by vertex id).
    pub fn from_store(store: SketchStore) -> Self {
        Self::from_sorted_entries(store.into_sorted_hlls())
    }

    /// Build from entries already sorted by strictly increasing vertex id.
    pub fn from_sorted_entries(entries: Vec<(VertexId, Hll)>) -> Self {
        debug_assert!(entries.windows(2).all(|w| w[0].0 < w[1].0));
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, &(v, _))| (v, i as u32))
            .collect();
        Self { index, entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, v: VertexId) -> Option<&Hll> {
        let i = *self.index.get(&v)?;
        Some(&self.entries[i as usize].1)
    }

    /// Iterate `(vertex, sketch)` in ascending vertex order.
    pub fn iter(&self) -> impl Iterator<Item = (VertexId, &Hll)> {
        self.entries.iter().map(|(v, h)| (*v, h))
    }

    /// Approximate heap footprint in bytes. `Hll::memory_bytes` already
    /// counts the inline struct, which the entries vector capacity term
    /// would double-count — subtract it per entry.
    pub fn memory_bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|(_, h)| h.memory_bytes() - std::mem::size_of::<Hll>())
            .sum::<usize>()
            + self.entries.capacity()
                * std::mem::size_of::<(VertexId, Hll)>()
            + self.index.capacity()
                * (std::mem::size_of::<VertexId>()
                    + std::mem::size_of::<u32>())
    }
}

/// The accumulated DegreeSketch `D`: a sharded map vertex → HLL.
#[derive(Debug, Clone)]
pub struct DegreeSketch {
    config: HllConfig,
    partitioner: Partitioner,
    shards: Vec<Shard>,
    /// Comm statistics of the accumulation epoch (for the scaling benches).
    pub accumulation_stats: CommStats,
}

impl DegreeSketch {
    pub(crate) fn from_parts(
        config: HllConfig,
        partitioner: Partitioner,
        shards: Vec<Shard>,
        accumulation_stats: CommStats,
    ) -> Self {
        Self {
            config,
            partitioner,
            shards,
            accumulation_stats,
        }
    }

    pub fn config(&self) -> &HllConfig {
        &self.config
    }

    pub fn partitioner(&self) -> Partitioner {
        self.partitioner
    }

    pub fn num_ranks(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Total number of vertices holding a sketch.
    pub fn num_vertices(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// Number of sketches that have saturated to dense registers.
    pub fn num_dense_sketches(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.iter())
            .filter(|(_, h)| h.is_dense())
            .count()
    }

    /// The owning rank of a vertex (the paper's `f(x)`).
    #[inline]
    pub fn rank_of(&self, v: VertexId) -> usize {
        self.partitioner.rank_of(v, self.shards.len())
    }

    /// Borrow the sketch of `v`, if it was ever seen in the stream.
    pub fn sketch(&self, v: VertexId) -> Option<&Hll> {
        self.shards[self.rank_of(v)].get(v)
    }

    /// `|D[x]|` — estimated degree of `x` (0 for unseen vertices).
    pub fn degree_estimate(&self, v: VertexId) -> f64 {
        self.degree_estimate_with(v, Estimator::default())
    }

    pub fn degree_estimate_with(&self, v: VertexId, est: Estimator) -> f64 {
        self.sketch(v).map_or(0.0, |s| s.estimate_with(est))
    }

    /// Iterate all (vertex, sketch) pairs across shards.
    pub fn iter(&self) -> impl Iterator<Item = (VertexId, &Hll)> {
        self.shards.iter().flat_map(|s| s.iter())
    }

    /// Approximate heap footprint in bytes — the semi-streaming accounting
    /// reported in EXPERIMENTS.md (compare to `O(ε⁻² n log log n)`).
    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.memory_bytes())
            .sum::<usize>()
            + self.shards.len() * std::mem::size_of::<Shard>()
    }
}

/// Options for accumulation.
#[derive(Debug, Clone, Copy)]
pub struct AccumulateOptions {
    pub backend: Backend,
    pub partitioner: Partitioner,
    /// Comm-plane flush policy (ignored by the sequential backend).
    pub flush: FlushPolicy,
    /// Fault-tolerance policy (socket backends): checkpointed epochs
    /// survive worker death via rollback + respawn. Default: off.
    pub fault: FaultPolicy,
}

impl Default for AccumulateOptions {
    fn default() -> Self {
        Self {
            backend: Backend::Sequential,
            partitioner: Partitioner::RoundRobin,
            flush: FlushPolicy::default(),
            fault: FaultPolicy::default(),
        }
    }
}

struct AccumActor {
    ranks: usize,
    partitioner: Partitioner,
    substream: MemoryStream,
    store: SketchStore,
    /// Pending `(x, y)` messages, applied in grouped batches.
    batch: Vec<(VertexId, VertexId)>,
}

impl Actor for AccumActor {
    /// `(x, y)`: INSERT(D[x], y) at rank f(x).
    type Msg = Edge;

    fn seed(&mut self, out: &mut Outbox<Edge>) {
        seed_edges(&self.substream, self.partitioner, self.ranks, out);
    }

    fn on_message(&mut self, (x, y): Edge, _out: &mut Outbox<Edge>) {
        self.batch.push((x, y));
        if self.batch.len() >= ACCUM_BATCH {
            self.store.insert_batch(&mut self.batch);
        }
    }

    fn on_idle(&mut self, _out: &mut Outbox<Edge>) {
        // quiescence: land the partial batch
        self.store.insert_batch(&mut self.batch);
    }

    fn heat_vertex((x, _): &Edge) -> Option<u64> {
        // destination rank is f(x), so x names the traffic range
        Some(*x)
    }
}

impl WireActor for AccumActor {
    fn write_state(&self, buf: &mut Vec<u8>) {
        // on_idle has always landed the partial batch by Stop time
        debug_assert!(self.batch.is_empty(), "batch flushed at idle");
        codec::encode_store_into(&self.store, buf);
    }

    fn read_state(&mut self, input: &mut &[u8]) -> Result<(), WireError> {
        self.store = codec::decode_store(*self.store.config(), input)?;
        self.batch.clear();
        Ok(())
    }
}

/// seed_state leg: Algorithm 1's epoch inputs are the rank count, the
/// partition `f`, the shared sketch config, and this rank's edge
/// substream σ_P — everything a remote worker needs to run `seed` and
/// accumulate, with no fork copy-on-write involved. The substream is
/// also the checkpointable input: `seed_range` replays edge windows, so
/// resilient epochs can chunk the seed context and resume from a
/// checkpoint frontier.
impl FabricActor for AccumActor {
    const KIND: &'static str = "deg-accum";

    fn write_seed(&self, buf: &mut Vec<u8>) {
        codec::put_u64(buf, self.ranks as u64);
        self.partitioner.encode_into(buf);
        codec::encode_config_into(self.store.config(), buf);
        codec::encode_edges_into(self.substream.edges(), buf);
    }

    fn read_seed(input: &mut &[u8]) -> Result<Self, WireError> {
        let ranks = codec::get_u64(input)? as usize;
        if ranks == 0 {
            return Err(WireError::Invalid("seed with zero ranks".into()));
        }
        let partitioner = Partitioner::decode(input)?;
        let config = codec::decode_config(input)?;
        let edges = codec::decode_edges(input)?;
        Ok(Self {
            ranks,
            partitioner,
            substream: MemoryStream::new(edges),
            store: SketchStore::new(config),
            batch: Vec::new(),
        })
    }

    fn input_len(&self) -> usize {
        self.substream.edges().len()
    }

    fn seed_range(&mut self, start: usize, end: usize, out: &mut Outbox<Edge>) {
        let ranks = self.ranks;
        let part = self.partitioner;
        for &(u, v) in &self.substream.edges()[start..end] {
            if u == v {
                continue;
            }
            out.send(part.rank_of(u, ranks), (u, v));
            out.send(part.rank_of(v, ranks), (v, u));
        }
    }
}

/// Register Algorithm 1's actor kind on a tcp worker dispatch.
pub(crate) fn register_fabric(
    dispatch: crate::comm::tcp::WorkerDispatch,
) -> crate::comm::tcp::WorkerDispatch {
    dispatch.register::<AccumActor>()
}

/// **Algorithm 1**: accumulate a DegreeSketch over `ranks` processors from
/// pre-sharded substreams (one per rank; see [`EdgeStream::shard`]).
pub fn accumulate(
    substreams: Vec<MemoryStream>,
    config: HllConfig,
    opts: AccumulateOptions,
) -> DegreeSketch {
    let ranks = substreams.len();
    assert!(ranks > 0, "need at least one rank");
    let mut actors: Vec<AccumActor> = substreams
        .into_iter()
        .map(|substream| AccumActor {
            ranks,
            partitioner: opts.partitioner,
            substream,
            store: SketchStore::new(config),
            batch: Vec::new(),
        })
        .collect();
    let stats = run_epoch_wire_full(
        opts.backend,
        &mut actors,
        opts.flush,
        &[],
        opts.fault,
    );
    DegreeSketch::from_parts(
        config,
        opts.partitioner,
        actors
            .into_iter()
            .map(|a| {
                debug_assert!(a.batch.is_empty(), "batch flushed at idle");
                Shard::from_store(a.store)
            })
            .collect(),
        stats,
    )
}

/// Convenience: accumulate from a single stream, sharding round-robin.
pub fn accumulate_stream(
    stream: &dyn EdgeStream,
    ranks: usize,
    config: HllConfig,
    opts: AccumulateOptions,
) -> DegreeSketch {
    accumulate(stream.shard(ranks), config, opts)
}

struct ReferenceActor {
    ranks: usize,
    partitioner: Partitioner,
    config: HllConfig,
    substream: MemoryStream,
    shard: HashMap<VertexId, Hll>,
}

impl Actor for ReferenceActor {
    type Msg = Edge;

    fn seed(&mut self, out: &mut Outbox<Edge>) {
        seed_edges(&self.substream, self.partitioner, self.ranks, out);
    }

    fn on_message(&mut self, (x, y): Edge, _out: &mut Outbox<Edge>) {
        self.shard
            .entry(x)
            .or_insert_with(|| Hll::new(self.config))
            .insert(y);
    }

    fn heat_vertex((x, _): &Edge) -> Option<u64> {
        Some(*x)
    }
}

/// The pre-arena reference path: one heap-allocated [`Hll`] per vertex,
/// one binary-search insert per message. Kept as the semantic baseline —
/// parity tests assert [`accumulate`] matches it register-for-register —
/// and as the "before" side of the accumulation microbench. In-memory
/// backends only (it has no wire-state codec).
pub fn accumulate_reference(
    substreams: Vec<MemoryStream>,
    config: HllConfig,
    opts: AccumulateOptions,
) -> DegreeSketch {
    let ranks = substreams.len();
    assert!(ranks > 0, "need at least one rank");
    let mut actors: Vec<ReferenceActor> = substreams
        .into_iter()
        .map(|substream| ReferenceActor {
            ranks,
            partitioner: opts.partitioner,
            config,
            substream,
            shard: HashMap::new(),
        })
        .collect();
    let stats = run_epoch_with(opts.backend, &mut actors, opts.flush);
    DegreeSketch::from_parts(
        config,
        opts.partitioner,
        actors
            .into_iter()
            .map(|a| {
                let mut entries: Vec<(VertexId, Hll)> =
                    a.shard.into_iter().collect();
                entries.sort_unstable_by_key(|&(v, _)| v);
                Shard::from_sorted_entries(entries)
            })
            .collect(),
        stats,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::gen::{karate, GraphSpec};

    fn cfg() -> HllConfig {
        HllConfig::new(10, 0xACC)
    }

    #[test]
    fn accumulation_estimates_degrees() {
        let edges = karate::edges();
        let stream = MemoryStream::new(edges.clone());
        let ds = accumulate_stream(&stream, 4, cfg(), AccumulateOptions::default());
        let csr = Csr::from_edges(&edges);
        assert_eq!(ds.num_vertices(), csr.num_vertices());
        for v in 0..csr.num_vertices() as u32 {
            let truth = csr.degree(v) as f64;
            let est = ds.degree_estimate(csr.original_id(v));
            // p=10 on degree ≤ 17: sparse regime, estimates are near exact
            assert!(
                (est - truth).abs() <= truth * 0.15 + 1.0,
                "v={v} truth={truth} est={est}"
            );
        }
    }

    #[test]
    fn backends_agree_exactly() {
        let edges = karate::edges();
        let stream = MemoryStream::new(edges);
        let seq = accumulate_stream(
            &stream,
            3,
            cfg(),
            AccumulateOptions {
                backend: Backend::Sequential,
                ..Default::default()
            },
        );
        let thr = accumulate_stream(
            &stream,
            3,
            cfg(),
            AccumulateOptions {
                backend: Backend::Threaded,
                ..Default::default()
            },
        );
        let prc = accumulate_stream(
            &stream,
            3,
            cfg(),
            AccumulateOptions {
                backend: Backend::Process,
                ..Default::default()
            },
        );
        // sketches are order-insensitive: shards must match exactly
        for (v, h) in seq.iter() {
            assert_eq!(Some(h), thr.sketch(v), "vertex {v}");
            assert_eq!(Some(h), prc.sketch(v), "process vertex {v}");
        }
        assert_eq!(seq.num_vertices(), thr.num_vertices());
        assert_eq!(seq.num_vertices(), prc.num_vertices());
        assert_eq!(
            seq.accumulation_stats.messages,
            thr.accumulation_stats.messages
        );
        assert_eq!(
            seq.accumulation_stats.messages,
            prc.accumulation_stats.messages
        );
        assert_eq!(prc.accumulation_stats.mode, Backend::Process);
    }

    #[test]
    fn store_path_matches_reference_path() {
        // the arena + batched path must be register-identical (including
        // sparse/dense representation) to the per-sketch reference on
        // both comm backends — karate plus a generated graph whose hub
        // degrees cross the saturation threshold
        for spec in ["karate", "ba:400:5"] {
            let edges = if spec == "karate" {
                karate::edges()
            } else {
                GraphSpec::parse(spec).unwrap().generate(11)
            };
            let stream = MemoryStream::new(edges);
            let c = HllConfig::new(6, 0xBEEF); // r = 64: saturations happen
            for backend in [Backend::Sequential, Backend::Threaded] {
                let opts = AccumulateOptions {
                    backend,
                    ..Default::default()
                };
                let fast = accumulate(stream.shard(8), c, opts);
                let slow = accumulate_reference(stream.shard(8), c, opts);
                assert_eq!(
                    fast.num_vertices(),
                    slow.num_vertices(),
                    "{spec} {backend:?}"
                );
                for (v, h) in slow.iter() {
                    assert_eq!(
                        Some(h),
                        fast.sketch(v),
                        "{spec} {backend:?} vertex {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn duplicate_and_self_edges_are_harmless() {
        let mut edges = karate::edges();
        edges.push((0, 0));
        edges.extend(karate::edges()); // duplicates
        let ds = accumulate_stream(
            &MemoryStream::new(edges),
            2,
            cfg(),
            AccumulateOptions::default(),
        );
        let clean = accumulate_stream(
            &MemoryStream::new(karate::edges()),
            2,
            cfg(),
            AccumulateOptions::default(),
        );
        for (v, h) in clean.iter() {
            assert_eq!(Some(h), ds.sketch(v));
        }
    }

    #[test]
    fn vertices_live_on_their_partition_rank() {
        let ds = accumulate_stream(
            &MemoryStream::new(karate::edges()),
            5,
            cfg(),
            AccumulateOptions::default(),
        );
        for (rank, shard) in ds.shards().iter().enumerate() {
            for (v, _) in shard.iter() {
                assert_eq!(ds.rank_of(v), rank);
            }
        }
    }

    #[test]
    fn message_count_is_two_per_edge() {
        let edges = karate::edges();
        let m = edges.len() as u64;
        let ds = accumulate_stream(
            &MemoryStream::new(edges),
            4,
            cfg(),
            AccumulateOptions::default(),
        );
        assert_eq!(ds.accumulation_stats.messages, 2 * m);
    }

    #[test]
    fn shards_iterate_sorted() {
        let ds = accumulate_stream(
            &MemoryStream::new(karate::edges()),
            3,
            cfg(),
            AccumulateOptions::default(),
        );
        for shard in ds.shards() {
            let ids: Vec<VertexId> = shard.iter().map(|(v, _)| v).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(ids, sorted);
        }
    }
}
