//! The distributed DegreeSketch dictionary `D` and **Algorithm 1**
//! (single-pass accumulation).
//!
//! Each rank owns a shard: a map from vertex id to that vertex's HLL
//! sketch of its adjacency set. Accumulation streams edges: processor `P`
//! reads `uv` from its substream σ_P and sends `(u, v)` to `f(u)` and
//! `(v, u)` to `f(v)`; the owner INSERTs the opposite endpoint into the
//! vertex's sketch. One pass, `O(ε⁻² n log log n)` total space — the
//! semi-streaming property.

use std::collections::HashMap;

use crate::comm::{run_epoch, Actor, Backend, CommStats, Outbox};
use crate::graph::stream::{EdgeStream, MemoryStream};
use crate::graph::{Edge, VertexId};
use crate::hll::{Estimator, Hll, HllConfig};

use super::partition::Partitioner;

/// One rank's shard of the distributed dictionary.
pub type Shard = HashMap<VertexId, Hll>;

/// The accumulated DegreeSketch `D`: a sharded map vertex → HLL.
#[derive(Debug, Clone)]
pub struct DegreeSketch {
    config: HllConfig,
    partitioner: Partitioner,
    shards: Vec<Shard>,
    /// Comm statistics of the accumulation epoch (for the scaling benches).
    pub accumulation_stats: CommStats,
}

impl DegreeSketch {
    pub(crate) fn from_parts(
        config: HllConfig,
        partitioner: Partitioner,
        shards: Vec<Shard>,
        accumulation_stats: CommStats,
    ) -> Self {
        Self {
            config,
            partitioner,
            shards,
            accumulation_stats,
        }
    }

    pub fn config(&self) -> &HllConfig {
        &self.config
    }

    pub fn partitioner(&self) -> Partitioner {
        self.partitioner
    }

    pub fn num_ranks(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Total number of vertices holding a sketch.
    pub fn num_vertices(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// The owning rank of a vertex (the paper's `f(x)`).
    #[inline]
    pub fn rank_of(&self, v: VertexId) -> usize {
        self.partitioner.rank_of(v, self.shards.len())
    }

    /// Borrow the sketch of `v`, if it was ever seen in the stream.
    pub fn sketch(&self, v: VertexId) -> Option<&Hll> {
        self.shards[self.rank_of(v)].get(&v)
    }

    /// `|D[x]|` — estimated degree of `x` (0 for unseen vertices).
    pub fn degree_estimate(&self, v: VertexId) -> f64 {
        self.degree_estimate_with(v, Estimator::default())
    }

    pub fn degree_estimate_with(&self, v: VertexId, est: Estimator) -> f64 {
        self.sketch(v).map_or(0.0, |s| s.estimate_with(est))
    }

    /// Iterate all (vertex, sketch) pairs across shards.
    pub fn iter(&self) -> impl Iterator<Item = (VertexId, &Hll)> {
        self.shards
            .iter()
            .flat_map(|s| s.iter().map(|(&v, h)| (v, h)))
    }

    /// Approximate heap footprint in bytes — the semi-streaming accounting
    /// reported in EXPERIMENTS.md (compare to `O(ε⁻² n log log n)`).
    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| s.values())
            .map(|h| h.memory_bytes())
            .sum::<usize>()
            + self.shards.len() * std::mem::size_of::<Shard>()
    }
}

/// Options for accumulation.
#[derive(Debug, Clone, Copy)]
pub struct AccumulateOptions {
    pub backend: Backend,
    pub partitioner: Partitioner,
}

impl Default for AccumulateOptions {
    fn default() -> Self {
        Self {
            backend: Backend::Sequential,
            partitioner: Partitioner::RoundRobin,
        }
    }
}

struct AccumActor {
    ranks: usize,
    partitioner: Partitioner,
    config: HllConfig,
    substream: MemoryStream,
    shard: Shard,
}

impl Actor for AccumActor {
    /// `(x, y)`: INSERT(D[x], y) at rank f(x).
    type Msg = Edge;

    fn seed(&mut self, out: &mut Outbox<Edge>) {
        let ranks = self.ranks;
        let part = self.partitioner;
        self.substream.for_each(&mut |(u, v)| {
            if u == v {
                return; // simple graphs (paper §5 casts away self-loops)
            }
            out.send(part.rank_of(u, ranks), (u, v));
            out.send(part.rank_of(v, ranks), (v, u));
        });
    }

    fn on_message(&mut self, (x, y): Edge, _out: &mut Outbox<Edge>) {
        self.shard
            .entry(x)
            .or_insert_with(|| Hll::new(self.config))
            .insert(y);
    }
}

/// **Algorithm 1**: accumulate a DegreeSketch over `ranks` processors from
/// pre-sharded substreams (one per rank; see [`EdgeStream::shard`]).
pub fn accumulate(
    substreams: Vec<MemoryStream>,
    config: HllConfig,
    opts: AccumulateOptions,
) -> DegreeSketch {
    let ranks = substreams.len();
    assert!(ranks > 0, "need at least one rank");
    let mut actors: Vec<AccumActor> = substreams
        .into_iter()
        .map(|substream| AccumActor {
            ranks,
            partitioner: opts.partitioner,
            config,
            substream,
            shard: Shard::new(),
        })
        .collect();
    let stats = run_epoch(opts.backend, &mut actors);
    DegreeSketch::from_parts(
        config,
        opts.partitioner,
        actors.into_iter().map(|a| a.shard).collect(),
        stats,
    )
}

/// Convenience: accumulate from a single stream, sharding round-robin.
pub fn accumulate_stream(
    stream: &dyn EdgeStream,
    ranks: usize,
    config: HllConfig,
    opts: AccumulateOptions,
) -> DegreeSketch {
    accumulate(stream.shard(ranks), config, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::gen::karate;

    fn cfg() -> HllConfig {
        HllConfig::new(10, 0xACC)
    }

    #[test]
    fn accumulation_estimates_degrees() {
        let edges = karate::edges();
        let stream = MemoryStream::new(edges.clone());
        let ds = accumulate_stream(&stream, 4, cfg(), AccumulateOptions::default());
        let csr = Csr::from_edges(&edges);
        assert_eq!(ds.num_vertices(), csr.num_vertices());
        for v in 0..csr.num_vertices() as u32 {
            let truth = csr.degree(v) as f64;
            let est = ds.degree_estimate(csr.original_id(v));
            // p=10 on degree ≤ 17: sparse regime, estimates are near exact
            assert!(
                (est - truth).abs() <= truth * 0.15 + 1.0,
                "v={v} truth={truth} est={est}"
            );
        }
    }

    #[test]
    fn backends_agree_exactly() {
        let edges = karate::edges();
        let stream = MemoryStream::new(edges);
        let seq = accumulate_stream(
            &stream,
            3,
            cfg(),
            AccumulateOptions {
                backend: Backend::Sequential,
                ..Default::default()
            },
        );
        let thr = accumulate_stream(
            &stream,
            3,
            cfg(),
            AccumulateOptions {
                backend: Backend::Threaded,
                ..Default::default()
            },
        );
        // sketches are order-insensitive: shards must match exactly
        for (v, h) in seq.iter() {
            assert_eq!(Some(h), thr.sketch(v), "vertex {v}");
        }
        assert_eq!(seq.num_vertices(), thr.num_vertices());
        assert_eq!(
            seq.accumulation_stats.messages,
            thr.accumulation_stats.messages
        );
    }

    #[test]
    fn duplicate_and_self_edges_are_harmless() {
        let mut edges = karate::edges();
        edges.push((0, 0));
        edges.extend(karate::edges()); // duplicates
        let ds = accumulate_stream(
            &MemoryStream::new(edges),
            2,
            cfg(),
            AccumulateOptions::default(),
        );
        let clean = accumulate_stream(
            &MemoryStream::new(karate::edges()),
            2,
            cfg(),
            AccumulateOptions::default(),
        );
        for (v, h) in clean.iter() {
            assert_eq!(Some(h), ds.sketch(v));
        }
    }

    #[test]
    fn vertices_live_on_their_partition_rank() {
        let ds = accumulate_stream(
            &MemoryStream::new(karate::edges()),
            5,
            cfg(),
            AccumulateOptions::default(),
        );
        for (rank, shard) in ds.shards().iter().enumerate() {
            for &v in shard.keys() {
                assert_eq!(ds.rank_of(v), rank);
            }
        }
    }

    #[test]
    fn message_count_is_two_per_edge() {
        let edges = karate::edges();
        let m = edges.len() as u64;
        let ds = accumulate_stream(
            &MemoryStream::new(edges),
            4,
            cfg(),
            AccumulateOptions::default(),
        );
        assert_eq!(ds.accumulation_stats.messages, 2 * m);
    }
}
