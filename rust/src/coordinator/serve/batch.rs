//! The request batcher: a bounded pending queue feeding a worker pool
//! that drains *batches*, not single requests.
//!
//! Coalescing happens inside one drained batch: repeated keys are
//! computed once and fanned out, and every TRI/JACCARD on the same
//! vertex pair shares a single register-scan + MLE solve (the
//! `pair_stats_ref`/`mle_intersect_ref` split from the intersect
//! kernels — one pass over the registers answers both verbs). Each
//! batch pins one `(engine, generation)` pair up front, so its answers
//! are computed wholly against one snapshot generation even if a
//! `RELOAD` lands mid-batch.
//!
//! The queue bound doubles as the admission valve: `try_push` refuses
//! when full and the reactor sheds that request with `ERR overloaded`
//! instead of letting latency collapse under unbounded queueing.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::hll::{Domination, IntersectionEstimate};
use crate::snapshot::GenSwap;
use crate::telemetry::Registry;

use super::super::engine::QueryEngine;
use super::cache::{CacheKey, ResultCache};
use super::QueryKind;

/// One admitted query waiting for a worker. `token`/`conn_id` name the
/// issuing connection (the id guards against slot reuse); `seq` is its
/// response slot, so the reactor can interleave worker completions with
/// inline answers in strict request order.
pub struct Job {
    pub key: CacheKey,
    pub token: usize,
    pub conn_id: u64,
    pub seq: u64,
    pub started: Instant,
    /// Whether this query was picked by the reactor's 1-in-N span
    /// sampler: the worker measures its stages and the reactor emits a
    /// `serve.span` record on delivery.
    pub sampled: bool,
}

/// A computed response line headed back to the reactor, carrying the
/// span measurements the worker took on the way (the reactor adds the
/// final flush stage when it delivers the line).
pub struct Completion {
    pub token: usize,
    pub conn_id: u64,
    pub seq: u64,
    pub line: String,
    pub kind: QueryKind,
    pub sampled: bool,
    /// Index of the worker that computed the answer (its span track).
    pub worker: usize,
    /// Wait between reactor admission and the worker draining the job.
    pub queue_us: u64,
    /// Time inside `answer_key` (0 for answers deduplicated within the
    /// batch — the kernel ran once for the whole group).
    pub kernel_us: u64,
    /// When the query entered the reactor (end-to-end latency anchor).
    pub started: Instant,
    /// When the worker finished computing (flush-stage anchor).
    pub finished: Instant,
}

/// The bounded pending-request queue (reactor pushes, workers drain).
pub struct BatchQueue {
    inner: Mutex<VecDeque<Job>>,
    cv: Condvar,
    cap: usize,
    shutdown: AtomicBool,
}

impl BatchQueue {
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            cap: cap.max(1),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Admit a job, or refuse (`false`) when the queue is at capacity —
    /// the caller sheds the request.
    pub fn try_push(&self, job: Job) -> bool {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.cap {
            return false;
        }
        q.push_back(job);
        drop(q);
        self.cv.notify_one();
        true
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain up to `max` jobs, blocking briefly when empty. An empty
    /// result means "nothing yet — re-check shutdown and call again".
    // RELAXED: the shutdown flag is a monotonic latch with no data
    // dependencies; the queue mutex already orders job handoff, and a
    // raced-past set is caught on the next 100ms wakeup.
    pub fn pop_batch(&self, max: usize) -> Vec<Job> {
        let mut q = self.inner.lock().unwrap();
        while q.is_empty() {
            if self.shutdown.load(Ordering::Relaxed) {
                return Vec::new();
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(q, Duration::from_millis(100))
                .unwrap();
            q = guard;
            if timeout.timed_out() && q.is_empty() {
                return Vec::new();
            }
        }
        let n = q.len().min(max.max(1));
        q.drain(..n).collect()
    }

    // RELAXED: monotonic latch read; see pop_batch.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    // RELAXED: monotonic latch set; notify_all below pairs with the
    // condvar wait in pop_batch, which re-reads the flag under no
    // ordering assumptions.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }
}

/// Completions travelling back to the reactor, plus the wake that pulls
/// it out of `poll` to deliver them.
pub struct Completions {
    out: Mutex<Vec<Completion>>,
    wake: super::poller::WakeTx,
}

impl Completions {
    pub fn new(wake: super::poller::WakeTx) -> Self {
        Self {
            out: Mutex::new(Vec::new()),
            wake,
        }
    }

    pub fn push(&self, mut batch: Vec<Completion>) {
        if batch.is_empty() {
            return;
        }
        self.out.lock().unwrap().append(&mut batch);
        self.wake.wake();
    }

    pub fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.out.lock().unwrap())
    }
}

/// Everything one query worker needs, shared across the pool.
pub struct WorkerShared {
    pub queue: Arc<BatchQueue>,
    pub engine: Arc<GenSwap<QueryEngine>>,
    pub cache: Arc<ResultCache>,
    pub metrics: Arc<Registry>,
    pub completions: Arc<Completions>,
    pub batch_max: usize,
}

/// Record one served query: a request counter and a latency histogram
/// sample (microseconds, measured from reactor parse time — queue wait
/// included, it is real serving latency), both labeled with the query
/// kind so `METRICS` exposes p50/p90/p99 per verb.
pub fn record_query(metrics: &Registry, kind: &str, started: Instant) {
    metrics
        .counter("degreesketch_queries_total", &[("kind", kind)])
        .inc();
    metrics
        .histogram("degreesketch_query_latency_us", &[("kind", kind)])
        .observe(started.elapsed().as_micros() as u64);
}

/// Format the answer for one query key — the single source of truth for
/// response formatting, shared (via the cache) by every serving path,
/// which is what makes batched/cached answers bit-identical to direct
/// engine calls. `pairs` memoizes intersection estimates within a
/// batch: TRI and JACCARD on the same `(x, y)` share one MLE solve.
fn answer_key(
    engine: &QueryEngine,
    key: &CacheKey,
    pairs: &mut HashMap<(u64, u64), Option<IntersectionEstimate>>,
) -> String {
    match key.kind {
        QueryKind::Deg => engine
            .degree(key.ids[0])
            .map(|d| format!("{d:.3}"))
            .unwrap_or_else(|| "NONE".into()),
        QueryKind::Tri | QueryKind::Jaccard => {
            let (x, y) = (key.ids[0], key.ids[1]);
            let est = pairs
                .entry((x, y))
                .or_insert_with(|| engine.intersection(x, y));
            match (key.kind, est.as_ref()) {
                (QueryKind::Tri, Some(est)) => format!(
                    "{:.3} {:.3} {}",
                    est.intersection,
                    est.union,
                    u8::from(est.domination != Domination::None)
                ),
                (QueryKind::Jaccard, Some(est)) => {
                    format!("{:.6}", est.jaccard())
                }
                _ => "NONE".into(),
            }
        }
        QueryKind::Union => engine
            .union_cardinality(&key.ids)
            .map(|u| format!("{u:.3}"))
            .unwrap_or_else(|| "NONE".into()),
    }
}

/// One worker's life: drain a batch, pin the engine generation, answer
/// every job (coalescing duplicates and shared pairs), feed the cache,
/// and hand the completions back to the reactor. `worker` is this
/// worker's pool index — span records carry it so each worker gets its
/// own track in the Chrome export.
pub fn run_worker(sh: &WorkerShared, worker: usize) {
    loop {
        let batch = sh.queue.pop_batch(sh.batch_max);
        if batch.is_empty() {
            if sh.queue.is_shutdown() {
                return;
            }
            continue;
        }
        let drained = Instant::now();
        let (engine, gen) = sh.engine.load();
        sh.metrics
            .histogram("degreesketch_query_batch_size", &[])
            .observe(batch.len() as u64);
        sh.metrics
            .gauge("degreesketch_query_batch_max", &[])
            .raise(batch.len() as u64);
        let mut answers: HashMap<CacheKey, String> = HashMap::new();
        let mut pairs: HashMap<(u64, u64), Option<IntersectionEstimate>> =
            HashMap::new();
        let mut out = Vec::with_capacity(batch.len());
        for job in batch {
            let (line, kernel_us) = match answers.get(&job.key) {
                // deduplicated within the batch: the kernel already ran
                Some(l) => (l.clone(), 0),
                None => {
                    let k0 = Instant::now();
                    let l = answer_key(&engine, &job.key, &mut pairs);
                    let kernel_us = k0.elapsed().as_micros() as u64;
                    sh.cache.insert(job.key.clone(), gen, l.clone());
                    answers.insert(job.key.clone(), l.clone());
                    (l, kernel_us)
                }
            };
            record_query(&sh.metrics, job.key.kind.name(), job.started);
            out.push(Completion {
                token: job.token,
                conn_id: job.conn_id,
                seq: job.seq,
                line,
                kind: job.key.kind,
                sampled: job.sampled,
                worker,
                queue_us: drained
                    .saturating_duration_since(job.started)
                    .as_micros() as u64,
                kernel_us,
                started: job.started,
                finished: Instant::now(),
            });
        }
        sh.completions.push(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(n: u64) -> Job {
        Job {
            key: CacheKey {
                kind: QueryKind::Deg,
                ids: vec![n],
            },
            token: n as usize,
            conn_id: n,
            seq: 0,
            started: Instant::now(),
            sampled: false,
        }
    }

    #[test]
    fn queue_bound_refuses_when_full() {
        let q = BatchQueue::new(2);
        assert!(q.try_push(job(0)));
        assert!(q.try_push(job(1)));
        assert!(!q.try_push(job(2)), "cap=2 must shed the third");
        let drained = q.pop_batch(10);
        assert_eq!(drained.len(), 2);
        assert!(q.try_push(job(3)));
    }

    #[test]
    fn pop_batch_caps_at_max() {
        let q = BatchQueue::new(100);
        for i in 0..10 {
            assert!(q.try_push(job(i)));
        }
        assert_eq!(q.pop_batch(4).len(), 4);
        assert_eq!(q.pop_batch(100).len(), 6);
    }

    #[test]
    fn shutdown_unblocks_pop() {
        let q = Arc::new(BatchQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || loop {
            if q2.pop_batch(8).is_empty() && q2.is_shutdown() {
                return;
            }
        });
        std::thread::sleep(Duration::from_millis(20));
        q.shutdown();
        h.join().unwrap();
    }
}
