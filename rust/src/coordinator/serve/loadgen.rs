//! `degreesketch loadgen` — a poll-driven load generator for the
//! serving tier.
//!
//! The same trick that lets the reactor serve 10k sockets from one
//! thread lets a *client* drive 10k sockets from a handful: each worker
//! thread owns `connections / threads` nonblocking [`Conn`]s in one
//! poll set, keeps exactly one request in flight per connection, and
//! times every response. Latencies land in a shared telemetry
//! histogram, so the reported p50/p90/p99 come from the same
//! log2-bucket + ring-sampled quantile machinery the server exposes —
//! one definition of "p99" on both ends of the wire.
//!
//! The request mix is deliberately cache-shaped: a configurable
//! fraction of requests targets a small hot set of vertices (default
//! 90% → 128 vertices), the rest spray uniformly, so the run measures
//! the serving tier as deployed — batcher coalescing plus hot-vertex
//! cache — not just the raw kernel path. With `--live-reload` the
//! driver issues a `RELOAD` at the halfway mark and requires it to
//! succeed: the QPS and tail-latency numbers then *include* a snapshot
//! generation swap, which is the zero-downtime claim stated as a
//! benchmark.
//!
//! Ends with a `STATS` probe for the server-side cache hit/miss and
//! shed counters and writes the whole summary as JSON (`--out
//! BENCH_serving.json`): connections, requests, error count, wall
//! time, QPS, latency quantiles (µs), cache hit rate, generation
//! before/after. Any protocol error, eviction, or failed reload makes
//! the run fail — the CI e2e gate runs this binary directly.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::socket::Conn;
use crate::hash::Xoshiro256ss;
use crate::telemetry::Registry;

use super::poller::{self, fd_of, PollSlot};

/// Knobs for one load-generation run (CLI flags map 1:1).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Server address, e.g. `127.0.0.1:7514`.
    pub addr: String,
    /// Concurrent connections across the whole fleet.
    pub connections: usize,
    /// Total requests across the fleet (split evenly per connection).
    pub requests: u64,
    /// Driver threads; 0 = auto (one per ~2048 connections, ≥2, ≤8).
    pub threads: usize,
    /// Hot-set size: this many distinct vertices absorb `hot_fraction`
    /// of the traffic.
    pub hot_vertices: usize,
    /// Share of requests aimed at the hot set (0.0–1.0).
    pub hot_fraction: f64,
    pub seed: u64,
    /// Issue a `RELOAD` at the halfway mark and require `OK`.
    pub live_reload: bool,
    /// Write the JSON summary here.
    pub out: Option<PathBuf>,
    /// Fail the run if p99 exceeds this bound (the CI latency gate).
    pub max_p99_ms: Option<f64>,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7514".into(),
            connections: 64,
            requests: 10_000,
            threads: 0,
            hot_vertices: 128,
            hot_fraction: 0.9,
            seed: 0x10AD,
            live_reload: false,
            out: None,
            max_p99_ms: None,
        }
    }
}

impl LoadgenOptions {
    fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        self.connections.div_ceil(2048).clamp(2, 8)
    }
}

/// What one run measured (everything that lands in the JSON summary).
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub connections: usize,
    pub requests_sent: u64,
    pub responses_ok: u64,
    pub errors: u64,
    pub elapsed: Duration,
    pub qps: f64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub shed: u64,
    pub generation_start: u64,
    pub generation_end: u64,
    pub reloaded: bool,
}

impl LoadgenReport {
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// The summary as a JSON object (hand-rendered; every field is a
    /// number or bool, so no escaping is needed).
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"connections\": {},\n  \"requests_sent\": {},\n  \
             \"responses_ok\": {},\n  \"errors\": {},\n  \
             \"elapsed_secs\": {:.3},\n  \"qps\": {:.1},\n  \
             \"p50_us\": {},\n  \"p90_us\": {},\n  \"p99_us\": {},\n  \
             \"cache_hits\": {},\n  \"cache_misses\": {},\n  \
             \"cache_hit_rate\": {:.4},\n  \"shed\": {},\n  \
             \"generation_start\": {},\n  \"generation_end\": {},\n  \
             \"reloaded\": {}\n}}\n",
            self.connections,
            self.requests_sent,
            self.responses_ok,
            self.errors,
            self.elapsed.as_secs_f64(),
            self.qps,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate(),
            self.shed,
            self.generation_start,
            self.generation_end,
            self.reloaded
        )
    }
}

/// One blocking control-channel exchange: send `line`, read one line.
fn control_ask(addr: &str, line: &str) -> Result<String> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("loadgen: connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    writeln!(w, "{line}")?;
    let mut resp = String::new();
    r.read_line(&mut resp)?;
    writeln!(w, "QUIT").ok();
    Ok(resp.trim().to_string())
}

fn stats_field(stats: &str, name: &str) -> Option<u64> {
    stats
        .split_whitespace()
        .find_map(|t| t.strip_prefix(name)?.strip_prefix('=')?.parse().ok())
}

/// One in-flight client connection owned by a driver thread.
struct LgConn {
    conn: Conn<TcpStream>,
    fd: i32,
    inflight: Option<Instant>,
    remaining: u64,
    rng: Xoshiro256ss,
}

impl LgConn {
    /// Compose the next request line from the traffic mix.
    fn next_request(&mut self, vertices: u64, hot: u64, hot_frac: f64) -> String {
        let pick = |rng: &mut Xoshiro256ss| -> u64 {
            if rng.next_f64() < hot_frac {
                rng.next_below(hot.max(1))
            } else {
                rng.next_below(vertices.max(1))
            }
        };
        let roll = self.rng.next_f64();
        if roll < 0.5 {
            let x = pick(&mut self.rng);
            format!("DEG {x}\n")
        } else if roll < 0.7 {
            let x = pick(&mut self.rng);
            let y = pick(&mut self.rng);
            format!("TRI {x} {y}\n")
        } else if roll < 0.85 {
            let x = pick(&mut self.rng);
            let y = pick(&mut self.rng);
            format!("JACCARD {x} {y}\n")
        } else {
            let x = pick(&mut self.rng);
            let y = pick(&mut self.rng);
            format!("UNION {x} {y}\n")
        }
    }
}

struct DriverShared {
    lat: crate::telemetry::HistHandle,
    sent: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    halfway: AtomicBool,
}

/// One driver thread: `conns` connections, one request in flight each.
// RELAXED: sent/ok/errors are throughput tallies summed after join();
// the thread join provides the happens-before edge the final report
// needs, so per-increment ordering buys nothing.
fn drive(
    addr: &str,
    conns: usize,
    per_conn: u64,
    seed: u64,
    vertices: u64,
    hot: u64,
    hot_frac: f64,
    sh: &DriverShared,
) {
    let mut clients: Vec<LgConn> = Vec::with_capacity(conns);
    for i in 0..conns {
        let Ok(stream) = TcpStream::connect(addr) else {
            sh.errors.fetch_add(per_conn, Ordering::Relaxed);
            continue;
        };
        stream.set_nodelay(true).ok();
        let fd = fd_of(&stream);
        match Conn::new(stream) {
            Ok(conn) => clients.push(LgConn {
                conn,
                fd,
                inflight: None,
                remaining: per_conn,
                rng: Xoshiro256ss::new(seed ^ (i as u64) << 17),
            }),
            Err(_) => {
                sh.errors.fetch_add(per_conn, Ordering::Relaxed);
            }
        }
    }
    let mut slots: Vec<PollSlot> = Vec::with_capacity(clients.len());
    loop {
        let mut live = 0;
        slots.clear();
        for c in &clients {
            let done = c.remaining == 0 && c.inflight.is_none();
            if !done {
                live += 1;
            }
            slots.push(if done {
                PollSlot::new(-1, false, false)
            } else {
                PollSlot::new(
                    c.fd,
                    c.inflight.is_some(),
                    c.conn.has_queued_writes(),
                )
            });
        }
        if live == 0 {
            break;
        }
        poller::poll(&mut slots, Duration::from_millis(50));
        for (c, flags) in clients.iter_mut().zip(&slots) {
            if flags.fd < 0 {
                continue;
            }
            let mut dead = false;
            if flags.readable || flags.broken {
                match c.conn.fill("loadgen") {
                    Ok(out) => {
                        while let Some(line) = c.conn.take_line() {
                            if let Some(t0) = c.inflight.take() {
                                let us = t0.elapsed().as_micros() as u64;
                                if line.starts_with(b"ERR") {
                                    sh.errors.fetch_add(1, Ordering::Relaxed);
                                } else {
                                    sh.lat.observe(us);
                                    sh.ok.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        c.conn.compact();
                        if out.eof {
                            dead = true;
                        }
                    }
                    Err(_) => dead = true,
                }
            }
            if !dead && c.inflight.is_none() && c.remaining > 0 {
                let req = c.next_request(vertices, hot, hot_frac);
                c.conn.queue_frame(req.into_bytes());
                c.inflight = Some(Instant::now());
                c.remaining -= 1;
                sh.sent.fetch_add(1, Ordering::Relaxed);
            }
            if !dead
                && c.conn.has_queued_writes()
                && c.conn.pump_write("loadgen").is_err()
            {
                dead = true;
            }
            if dead {
                // a dropped connection forfeits its remaining quota —
                // counted as errors so the run cannot pass silently
                let lost =
                    c.remaining + u64::from(c.inflight.take().is_some());
                sh.errors.fetch_add(lost, Ordering::Relaxed);
                c.remaining = 0;
            }
        }
    }
}

/// Run the fleet against a live server and gather the report.
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    // probe the server: vertex count for the traffic mix, generation
    // and counter baselines for the report
    let stats0 = control_ask(&opts.addr, "STATS")?;
    let vertices = stats_field(&stats0, "vertices")
        .ok_or_else(|| anyhow!("bad STATS from {}: {stats0:?}", opts.addr))?;
    let gen0 = stats_field(&stats0, "generation").unwrap_or(0);
    let hits0 = stats_field(&stats0, "cache_hits").unwrap_or(0);
    let misses0 = stats_field(&stats0, "cache_misses").unwrap_or(0);
    if vertices == 0 {
        bail!("server at {} reports an empty engine", opts.addr);
    }

    let threads = opts.resolved_threads().min(opts.connections.max(1));
    let per_thread = opts.connections.div_ceil(threads);
    let per_conn = (opts.requests / opts.connections.max(1) as u64).max(1);
    let registry = Registry::new();
    let shared = Arc::new(DriverShared {
        lat: registry.histogram("loadgen_latency_us", &[]),
        sent: AtomicU64::new(0),
        ok: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        halfway: AtomicBool::new(false),
    });
    let total_planned = per_conn * opts.connections as u64;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    let mut assigned = 0usize;
    for t in 0..threads {
        let n = per_thread.min(opts.connections - assigned);
        assigned += n;
        if n == 0 {
            break;
        }
        let addr = opts.addr.clone();
        let sh = Arc::clone(&shared);
        let seed = opts
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(t as u64 + 1));
        let hot = opts.hot_vertices.max(1) as u64;
        let hot_frac = opts.hot_fraction.clamp(0.0, 1.0);
        handles.push(std::thread::spawn(move || {
            drive(&addr, n, per_conn, seed, vertices, hot, hot_frac, &sh)
        }));
    }

    // the main thread is the controller: watch progress, fire the
    // mid-run RELOAD once half the responses are in.
    // RELAXED: the halfway latch and progress reads are heuristics — an
    // off-by-a-few trigger point is harmless, and the final report reads
    // happen after join(), which already orders them.
    let mut reloaded = false;
    while handles.iter().any(|h| !h.is_finished()) {
        if opts.live_reload
            && !shared.halfway.load(Ordering::Relaxed)
            && shared.ok.load(Ordering::Relaxed)
                + shared.errors.load(Ordering::Relaxed)
                >= total_planned / 2
        {
            shared.halfway.store(true, Ordering::Relaxed);
            let resp = control_ask(&opts.addr, "RELOAD")?;
            if !resp.starts_with("OK") {
                bail!("mid-run RELOAD failed: {resp:?}");
            }
            reloaded = true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("loadgen driver panicked"))?;
    }
    let elapsed = t0.elapsed();
    if opts.live_reload && !reloaded {
        // the fleet finished before the halfway check fired — reload
        // anyway so the verb is still exercised end-to-end
        let resp = control_ask(&opts.addr, "RELOAD")?;
        if !resp.starts_with("OK") {
            bail!("post-run RELOAD failed: {resp:?}");
        }
        reloaded = true;
    }

    let stats1 = control_ask(&opts.addr, "STATS")?;
    let report = LoadgenReport {
        connections: opts.connections,
        requests_sent: shared.sent.load(Ordering::Relaxed),
        responses_ok: shared.ok.load(Ordering::Relaxed),
        errors: shared.errors.load(Ordering::Relaxed),
        elapsed,
        qps: shared.ok.load(Ordering::Relaxed) as f64
            / elapsed.as_secs_f64().max(1e-9),
        p50_us: shared.lat.quantile(0.5).unwrap_or(0),
        p90_us: shared.lat.quantile(0.9).unwrap_or(0),
        p99_us: shared.lat.quantile(0.99).unwrap_or(0),
        cache_hits: stats_field(&stats1, "cache_hits")
            .unwrap_or(0)
            .saturating_sub(hits0),
        cache_misses: stats_field(&stats1, "cache_misses")
            .unwrap_or(0)
            .saturating_sub(misses0),
        shed: stats_field(&stats1, "shed").unwrap_or(0),
        generation_start: gen0,
        generation_end: stats_field(&stats1, "generation").unwrap_or(gen0),
        reloaded,
    };

    if let Some(out) = &opts.out {
        std::fs::write(out, report.to_json())
            .with_context(|| format!("loadgen: write {}", out.display()))?;
    }
    if let Some(bound_ms) = opts.max_p99_ms {
        let p99_ms = report.p99_us as f64 / 1000.0;
        if p99_ms > bound_ms {
            bail!("p99 {p99_ms:.2}ms exceeds bound {bound_ms:.2}ms");
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_field_parses_server_stats_lines() {
        let line = "vertices=34 ranks=2 p=12 mem=100 generation=3 \
                    cache_hits=17 cache_misses=4 shed=0 comm=none";
        assert_eq!(stats_field(line, "vertices"), Some(34));
        assert_eq!(stats_field(line, "generation"), Some(3));
        assert_eq!(stats_field(line, "cache_hits"), Some(17));
        assert_eq!(stats_field(line, "absent"), None);
    }

    #[test]
    fn report_json_is_well_formed() {
        let r = LoadgenReport {
            connections: 8,
            requests_sent: 100,
            responses_ok: 99,
            errors: 1,
            elapsed: Duration::from_millis(1500),
            qps: 66.0,
            p50_us: 120,
            p90_us: 340,
            p99_us: 900,
            cache_hits: 60,
            cache_misses: 40,
            shed: 0,
            generation_start: 0,
            generation_end: 1,
            reloaded: true,
        };
        let j = r.to_json();
        assert!(j.contains("\"p99_us\": 900"), "{j}");
        assert!(j.contains("\"cache_hit_rate\": 0.6000"), "{j}");
        assert!(j.contains("\"reloaded\": true"), "{j}");
        // balanced braces and quotes, parseable by eye and by jq
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('"').count() % 2, 0);
    }

    #[test]
    fn loadgen_end_to_end_against_live_server() {
        use crate::coordinator::serve::{QueryServer, ServeOptions};
        use crate::coordinator::sketch::{
            accumulate_stream, AccumulateOptions,
        };
        use crate::coordinator::QueryEngine;
        use crate::graph::gen::karate;
        use crate::graph::stream::MemoryStream;
        use crate::hll::HllConfig;

        let stream = MemoryStream::new(karate::edges());
        let ds = accumulate_stream(
            &stream,
            2,
            HllConfig::new(12, 0x5E),
            AccumulateOptions::default(),
        );
        let engine = Arc::new(QueryEngine::new(ds));
        let server = QueryServer::start_with_opts(
            engine,
            "127.0.0.1:0",
            ServeOptions::default(),
        )
        .unwrap();
        let report = run(&LoadgenOptions {
            addr: server.addr().to_string(),
            connections: 16,
            requests: 800,
            threads: 2,
            hot_vertices: 8,
            hot_fraction: 0.9,
            ..LoadgenOptions::default()
        })
        .unwrap();
        assert_eq!(report.errors, 0, "{report:?}");
        assert_eq!(report.responses_ok, 800, "{report:?}");
        // 90% of traffic on 8 hot vertices must produce cache hits
        assert!(report.cache_hits > 0, "{report:?}");
        assert!(report.p99_us > 0, "{report:?}");
        server.stop();
    }
}
