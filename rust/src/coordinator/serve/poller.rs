//! Readiness notification for the serving reactor: a raw `poll(2)`
//! binding on unix (bound directly against the platform libc, like the
//! snapshot module's `mmap` binding — the `libc` crate is unavailable
//! offline), and a bounded sleep-tick fallback elsewhere so the reactor
//! stays portable: on the fallback every socket is reported ready and
//! the nonblocking reads/writes themselves sort out who actually has
//! data (`WouldBlock` is harmless), at a fixed small tick cost.
//!
//! Also home to the self-wake pipe: worker threads finish batches while
//! the reactor may be parked in `poll`, so completions write one byte
//! into a socketpair whose read end sits in the poll set.

use std::time::Duration;

/// One pollable slot: the fd plus the interest flags for this round.
/// `fd < 0` marks an empty slot that is never reported ready.
#[derive(Debug, Clone, Copy, Default)]
pub struct PollSlot {
    pub fd: i32,
    pub want_read: bool,
    pub want_write: bool,
    pub readable: bool,
    pub writable: bool,
    /// Error/hangup/invalid — the owner should try IO and let the
    /// resulting error close the connection.
    pub broken: bool,
}

impl PollSlot {
    pub fn new(fd: i32, want_read: bool, want_write: bool) -> Self {
        Self {
            fd,
            want_read,
            want_write,
            readable: false,
            writable: false,
            broken: false,
        }
    }
}

/// The raw fd of any `AsRawFd` stream (−1 on platforms without fds,
/// where the fallback poller reports everything ready anyway).
#[cfg(unix)]
pub fn fd_of<T: std::os::unix::io::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}

#[cfg(not(unix))]
pub fn fd_of<T>(_s: &T) -> i32 {
    -1
}

#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = std::os::raw::c_uint;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    // flag values shared by Linux and the BSD/darwin family
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;
}

/// Wait up to `timeout` for readiness on `slots`, filling in the
/// outcome flags. Returns how many slots are ready (0 on timeout).
#[cfg(unix)]
pub fn poll(slots: &mut [PollSlot], timeout: Duration) -> usize {
    let mut fds: Vec<sys::PollFd> = slots
        .iter()
        .map(|s| sys::PollFd {
            fd: if s.fd >= 0 && (s.want_read || s.want_write) {
                s.fd
            } else {
                // poll(2) ignores negative fds — exactly what an empty
                // or interest-free slot wants
                -1
            },
            events: (if s.want_read { sys::POLLIN } else { 0 })
                | (if s.want_write { sys::POLLOUT } else { 0 }),
            revents: 0,
        })
        .collect();
    let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
    // SAFETY: `fds` is a live Vec for the duration of the call, the
    // length matches the pointer's allocation, and poll(2) only writes
    // within `fds[..len]` (the `revents` fields).
    let rc = unsafe {
        sys::poll(fds.as_mut_ptr(), fds.len() as sys::NfdsT, ms.max(1))
    };
    if rc <= 0 {
        // timeout, or EINTR/transient error: report nothing ready; the
        // reactor's next round retries
        for s in slots.iter_mut() {
            (s.readable, s.writable, s.broken) = (false, false, false);
        }
        return 0;
    }
    let mut ready = 0;
    for (s, f) in slots.iter_mut().zip(&fds) {
        s.readable = f.revents & sys::POLLIN != 0;
        s.writable = f.revents & sys::POLLOUT != 0;
        s.broken =
            f.revents & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0;
        if s.readable || s.writable || s.broken {
            ready += 1;
        }
    }
    ready
}

/// Portable fallback: sleep a bounded tick and report every interested
/// slot ready — the nonblocking IO that follows is the real filter.
#[cfg(not(unix))]
pub fn poll(slots: &mut [PollSlot], timeout: Duration) -> usize {
    std::thread::sleep(timeout.min(Duration::from_millis(2)));
    let mut ready = 0;
    for s in slots.iter_mut() {
        s.readable = s.want_read;
        s.writable = s.want_write;
        s.broken = false;
        if s.readable || s.writable {
            ready += 1;
        }
    }
    ready
}

// ---------------------------------------------------------------------
// Self-wake pipe
// ---------------------------------------------------------------------

/// Write end of the reactor's wake pipe — cloneable, shared by the
/// worker pool's completion queue and the server's stop path.
#[derive(Clone)]
pub struct WakeTx {
    #[cfg(unix)]
    tx: std::sync::Arc<std::os::unix::net::UnixStream>,
}

impl WakeTx {
    /// Nudge the reactor: one byte into the pipe. A full pipe means the
    /// reactor is hopelessly behind on wakes already — dropping the
    /// byte is fine, it will drain the pipe and the completion queue on
    /// the same round.
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            use std::io::Write;
            let _ = (&*self.tx).write(&[1u8]);
        }
    }
}

/// Read end of the wake pipe: polled by the reactor, drained each round.
pub struct WakeRx {
    #[cfg(unix)]
    rx: std::os::unix::net::UnixStream,
}

impl WakeRx {
    pub fn fd(&self) -> i32 {
        #[cfg(unix)]
        {
            fd_of(&self.rx)
        }
        #[cfg(not(unix))]
        {
            -1
        }
    }

    /// Swallow every pending wake byte.
    pub fn drain(&self) {
        #[cfg(unix)]
        {
            use std::io::Read;
            let mut buf = [0u8; 256];
            while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
        }
    }
}

/// A connected wake pair (`UnixStream::pair` on unix — pure std, both
/// ends nonblocking; inert elsewhere, where the fallback poller's sleep
/// tick bounds wake latency instead).
pub fn wake_pair() -> std::io::Result<(WakeTx, WakeRx)> {
    #[cfg(unix)]
    {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((
            WakeTx {
                tx: std::sync::Arc::new(tx),
            },
            WakeRx { rx },
        ))
    }
    #[cfg(not(unix))]
    {
        Ok((WakeTx {}, WakeRx {}))
    }
}

#[cfg(test)]
// Miri cannot emulate the raw poll/mmap/fork/socket syscalls these
// tests drive; the Miri CI job scopes to the pure-core suites instead.
#[cfg(not(miri))]
mod tests {
    use super::*;

    #[cfg(unix)]
    #[test]
    fn poll_reports_wake_pipe_readability() {
        let (tx, rx) = wake_pair().unwrap();
        let mut slots = [PollSlot::new(rx.fd(), true, false)];
        // nothing written yet: a short poll times out
        assert_eq!(poll(&mut slots, Duration::from_millis(5)), 0);
        assert!(!slots[0].readable);
        tx.wake();
        assert_eq!(poll(&mut slots, Duration::from_millis(1000)), 1);
        assert!(slots[0].readable);
        rx.drain();
        // drained: back to timing out
        assert_eq!(poll(&mut slots, Duration::from_millis(5)), 0);
    }

    #[cfg(unix)]
    #[test]
    fn negative_fd_slots_are_ignored() {
        let (tx, rx) = wake_pair().unwrap();
        tx.wake();
        let mut slots = [
            PollSlot::new(-1, true, true),
            PollSlot::new(rx.fd(), true, false),
        ];
        assert_eq!(poll(&mut slots, Duration::from_millis(1000)), 1);
        assert!(!slots[0].readable && !slots[0].broken);
        assert!(slots[1].readable);
    }
}
