//! The query-serving tier: an event-driven connection layer over the
//! persistent [`QueryEngine`](super::engine::QueryEngine).
//!
//! The PR-2 snapshot made the engine cheap to *open* (O(1) mmap); this
//! module makes it cheap to *serve* — the paper's "persistent query
//! engine" treated as a high-QPS estimation service rather than a batch
//! artifact. Like the comm plane, it is built as explicit layers:
//!
//! * **Readiness** ([`poller`]) — a `poll(2)` binding in the style of
//!   the snapshot module's raw `mmap` binding (the `libc` crate is
//!   unavailable offline), with a portable sleep-tick fallback, plus the
//!   self-wake pipe the worker pool uses to interrupt a sleeping
//!   reactor.
//! * **Reactor** ([`reactor`]) — ONE thread owns the listener and every
//!   client socket, each wrapped in the same buffered nonblocking
//!   [`Conn`](crate::comm::socket::Conn) machinery the fabric uses for
//!   DSKF frames (only the framing differs: newline vs length header).
//!   It accepts, parses request lines, answers protocol/cached requests
//!   inline, hands query work to the batcher, and writes completions
//!   back — in strict per-connection request order via response slots,
//!   so pipelined clients never see reordered answers. Idle-connection
//!   eviction (the PR-6 `ConnLimits` contract) rides the poll deadline:
//!   a client silent past `idle_cap` is answered
//!   `ERR idle timeout, closing` and disconnected, counted in `STATS`
//!   as `evicted=<n>`.
//! * **Batcher** ([`batch`]) — a bounded pending-request queue feeding a
//!   small worker pool. Each worker drains up to `batch_max` requests in
//!   one pass and coalesces them: repeated keys are answered once, and
//!   every TRI/JACCARD on the same vertex pair shares a single
//!   `pair_stats_ref` + MLE solve — concurrent load turns into batched
//!   calls over the intersect kernels instead of per-request lock
//!   traffic. The queue bound is the admission valve: when it is full
//!   the reactor sheds with `ERR overloaded` instead of queueing
//!   unboundedly.
//! * **Cache** ([`cache`]) — a sharded, bounded, generation-tagged
//!   result cache for hot vertices. Entries store the *formatted
//!   response line*, so a hit is bit-identical to a recomputation by
//!   construction. Tags make snapshot swaps free: entries recorded
//!   under generation N silently stop matching when the engine slot
//!   says N+1 — no sweep, no lock storm.
//! * **Swap** — the engine lives in a
//!   [`GenSwap`](crate::snapshot::GenSwap): workers pin one `(engine,
//!   generation)` pair per batch, so every answer is computed wholly
//!   against one generation (never a blend), while the `RELOAD` verb
//!   opens the snapshot path fresh (typically after a writer renamed
//!   the next generation over it) and swaps it in with zero dropped
//!   connections — the old mmap stays valid until its last batch
//!   finishes.
//! * **Load generator** ([`loadgen`]) — `degreesketch loadgen`: a
//!   poll-driven client fleet (10k+ connections on a handful of
//!   threads) reporting p50/p90/p99 latency, QPS, and the server's
//!   cache hit rate into `BENCH_serving.json`.
//!
//! * **Spans** — every Nth query ([`ServeOptions::span_sample`]) is
//!   traced end to end: the reactor stamps admission, the worker
//!   measures queue wait and kernel time, and completion delivery
//!   measures write flush. Sampled spans become `serve.span` events in
//!   the trace dir (one Chrome-export track per worker) and JSONL
//!   access-log records; queries slower than
//!   [`ServeOptions::slow_query_us`] are logged **regardless** of
//!   sampling, so outliers always leave a record.
//!
//! Every stage records into the PR-7 telemetry plane and is visible in
//! one `METRICS` scrape: per-kind query counters and latency quantiles,
//! per-stage span histograms (`degreesketch_query_stage_us`), the
//! batch-size histogram (`degreesketch_query_batch_size`), per-kind
//! cache hit/miss counters, shed counts, and the serving generation.

pub mod batch;
pub mod cache;
pub mod loadgen;
pub mod poller;
pub mod reactor;

pub use reactor::QueryServer;

use std::time::Duration;

/// The query verbs that flow through the batcher and cache (the other
/// verbs — STATS/METRICS/RELOAD/QUIT — are answered inline by the
/// reactor).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryKind {
    Deg,
    Tri,
    Jaccard,
    Union,
}

impl QueryKind {
    pub fn name(self) -> &'static str {
        match self {
            Self::Deg => "deg",
            Self::Tri => "tri",
            Self::Jaccard => "jaccard",
            Self::Union => "union",
        }
    }

    /// Stable numeric code for trace-event fields (`serve.span`'s
    /// `kind` field; events carry u64s, not strings).
    pub fn index(self) -> u64 {
        match self {
            Self::Deg => 0,
            Self::Tri => 1,
            Self::Jaccard => 2,
            Self::Union => 3,
        }
    }
}

/// Per-connection read bounds: `read_timeout` caps the reactor's poll
/// wait (the eviction scan granularity); a client silent for longer
/// than `idle_cap` is evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnLimits {
    pub read_timeout: Duration,
    pub idle_cap: Duration,
}

impl Default for ConnLimits {
    fn default() -> Self {
        Self {
            read_timeout: Duration::from_millis(250),
            idle_cap: Duration::from_secs(300),
        }
    }
}

/// Serving-tier knobs (config section `serve.*`, overridable per flag).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Query worker threads; 0 = auto (min(cores, 4)).
    pub workers: usize,
    /// Most requests one worker drains into a single batch.
    pub batch_max: usize,
    /// Result-cache capacity in entries (0 disables caching).
    pub cache_capacity: usize,
    /// Pending-request queue bound — beyond it the reactor sheds with
    /// `ERR overloaded`.
    pub pending_cap: usize,
    /// Query-span sampling: every Nth query gets a full per-stage span
    /// (`serve.span` trace event + access-log record). 0 disables
    /// sampling; 1 spans every query. Sampling bounds the per-request
    /// overhead — unsampled queries still feed the per-stage histograms
    /// and per-kind counters, they just produce no per-request record.
    pub span_sample: u64,
    /// Slow-query threshold in microseconds: a query whose end-to-end
    /// latency reaches this is **always** written to the access log,
    /// whether or not it was sampled — tail outliers survive any
    /// sampling rate. 0 disables the threshold.
    pub slow_query_us: u64,
    /// JSONL access log path (sampled queries + every slow query).
    /// `None` disables the log.
    pub access_log: Option<std::path::PathBuf>,
    pub limits: ConnLimits,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 0,
            batch_max: 64,
            cache_capacity: 65536,
            pending_cap: 8192,
            span_sample: 0,
            slow_query_us: 0,
            access_log: None,
            limits: ConnLimits::default(),
        }
    }
}

impl ServeOptions {
    /// `workers` with 0 resolved to the machine's parallelism (capped —
    /// serving work is short and lock-light, more threads just contend).
    pub fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, 4)
    }
}
