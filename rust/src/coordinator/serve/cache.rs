//! The hot-vertex result cache: sharded, bounded, generation-tagged.
//!
//! Keys are whole requests `(kind, ids)`; values are the *formatted
//! response line* computed by the batcher, so a hit is bit-identical to
//! a recomputation by construction (the parity contract). Every entry
//! is tagged with the snapshot generation it was computed under; a
//! lookup only matches the *current* generation, which makes snapshot
//! swaps free — no sweep, stale entries just stop matching and are
//! overwritten or FIFO-churned out.
//!
//! Sharding (16 ways, one mutex each) keeps the reactor's lookup and
//! the workers' inserts from contending on one lock; per-shard FIFO
//! eviction bounds memory without LRU bookkeeping on the hit path.

use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::QueryKind;

const SHARDS: usize = 16;

/// A whole request as cached: the verb plus its vertex ids, in request
/// order (TRI x y and TRI y x are distinct keys — symmetric answers are
/// not assumed, bit-parity is).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub kind: QueryKind,
    pub ids: Vec<u64>,
}

struct Shard {
    map: HashMap<CacheKey, (u64, String)>,
    /// Insertion order for FIFO eviction (keys in `map` exactly once).
    order: VecDeque<CacheKey>,
}

pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResultCache {
    /// `capacity` in total entries; 0 disables the cache entirely.
    pub fn new(capacity: usize) -> Self {
        let per_shard_cap = capacity.div_ceil(SHARDS);
        Self {
            shards: (0..SHARDS)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        order: VecDeque::new(),
                    })
                })
                .collect(),
            per_shard_cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.per_shard_cap > 0
    }

    fn shard_of(&self, key: &CacheKey) -> &Mutex<Shard> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// The cached response line for `key` at generation `gen`, counting
    /// the hit/miss. Entries from other generations are misses.
    // RELAXED: hit/miss tallies are statistics only — no reader makes a
    // control decision on them, so cross-counter ordering is irrelevant.
    pub fn get(&self, key: &CacheKey, gen: u64) -> Option<String> {
        if !self.enabled() {
            return None;
        }
        let shard = self.shard_of(key).lock().unwrap();
        match shard.map.get(key) {
            Some((g, line)) if *g == gen => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(line.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record `line` as the generation-`gen` answer for `key`.
    pub fn insert(&self, key: CacheKey, gen: u64, line: String) {
        if !self.enabled() {
            return;
        }
        let mut shard = self.shard_of(&key).lock().unwrap();
        if shard.map.insert(key.clone(), (gen, line)).is_none() {
            shard.order.push_back(key);
            if shard.order.len() > self.per_shard_cap {
                if let Some(old) = shard.order.pop_front() {
                    shard.map.remove(&old);
                }
            }
        }
    }

    // RELAXED: statistics read; may lag a concurrent get() by design.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    // RELAXED: statistics read; may lag a concurrent get() by design.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Live entries across all shards (test/inspection helper).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(kind: QueryKind, ids: &[u64]) -> CacheKey {
        CacheKey {
            kind,
            ids: ids.to_vec(),
        }
    }

    #[test]
    fn hit_only_on_matching_generation() {
        let c = ResultCache::new(1024);
        let k = key(QueryKind::Deg, &[7]);
        assert_eq!(c.get(&k, 0), None);
        c.insert(k.clone(), 0, "17.000".into());
        assert_eq!(c.get(&k, 0).as_deref(), Some("17.000"));
        // a generation flip invalidates without any sweep
        assert_eq!(c.get(&k, 1), None);
        c.insert(k.clone(), 1, "18.000".into());
        assert_eq!(c.get(&k, 1).as_deref(), Some("18.000"));
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 2);
    }

    #[test]
    fn ordered_ids_are_distinct_keys() {
        let c = ResultCache::new(1024);
        c.insert(key(QueryKind::Tri, &[1, 2]), 0, "a".into());
        assert_eq!(c.get(&key(QueryKind::Tri, &[2, 1]), 0), None);
        assert_eq!(c.get(&key(QueryKind::Jaccard, &[1, 2]), 0), None);
        assert_eq!(c.get(&key(QueryKind::Tri, &[1, 2]), 0).as_deref(), Some("a"));
    }

    #[test]
    fn capacity_bounds_entries_fifo() {
        let c = ResultCache::new(SHARDS); // one entry per shard
        for v in 0..1000u64 {
            c.insert(key(QueryKind::Deg, &[v]), 0, v.to_string());
        }
        assert!(c.len() <= SHARDS, "len={}", c.len());
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ResultCache::new(0);
        assert!(!c.enabled());
        c.insert(key(QueryKind::Deg, &[1]), 0, "x".into());
        assert_eq!(c.get(&key(QueryKind::Deg, &[1]), 0), None);
        assert!(c.is_empty());
        // disabled caches count nothing — hit rate stays undefined
        assert_eq!(c.hits() + c.misses(), 0);
    }
}
