//! The serving reactor: one thread, every socket, `poll(2)` readiness.
//!
//! Protocol (request → response, one line each):
//!
//! ```text
//! DEG <x>              → <estimate> | NONE
//! TRI <x> <y>          → <intersection> <union> <dominated:0|1> | NONE
//! JACCARD <x> <y>      → <jaccard> | NONE
//! UNION <x> [<y> ...]  → <estimate> | NONE
//! STATS                → vertices=<n> ranks=<p> p=<p> mem=<bytes>
//!                        dense=<n> mode=<heap|mmap> resident=<bytes>
//!                        evicted=<n> generation=<g> conns=<n>
//!                        pending=<n> shed=<n> cache_hits=<n>
//!                        cache_misses=<n>
//!                        comm=<sequential|threaded|process|tcp|none>
//!                        [ckpts=<n> restores=<n> hb_stale_ms=<ms>]
//!                        [rank<i>=<msgs>/<bytes>/<flushes> ...]
//! METRICS              → Prometheus text exposition, terminated by a
//!                        `# EOF` line (the one multi-line response)
//! RELOAD [path]        → OK generation=<g> vertices=<n> resident=<b>
//!                        | ERR reload: <why>  (old generation keeps
//!                        serving on error — zero downtime either way)
//! QUIT                 → BYE (closes the connection)
//! ```
//!
//! Unknown commands answer `ERR <reason>`. `mem`/`resident`/`comm`
//! semantics are unchanged from the thread-per-connection server this
//! replaces: `mem` is private heap sketch bytes, `resident` the mapped
//! snapshot bytes (shared page cache), `comm` the backend that
//! accumulated the engine (`none` for disk-loaded ones).
//!
//! Request handling is split by cost: STATS/METRICS/RELOAD/QUIT and
//! every parse error are answered inline by the reactor; DEG/TRI/
//! JACCARD/UNION first consult the generation-tagged result cache and
//! only on a miss enter the bounded pending queue toward the worker
//! pool (or shed with `ERR overloaded` when it is full). Responses are
//! delivered through per-connection *slots* in request order, so a
//! pipelined client mixing cached, inline, and worker-computed requests
//! never sees reordered answers.

use std::collections::VecDeque;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::Result;

use crate::comm::socket::Conn;
use crate::snapshot::GenSwap;
use crate::telemetry::{self, prom, Counter, Registry};

use super::super::engine::QueryEngine;
use super::batch::{
    record_query, run_worker, BatchQueue, Completion, Completions, Job,
    WorkerShared,
};
use super::cache::{CacheKey, ResultCache};
use super::poller::{self, fd_of, PollSlot, WakeRx, WakeTx};
use super::{ConnLimits, QueryKind, ServeOptions};

/// A request line longer than this without a newline is abuse, not a
/// query — the client is answered `ERR line too long` and dropped.
const MAX_LINE_BYTES: usize = 1 << 20;

/// A running serving-tier handle: one reactor thread plus the query
/// worker pool. Dropping (or [`QueryServer::stop`]) shuts everything
/// down and joins the threads.
pub struct QueryServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
    evicted: Arc<AtomicU64>,
    metrics: Arc<Registry>,
    engine: Arc<GenSwap<QueryEngine>>,
    cache: Arc<ResultCache>,
    queue: Arc<BatchQueue>,
    wake: WakeTx,
    reactor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl QueryServer {
    /// Bind and start serving. `addr` like `"127.0.0.1:0"` (0 = ephemeral).
    pub fn start(engine: Arc<QueryEngine>, addr: &str) -> Result<Self> {
        Self::start_with_opts(engine, addr, ServeOptions::default())
    }

    /// [`QueryServer::start`] with explicit per-connection read bounds.
    pub fn start_with_limits(
        engine: Arc<QueryEngine>,
        addr: &str,
        limits: ConnLimits,
    ) -> Result<Self> {
        Self::start_with_opts(
            engine,
            addr,
            ServeOptions {
                limits,
                ..ServeOptions::default()
            },
        )
    }

    /// Full-control start: worker count, batch bound, cache capacity,
    /// admission queue depth, connection limits.
    pub fn start_with_opts(
        engine: Arc<QueryEngine>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));
        let evicted = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(Registry::new());
        let engine = Arc::new(GenSwap::new(engine));
        let cache = Arc::new(ResultCache::new(opts.cache_capacity));
        let queue = Arc::new(BatchQueue::new(opts.pending_cap));
        let (wake, wake_rx) = poller::wake_pair()?;
        let completions = Arc::new(Completions::new(wake.clone()));

        let shared = Arc::new(WorkerShared {
            queue: Arc::clone(&queue),
            engine: Arc::clone(&engine),
            cache: Arc::clone(&cache),
            metrics: Arc::clone(&metrics),
            completions: Arc::clone(&completions),
            batch_max: opts.batch_max.max(1),
        });
        let workers_n = opts.resolved_workers();
        let workers = (0..workers_n)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || run_worker(&sh, i))
            })
            .collect();

        let access = match opts.access_log.as_ref() {
            Some(p) => Some(std::fs::File::create(p)?),
            None => None,
        };
        let reactor = Reactor {
            listener,
            wake_rx,
            shutdown: Arc::clone(&shutdown),
            live: Arc::clone(&live),
            evicted: Arc::clone(&evicted),
            metrics: Arc::clone(&metrics),
            engine: Arc::clone(&engine),
            cache: Arc::clone(&cache),
            queue: Arc::clone(&queue),
            completions,
            limits: opts.limits,
            clients: Vec::new(),
            free: Vec::new(),
            next_conn_id: 0,
            hits_total: 0,
            misses_total: 0,
            span_sample: opts.span_sample,
            slow_us: opts.slow_query_us,
            span_counter: 0,
            workers_n,
            access,
            shed: metrics.counter("degreesketch_requests_shed_total", &[]),
            reloads: metrics.counter("degreesketch_reloads_total", &[]),
        };
        let handle = std::thread::spawn(move || reactor.run());

        Ok(Self {
            addr: local,
            shutdown,
            live,
            evicted,
            metrics,
            engine,
            cache,
            queue,
            wake,
            reactor: Some(handle),
            workers,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Live connections currently owned by the reactor.
    // RELAXED: monitoring gauge — a snapshot that lags the reactor loop
    // by one round is exactly as useful as a fenced one.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// Connections evicted so far for exceeding the idle cap.
    // RELAXED: monitoring counter; see live_workers.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// This server's metric registry (query counters, latency and
    /// batch-size histograms, cache/shed/reload counters).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// The snapshot generation currently being served.
    pub fn generation(&self) -> u64 {
        self.engine.generation()
    }

    /// Result-cache hit/miss totals (also in `STATS` and `METRICS`).
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits(), self.cache.misses())
    }

    // RELAXED: the shutdown latch is monotonic and re-checked every
    // reactor round; wake() plus the joins below give the actual
    // synchronization — the flag only has to become visible eventually.
    fn begin_stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.shutdown();
        self.wake.wake();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Stop serving and join the reactor + worker threads.
    pub fn stop(mut self) {
        self.begin_stop();
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        self.begin_stop();
    }
}

// ---------------------------------------------------------------------
// Per-connection state
// ---------------------------------------------------------------------

struct Client {
    conn: Conn<TcpStream>,
    fd: i32,
    /// Monotonic connection id — completions carry it so an answer for
    /// a dead connection can never be delivered to its slot's reuser.
    id: u64,
    token: usize,
    last_activity: Instant,
    /// Response slots in request order (`None` = awaiting a worker).
    /// Only the contiguous ready prefix is ever written out.
    pending: VecDeque<Option<String>>,
    /// Sequence number of `pending`'s front slot.
    base_seq: u64,
    next_seq: u64,
    read_closed: bool,
    closing: bool,
}

impl Client {
    fn reserve_slot(&mut self) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(None);
        seq
    }

    fn fill_slot(&mut self, seq: u64, line: String) {
        if let Some(idx) = seq.checked_sub(self.base_seq) {
            if let Some(slot) = self.pending.get_mut(idx as usize) {
                *slot = Some(line);
            }
        }
    }

    fn push_inline(&mut self, line: String) {
        let seq = self.reserve_slot();
        self.fill_slot(seq, line);
    }

    /// Move every contiguous ready response into the write queue.
    fn flush_ready(&mut self) {
        while matches!(self.pending.front(), Some(Some(_))) {
            let line = self.pending.pop_front().flatten().unwrap();
            self.base_seq += 1;
            self.conn.queue_frame(line.into_bytes());
        }
    }
}

// ---------------------------------------------------------------------
// Request parsing
// ---------------------------------------------------------------------

enum Request {
    Query(CacheKey),
    /// Parse errors and usage messages, answered as-is.
    Immediate(String),
    Stats,
    Metrics,
    Reload(Option<String>),
    Quit,
}

fn parse_request(line: &str) -> Request {
    let mut it = line.split_whitespace();
    let Some(cmd) = it.next() else {
        return Request::Immediate("ERR empty".into());
    };
    let cmd = cmd.to_ascii_uppercase();
    let parse_ids = |it: std::str::SplitWhitespace| -> Result<Vec<u64>, String> {
        it.map(|t| t.parse::<u64>().map_err(|_| format!("bad id {t:?}")))
            .collect()
    };
    let query = |kind: QueryKind, ids: Vec<u64>| {
        Request::Query(CacheKey { kind, ids })
    };
    match cmd.as_str() {
        "DEG" => match parse_ids(it) {
            Ok(ids) if ids.len() == 1 => query(QueryKind::Deg, ids),
            Ok(_) => Request::Immediate("ERR usage: DEG <x>".into()),
            Err(e) => Request::Immediate(format!("ERR {e}")),
        },
        "TRI" => match parse_ids(it) {
            Ok(ids) if ids.len() == 2 => query(QueryKind::Tri, ids),
            Ok(_) => Request::Immediate("ERR usage: TRI <x> <y>".into()),
            Err(e) => Request::Immediate(format!("ERR {e}")),
        },
        "JACCARD" => match parse_ids(it) {
            Ok(ids) if ids.len() == 2 => query(QueryKind::Jaccard, ids),
            Ok(_) => Request::Immediate("ERR usage: JACCARD <x> <y>".into()),
            Err(e) => Request::Immediate(format!("ERR {e}")),
        },
        "UNION" => match parse_ids(it) {
            Ok(ids) if !ids.is_empty() => query(QueryKind::Union, ids),
            Ok(_) => Request::Immediate("ERR usage: UNION <x> [<y> ...]".into()),
            Err(e) => Request::Immediate(format!("ERR {e}")),
        },
        "STATS" => Request::Stats,
        "METRICS" => Request::Metrics,
        "RELOAD" => {
            let path = it.next().map(String::from);
            match it.next() {
                Some(_) => Request::Immediate("ERR usage: RELOAD [path]".into()),
                None => Request::Reload(path),
            }
        }
        "QUIT" => Request::Quit,
        other => Request::Immediate(format!("ERR unknown command {other:?}")),
    }
}

// ---------------------------------------------------------------------
// The reactor proper
// ---------------------------------------------------------------------

struct Reactor {
    listener: TcpListener,
    wake_rx: WakeRx,
    shutdown: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
    evicted: Arc<AtomicU64>,
    metrics: Arc<Registry>,
    engine: Arc<GenSwap<QueryEngine>>,
    cache: Arc<ResultCache>,
    queue: Arc<BatchQueue>,
    completions: Arc<Completions>,
    limits: ConnLimits,
    clients: Vec<Option<Client>>,
    /// Freed slot indices, reused before growing `clients`.
    free: Vec<usize>,
    next_conn_id: u64,
    /// Aggregate cache totals for `STATS` (the per-kind counters live in
    /// the metric registry as `degreesketch_cache_{hits,misses}_total`).
    hits_total: u64,
    misses_total: u64,
    /// 1-in-N query-span sampling (0 = off) and the rolling counter
    /// behind it.
    span_sample: u64,
    slow_us: u64,
    span_counter: u64,
    /// Worker pool size; cache-hit spans (answered inline by the
    /// reactor, no worker involved) log on track `workers_n`.
    workers_n: usize,
    /// JSONL access log (sampled queries + every slow query).
    access: Option<std::fs::File>,
    shed: Counter,
    reloads: Counter,
}

impl Reactor {
    // RELAXED: shutdown is a monotonic latch polled once per loop round
    // and live/evicted are monitoring tallies read by stats endpoints;
    // none of them guards data this loop hands to another thread (the
    // job queue's mutex does that).
    fn run(mut self) {
        // listener + wake pipe occupy the first two poll slots
        const FIXED: usize = 2;
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let mut slots = Vec::with_capacity(self.clients.len() + FIXED);
            slots.push(PollSlot::new(fd_of(&self.listener), true, false));
            slots.push(PollSlot::new(self.wake_rx.fd(), true, false));
            for c in &self.clients {
                slots.push(match c {
                    Some(c) => PollSlot::new(
                        c.fd,
                        !c.read_closed,
                        c.conn.has_queued_writes(),
                    ),
                    None => PollSlot::new(-1, false, false),
                });
            }
            let timeout = self
                .limits
                .read_timeout
                .min(Duration::from_millis(250))
                .max(Duration::from_millis(1));
            poller::poll(&mut slots, timeout);
            let now = Instant::now();
            self.wake_rx.drain();

            // deliver worker completions into their response slots
            for done in self.completions.drain() {
                let Completion {
                    token,
                    conn_id,
                    seq,
                    line,
                    kind,
                    sampled,
                    worker,
                    queue_us,
                    kernel_us,
                    started,
                    finished,
                } = done;
                if let Some(c) = self
                    .clients
                    .get_mut(token)
                    .and_then(|s| s.as_mut())
                {
                    if c.id == conn_id {
                        c.fill_slot(seq, line + "\n");
                        c.last_activity = now;
                    }
                }
                // span bookkeeping runs even when the connection died —
                // the work happened either way
                let flush_us = now
                    .saturating_duration_since(finished)
                    .as_micros() as u64;
                let total_us = now
                    .saturating_duration_since(started)
                    .as_micros() as u64;
                self.finish_span(
                    worker, kind, false, queue_us, kernel_us, flush_us,
                    total_us, sampled,
                );
            }

            if slots[0].readable {
                self.accept_all(now);
            }

            for token in 0..self.clients.len() {
                let flags = slots
                    .get(FIXED + token)
                    .copied()
                    .unwrap_or_default();
                self.client_io(token, &flags, now);
            }

            self.sweep(now);
        }
        self.live.store(0, Ordering::Relaxed);
    }

    fn accept_all(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nodelay(true).ok();
                    let fd = fd_of(&stream);
                    // Conn::new flips the stream nonblocking
                    let Ok(conn) = Conn::new(stream) else { continue };
                    self.next_conn_id += 1;
                    let token = self.free.pop().unwrap_or_else(|| {
                        self.clients.push(None);
                        self.clients.len() - 1
                    });
                    self.clients[token] = Some(Client {
                        conn,
                        fd,
                        id: self.next_conn_id,
                        token,
                        last_activity: now,
                        pending: VecDeque::new(),
                        base_seq: 0,
                        next_seq: 0,
                        read_closed: false,
                        closing: false,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    /// One connection's IO round: fill + parse on readability, then
    /// flush ready responses and pump the write queue.
    fn client_io(&mut self, token: usize, flags: &PollSlot, now: Instant) {
        let Some(mut c) = self.clients[token].take() else {
            return;
        };
        let mut dead = false;
        if (flags.readable || flags.broken) && !c.read_closed {
            match c.conn.fill("serve") {
                Ok(outcome) => {
                    if outcome.eof {
                        c.read_closed = true;
                    }
                    while let Some(line) = c.conn.take_line() {
                        c.last_activity = now;
                        self.handle_line(&mut c, &line);
                    }
                    if c.read_closed {
                        // a final request without a trailing newline is
                        // still answered (blocking-server behavior)
                        if let Some(rest) = c.conn.take_trailing() {
                            c.last_activity = now;
                            self.handle_line(&mut c, &rest);
                        }
                    } else if c.conn.pending_read_bytes() > MAX_LINE_BYTES {
                        c.push_inline("ERR line too long\n".into());
                        c.closing = true;
                    }
                    c.conn.compact();
                }
                Err(_) => dead = true,
            }
        }
        if !dead {
            c.flush_ready();
            if c.conn.has_queued_writes()
                && c.conn.pump_write("serve").is_err()
            {
                dead = true;
            }
        }
        if dead {
            self.release(token);
        } else {
            self.clients[token] = Some(c);
        }
    }

    fn handle_line(&mut self, c: &mut Client, raw: &[u8]) {
        if c.closing {
            return; // post-QUIT pipeline residue is ignored
        }
        let text = String::from_utf8_lossy(raw);
        let line = text.trim_end();
        let started = Instant::now();
        match parse_request(line) {
            Request::Query(key) => {
                // 1-in-N span sampling, decided at admission so the
                // whole pipeline (worker included) measures its stages
                let sampled = self.span_sample > 0 && {
                    let n = self.span_counter;
                    self.span_counter += 1;
                    n % self.span_sample == 0
                };
                let kind = key.kind;
                let gen = self.engine.generation();
                if let Some(hit) = self.cache.get(&key, gen) {
                    self.hits_total += 1;
                    self.metrics
                        .counter(
                            "degreesketch_cache_hits_total",
                            &[("kind", kind.name())],
                        )
                        .inc();
                    record_query(&self.metrics, kind.name(), started);
                    c.push_inline(hit + "\n");
                    // the whole span is the cache lookup: answered
                    // inline, no queue/kernel/flush stages
                    let cache_us = started.elapsed().as_micros() as u64;
                    self.finish_span(
                        self.workers_n, kind, true, 0, 0, 0, cache_us,
                        sampled,
                    );
                    return;
                }
                self.misses_total += 1;
                self.metrics
                    .counter(
                        "degreesketch_cache_misses_total",
                        &[("kind", kind.name())],
                    )
                    .inc();
                let seq = c.reserve_slot();
                let admitted = self.queue.try_push(Job {
                    key,
                    token: c.token,
                    conn_id: c.id,
                    seq,
                    started,
                    sampled,
                });
                if !admitted {
                    self.shed.inc();
                    c.fill_slot(seq, "ERR overloaded\n".into());
                }
            }
            Request::Immediate(s) => c.push_inline(s + "\n"),
            Request::Stats => {
                let line = self.stats_line();
                c.push_inline(line + "\n");
            }
            Request::Metrics => {
                self.scrape_gauges();
                // multi-line: carries its own framing (`# EOF\n`)
                c.push_inline(prom::render(&[
                    &self.metrics,
                    telemetry::registry(),
                ]));
            }
            Request::Reload(path) => {
                let reply = self.do_reload(path.as_deref());
                c.push_inline(reply + "\n");
            }
            Request::Quit => {
                c.push_inline("BYE\n".into());
                c.closing = true;
            }
        }
    }

    /// Close out one query's span: feed the per-stage histograms, and —
    /// when the query was sampled or breached the slow-query threshold —
    /// write the per-request records (trace event + access log). `hit`
    /// marks a cache hit answered inline by the reactor: its only stage
    /// is the cache lookup (`total_us`), logged on track `workers_n`.
    #[allow(clippy::too_many_arguments)]
    fn finish_span(
        &mut self,
        worker: usize,
        kind: QueryKind,
        hit: bool,
        queue_us: u64,
        kernel_us: u64,
        flush_us: u64,
        total_us: u64,
        sampled: bool,
    ) {
        let kname = kind.name();
        let stages: &[(&str, u64)] = if hit {
            &[("cache", total_us)]
        } else {
            &[
                ("queue", queue_us),
                ("kernel", kernel_us),
                ("flush", flush_us),
            ]
        };
        for (stage, v) in stages {
            self.metrics
                .histogram(
                    "degreesketch_query_stage_us",
                    &[("stage", stage), ("kind", kname)],
                )
                .observe(*v);
        }
        let slow = self.slow_us > 0 && total_us >= self.slow_us;
        if sampled {
            telemetry::serve_event(
                worker,
                "serve.span",
                &[
                    ("kind", kind.index()),
                    ("hit", u64::from(hit)),
                    ("queue_us", queue_us),
                    ("kernel_us", kernel_us),
                    ("flush_us", flush_us),
                    ("total_us", total_us),
                ],
            );
        }
        // slow queries ALWAYS reach the access log, sampled or not —
        // tail outliers must survive any sampling rate
        if (sampled || slow) && self.access.is_some() {
            let t_us = SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_micros() as u64)
                .unwrap_or(0);
            let w = self.access.as_mut().unwrap();
            let _ = writeln!(
                w,
                "{{\"t_us\":{t_us},\"kind\":\"{kname}\",\"hit\":{hit},\
                 \"worker\":{worker},\"queue_us\":{queue_us},\
                 \"kernel_us\":{kernel_us},\"flush_us\":{flush_us},\
                 \"total_us\":{total_us},\"sampled\":{sampled},\
                 \"slow\":{slow}}}"
            );
            let _ = w.flush();
        }
    }

    /// Open the next snapshot generation and swap it in. The current
    /// generation serves until the swap lands; on error it simply keeps
    /// serving — a failed reload is invisible to other clients.
    fn do_reload(&self, path_arg: Option<&str>) -> String {
        let (cur, _) = self.engine.load();
        let opened = match path_arg {
            Some(p) => {
                // explicit path: keep the current backing mode if known
                let mode = cur
                    .reload_origin()
                    .map(|(_, m)| m)
                    .unwrap_or_default();
                QueryEngine::open_snapshot_with(Path::new(p), mode)
            }
            None => cur.reopen(),
        };
        match opened {
            Ok(next) => {
                let vertices = next.num_vertices();
                let resident = next.resident_bytes();
                let gen = self.engine.swap(Arc::new(next));
                self.reloads.inc();
                self.metrics
                    .gauge("degreesketch_server_generation", &[])
                    .set(gen);
                format!(
                    "OK generation={gen} vertices={vertices} \
                     resident={resident}"
                )
            }
            // single-line error: the anyhow chain joined with ": "
            Err(e) => format!("ERR reload: {e:#}"),
        }
    }

    // RELAXED: evicted is a monitoring tally; a stats line may lag the
    // reactor by a round.
    fn stats_line(&self) -> String {
        let (engine, gen) = self.engine.load();
        let mut line = format!(
            "vertices={} ranks={} p={} mem={} dense={} mode={} \
             resident={} evicted={}",
            engine.num_vertices(),
            engine.num_ranks(),
            engine.config().p(),
            engine.heap_bytes(),
            engine.num_dense_sketches(),
            engine.backing_mode(),
            engine.resident_bytes(),
            self.evicted.load(Ordering::Relaxed)
        );
        line.push_str(&format!(
            " generation={gen} conns={} pending={} shed={} cache_hits={} \
             cache_misses={}",
            self.clients.iter().filter(|c| c.is_some()).count(),
            self.queue.len(),
            self.shed.get(),
            self.hits_total,
            self.misses_total
        ));
        match engine.accumulation_stats() {
            Some(cs) => {
                line.push_str(&format!(
                    " comm={} ckpts={} restores={} hb_stale_ms={}",
                    cs.mode.name(),
                    cs.checkpoints,
                    cs.restores,
                    cs.max_stale_ms
                ));
                for (r, pr) in cs.per_rank.iter().enumerate() {
                    line.push_str(&format!(
                        " rank{r}={}/{}/{}",
                        pr.messages, pr.bytes, pr.flushes
                    ));
                }
            }
            None => line.push_str(" comm=none"),
        }
        line
    }

    /// Refresh scrape-time gauges: engine sizing, serving-tier state,
    /// and — when this engine was accumulated in-process — the comm
    /// fabric's message/checkpoint/recovery/heartbeat totals.
    // RELAXED: scrape-time snapshot of a monitoring tally; see
    // stats_line.
    fn scrape_gauges(&self) {
        let (engine, gen) = self.engine.load();
        let g = |name: &str, v: u64| self.metrics.gauge(name, &[]).set(v);
        g("degreesketch_server_vertices", engine.num_vertices() as u64);
        g("degreesketch_server_heap_bytes", engine.heap_bytes() as u64);
        g(
            "degreesketch_server_resident_bytes",
            engine.resident_bytes() as u64,
        );
        g(
            "degreesketch_server_dense_sketches",
            engine.num_dense_sketches() as u64,
        );
        g(
            "degreesketch_server_evicted_connections",
            self.evicted.load(Ordering::Relaxed),
        );
        g("degreesketch_server_generation", gen);
        g(
            "degreesketch_server_connections",
            self.clients.iter().filter(|c| c.is_some()).count() as u64,
        );
        g("degreesketch_server_pending_requests", self.queue.len() as u64);
        if let Some(cs) = engine.accumulation_stats() {
            g("degreesketch_comm_messages", cs.messages);
            g("degreesketch_comm_bytes", cs.bytes);
            g("degreesketch_comm_flushes", cs.flushes);
            g("degreesketch_comm_checkpoints", cs.checkpoints);
            g("degreesketch_comm_restores", cs.restores);
            g("degreesketch_comm_hb_stale_ms", cs.max_stale_ms);
            for (r, pr) in cs.per_rank.iter().enumerate() {
                let rank = r.to_string();
                self.metrics
                    .gauge("degreesketch_comm_rank_messages", &[("rank", &rank)])
                    .set(pr.messages);
                self.metrics
                    .gauge("degreesketch_comm_rank_bytes", &[("rank", &rank)])
                    .set(pr.bytes);
            }
        }
    }

    /// Close idle/finished connections and refresh the live count.
    // RELAXED: evicted/live are monitoring tallies published for stats
    // readers on other threads; only the reactor writes them, so there
    // is no ordering to establish.
    fn sweep(&mut self, now: Instant) {
        for token in 0..self.clients.len() {
            let Some(c) = self.clients[token].as_mut() else {
                continue;
            };
            let done_reading = c.read_closed || c.closing;
            let drained =
                c.pending.is_empty() && !c.conn.has_queued_writes();
            if done_reading && drained {
                self.release(token);
                continue;
            }
            // Idle eviction by poll deadline: only truly idle clients —
            // nothing in flight, silent past the cap. Partial lines
            // never reset the idle clock (`last_activity` moves on
            // complete requests only), so half-open peers that wrote
            // "DEG " and vanished are evicted too.
            if !done_reading
                && c.pending.is_empty()
                && now.duration_since(c.last_activity) >= self.limits.idle_cap
            {
                c.conn
                    .queue_frame(b"ERR idle timeout, closing\n".to_vec());
                let _ = c.conn.pump_write("serve-evict");
                self.evicted.fetch_add(1, Ordering::Relaxed);
                self.release(token);
            }
        }
        let n = self.clients.iter().filter(|c| c.is_some()).count();
        self.live.store(n, Ordering::Relaxed);
    }

    fn release(&mut self, token: usize) {
        if self.clients[token].take().is_some() {
            self.free.push(token);
        }
    }
}

#[cfg(test)]
// Miri cannot emulate the raw poll/mmap/fork/socket syscalls these
// tests drive; the Miri CI job scopes to the pure-core suites instead.
#[cfg(not(miri))]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::gen::karate;
    use crate::graph::stream::MemoryStream;
    use crate::hll::HllConfig;
    use std::io::{BufRead, BufReader, Write};

    fn test_engine() -> Arc<QueryEngine> {
        let stream = MemoryStream::new(karate::edges());
        let ds = accumulate_stream(
            &stream,
            2,
            HllConfig::new(12, 0x5E),
            AccumulateOptions::default(),
        );
        Arc::new(QueryEngine::new(ds))
    }

    fn ask(addr: std::net::SocketAddr, lines: &[&str]) -> Vec<String> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(w, "{l}").unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            out.push(resp.trim().to_string());
        }
        out
    }

    /// One METRICS scrape: reads the multi-line body through its `# EOF`
    /// framing line (inclusive).
    fn scrape_metrics(addr: std::net::SocketAddr) -> String {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "METRICS").unwrap();
        let mut text = String::new();
        loop {
            let mut line = String::new();
            assert!(r.read_line(&mut line).unwrap() > 0, "closed before # EOF");
            text.push_str(&line);
            if line.trim_end() == "# EOF" {
                break;
            }
        }
        writeln!(w, "QUIT").unwrap();
        text
    }

    #[test]
    fn serves_queries_over_tcp() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let resp = ask(
            addr,
            &[
                "DEG 33",
                "DEG 999",
                "TRI 0 33",
                "JACCARD 0 1",
                "UNION 0 33",
                "STATS",
                "NOPE",
                "QUIT",
            ],
        );
        let d: f64 = resp[0].parse().unwrap();
        assert!((d - 17.0).abs() < 2.0, "{resp:?}");
        assert_eq!(resp[1], "NONE");
        assert_eq!(resp[2].split_whitespace().count(), 3);
        let j: f64 = resp[3].parse().unwrap();
        assert!((0.0..=1.0).contains(&j));
        assert!(resp[4].parse::<f64>().unwrap() > 20.0);
        assert!(resp[5].starts_with("vertices=34"), "{:?}", resp[5]);
        assert!(resp[5].contains("mode=heap"), "{:?}", resp[5]);
        assert!(resp[5].contains("resident="), "{:?}", resp[5]);
        assert!(resp[5].contains("generation=0"), "{:?}", resp[5]);
        // accumulated in-process on 2 sequential ranks: comm backend and
        // both ranks' message/byte/flush counters are reported
        assert!(resp[5].contains("comm=sequential"), "{:?}", resp[5]);
        assert!(resp[5].contains("rank0="), "{:?}", resp[5]);
        assert!(resp[5].contains("rank1="), "{:?}", resp[5]);
        assert!(resp[6].starts_with("ERR"));
        assert_eq!(resp[7], "BYE");
        server.stop();
    }

    #[test]
    fn pipelined_requests_answer_in_order() {
        // One write carrying inline (STATS), worker (DEG/TRI), and
        // cached requests: responses must come back in request order.
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        write!(w, "DEG 33\nSTATS\nDEG 33\nTRI 0 33\nSTATS\nQUIT\n").unwrap();
        let mut lines = Vec::new();
        for _ in 0..6 {
            let mut line = String::new();
            assert!(r.read_line(&mut line).unwrap() > 0);
            lines.push(line.trim().to_string());
        }
        assert!(lines[0].parse::<f64>().is_ok(), "{lines:?}");
        assert!(lines[1].starts_with("vertices="), "{lines:?}");
        // the repeat answers bit-identically (cached or recomputed)
        assert_eq!(lines[0], lines[2], "{lines:?}");
        assert_eq!(lines[3].split_whitespace().count(), 3, "{lines:?}");
        assert!(lines[4].starts_with("vertices="), "{lines:?}");
        assert_eq!(lines[5], "BYE");
        server.stop();
    }

    #[test]
    fn metrics_verb_serves_valid_prometheus_text_with_quantiles() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        // Exercise each timed verb so every per-kind series exists.
        let _ = ask(
            addr,
            &["DEG 0", "DEG 33", "TRI 0 33", "JACCARD 0 1", "UNION 0 33", "QUIT"],
        );
        let text = scrape_metrics(addr);
        // Must pass the minimal Prometheus checker (TYPE lines, cumulative
        // buckets, # EOF framing).
        let samples = prom::check_text(&text)
            .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
        assert!(samples > 10, "suspiciously few samples:\n{text}");
        for kind in ["deg", "tri", "jaccard", "union"] {
            assert!(
                text.contains(&format!(
                    "degreesketch_queries_total{{kind=\"{kind}\"}}"
                )),
                "missing counter for {kind}:\n{text}"
            );
            for q in ["0.5", "0.99"] {
                assert!(
                    text.contains(&format!(
                        "degreesketch_query_latency_us_quantiles\
                         {{kind=\"{kind}\",quantile=\"{q}\"}}"
                    )),
                    "missing p{q} for {kind}:\n{text}"
                );
            }
        }
        // The serving tier's own series: batch-size histogram (every
        // worker batch observes), cache counters, generation gauge.
        assert!(text.contains("degreesketch_query_batch_size"), "{text}");
        assert!(text.contains("degreesketch_cache_misses_total"), "{text}");
        assert!(text.contains("degreesketch_server_generation"), "{text}");
        // Comm gauges from the in-process accumulation are scraped too.
        assert!(text.contains("degreesketch_comm_messages"), "{text}");
        assert!(text.contains("degreesketch_comm_hb_stale_ms"), "{text}");
        // DEG ran twice above; the counter must say so.
        assert!(
            text.contains("degreesketch_queries_total{kind=\"deg\"} 2"),
            "{text}"
        );
        server.stop();
    }

    #[test]
    fn stats_reports_hb_staleness_alongside_recovery_counts() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let resp = ask(server.addr(), &["STATS", "QUIT"]);
        assert!(resp[0].contains("ckpts="), "{:?}", resp[0]);
        assert!(resp[0].contains("restores="), "{:?}", resp[0]);
        assert!(resp[0].contains("hb_stale_ms=0"), "{:?}", resp[0]);
        server.stop();
    }

    #[test]
    fn stats_reports_mmap_backing_for_snapshot_engines() {
        let path = std::env::temp_dir().join("ds_server_stats.snap");
        let _ = std::fs::remove_file(&path);
        test_engine().save_snapshot(&path).unwrap();
        let engine = Arc::new(QueryEngine::load(&path).unwrap());
        let expected_mode = format!("mode={}", engine.backing_mode());
        let server = QueryServer::start(engine, "127.0.0.1:0").unwrap();
        let resp = ask(server.addr(), &["STATS", "QUIT"]);
        // mmap on 64-bit unix; the heap fallback elsewhere — either way the
        // snapshot resident size (the file length) is reported
        assert!(resp[0].contains(&expected_mode), "{:?}", resp[0]);
        // loaded engines weren't accumulated here: no comm stats to report
        assert!(resp[0].contains("comm=none"), "{:?}", resp[0]);
        let resident: u64 = resp[0]
            .split_whitespace()
            .find_map(|t| t.strip_prefix("resident="))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(resident, std::fs::metadata(&path).unwrap().len());
        server.stop();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn reload_on_heap_engine_reports_error_and_keeps_serving() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let resp = ask(server.addr(), &["RELOAD", "DEG 33", "QUIT"]);
        assert!(resp[0].starts_with("ERR reload"), "{:?}", resp[0]);
        // the failed reload changed nothing — queries still flow
        assert!(resp[1].parse::<f64>().is_ok(), "{:?}", resp[1]);
        assert_eq!(server.generation(), 0);
        server.stop();
    }

    #[test]
    fn finished_workers_are_reaped_in_the_accept_loop() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        for _ in 0..16 {
            let resp = ask(addr, &["DEG 0", "QUIT"]);
            assert!(resp[0].parse::<f64>().is_ok());
        }
        // every connection above is closed; after the next reactor round
        // the live-connection count must fall back to ~0 rather than
        // accumulating one slot per historical connection
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        loop {
            // poke the loop so it runs a sweep pass even if idle
            let _ = ask(addr, &["QUIT"]);
            if server.live_workers() <= 2 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "connections never swept: {}",
                server.live_workers()
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        server.stop();
    }

    #[test]
    fn idle_connections_are_evicted_and_counted() {
        let limits = ConnLimits {
            read_timeout: Duration::from_millis(10),
            idle_cap: Duration::from_millis(80),
        };
        let server =
            QueryServer::start_with_limits(test_engine(), "127.0.0.1:0", limits)
                .unwrap();
        let addr = server.addr();
        // A silent client — and a half-open one that wrote a partial line
        // (no newline) — must both be evicted, not parked forever.
        let silent = TcpStream::connect(addr).unwrap();
        let half_open = TcpStream::connect(addr).unwrap();
        {
            let mut w = half_open.try_clone().unwrap();
            write!(w, "DEG ").unwrap(); // never finishes the line
        }
        for stream in [silent, half_open] {
            let mut r = BufReader::new(stream);
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            assert!(resp.starts_with("ERR idle"), "{resp:?}");
            resp.clear();
            assert_eq!(r.read_line(&mut resp).unwrap(), 0, "not closed");
        }
        // A live client still works and sees the eviction counter in STATS.
        let out = ask(addr, &["STATS", "QUIT"]);
        assert!(out[0].contains("evicted=2"), "{:?}", out[0]);
        assert_eq!(server.evicted(), 2);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let resp = ask(addr, &["DEG 0", "QUIT"]);
                    resp[0].parse::<f64>().unwrap()
                })
            })
            .collect();
        for h in handles {
            let d = h.join().unwrap();
            assert!((d - 16.0).abs() < 2.0);
        }
        server.stop();
    }
}
