//! The persistent query engine — DegreeSketch's "leave-behind" property.
//!
//! After accumulation, `D` is saved once and answers graph queries forever
//! after without touching the edge stream: degree estimates, pairwise
//! intersection (edge-local triangle) estimates, Jaccard similarity, and
//! cardinalities of arbitrary adjacency-set unions — the "more general
//! queries that can be phrased as unions and possibly an intersection of
//! adjacency sets" of the paper's conclusion.
//!
//! On-disk layout (`save_dir`):
//! ```text
//! meta.txt          p seed ranks partitioner-name
//! shard_<r>.bin     u32 count, then count × (u64 vertex, HLL blob)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::comm::CommStats;
use crate::hll::{
    mle_intersect, Estimator, Hll, HllConfig, IntersectionEstimate,
    MleOptions,
};

use super::partition::Partitioner;
use super::sketch::{DegreeSketch, Shard};

/// A loaded (or freshly accumulated) DegreeSketch plus query methods.
pub struct QueryEngine {
    ds: DegreeSketch,
    mle: MleOptions,
    estimator: Estimator,
}

impl QueryEngine {
    pub fn new(ds: DegreeSketch) -> Self {
        Self {
            ds,
            mle: MleOptions::default(),
            estimator: Estimator::default(),
        }
    }

    pub fn sketch_data(&self) -> &DegreeSketch {
        &self.ds
    }

    /// `|D[x]|` — degree estimate (None if x never appeared).
    pub fn degree(&self, x: u64) -> Option<f64> {
        self.ds.sketch(x).map(|s| s.estimate_with(self.estimator))
    }

    /// `|D̃[x] ∩ D̃[y]|` — edge-local triangle estimate for any vertex pair
    /// (Eq. 10); also reports the union and domination status.
    pub fn intersection(&self, x: u64, y: u64) -> Option<IntersectionEstimate> {
        let a = self.ds.sketch(x)?;
        let b = self.ds.sketch(y)?;
        Some(mle_intersect(a, b, &self.mle))
    }

    /// Jaccard similarity of two adjacency sets — the paper's triangle
    /// density (Figure 3).
    pub fn jaccard(&self, x: u64, y: u64) -> Option<f64> {
        self.intersection(x, y).map(|e| e.jaccard())
    }

    /// `|∪̃_i D[x_i]|` — cardinality of a union of adjacency sets, e.g.
    /// "how many distinct accounts are adjacent to this suspect set?".
    pub fn union_cardinality(&self, xs: &[u64]) -> Option<f64> {
        let mut it = xs.iter().filter_map(|&x| self.ds.sketch(x));
        let first = it.next()?;
        let mut acc = first.clone();
        for s in it {
            acc.merge(s);
        }
        Some(acc.estimate_with(self.estimator))
    }

    /// Persist to a directory (created if needed).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let meta = format!(
            "{} {} {} {}\n",
            self.ds.config().p(),
            self.ds.config().hasher().seed(),
            self.ds.num_ranks(),
            self.ds.partitioner().name(),
        );
        std::fs::write(dir.join("meta.txt"), meta)?;
        for (rank, shard) in self.ds.shards().iter().enumerate() {
            let f = File::create(dir.join(format!("shard_{rank}.bin")))?;
            let mut w = BufWriter::with_capacity(1 << 20, f);
            w.write_all(&(shard.len() as u32).to_le_bytes())?;
            // frozen shards already iterate in ascending vertex order, so
            // files are reproducible without re-sorting
            for (v, h) in shard.iter() {
                w.write_all(&v.to_le_bytes())?;
                h.write_to(&mut w)?;
            }
            w.flush()?;
        }
        Ok(())
    }

    /// Load a previously saved engine.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("reading {}/meta.txt", dir.display()))?;
        let parts: Vec<&str> = meta.split_whitespace().collect();
        if parts.len() != 4 {
            bail!("malformed meta.txt: {meta:?}");
        }
        let p: u8 = parts[0].parse().context("bad p")?;
        let seed: u64 = parts[1].parse().context("bad seed")?;
        let ranks: usize = parts[2].parse().context("bad ranks")?;
        let partitioner = Partitioner::from_name(parts[3])
            .with_context(|| format!("bad partitioner {:?}", parts[3]))?;
        let config = HllConfig::new(p, seed);

        let mut shards: Vec<Shard> = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            let f = File::open(dir.join(format!("shard_{rank}.bin")))?;
            let mut r = BufReader::with_capacity(1 << 20, f);
            let mut count_buf = [0u8; 4];
            r.read_exact(&mut count_buf)?;
            let count = u32::from_le_bytes(count_buf) as usize;
            let mut entries: Vec<(u64, Hll)> = Vec::with_capacity(count);
            let mut prev: Option<u64> = None;
            for _ in 0..count {
                let mut vbuf = [0u8; 8];
                r.read_exact(&mut vbuf)?;
                let v = u64::from_le_bytes(vbuf);
                let h = Hll::read_from(&mut r)?;
                if h.config() != &config {
                    bail!("shard {rank}: sketch config mismatch for vertex {v}");
                }
                if partitioner.rank_of(v, ranks) != rank {
                    bail!("shard {rank}: vertex {v} stored on wrong rank");
                }
                if prev.is_some_and(|p| p >= v) {
                    bail!("shard {rank}: vertex ids not strictly increasing");
                }
                prev = Some(v);
                entries.push((v, h));
            }
            shards.push(Shard::from_sorted_entries(entries));
        }
        Ok(Self::new(DegreeSketch::from_parts(
            config,
            partitioner,
            shards,
            CommStats::default(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::gen::karate;
    use crate::graph::stream::MemoryStream;

    fn engine() -> QueryEngine {
        let stream = MemoryStream::new(karate::edges());
        let ds = accumulate_stream(
            &stream,
            3,
            HllConfig::new(12, 0xE0),
            AccumulateOptions::default(),
        );
        QueryEngine::new(ds)
    }

    #[test]
    fn degree_queries() {
        let e = engine();
        // vertex 33 (1-indexed 34) has degree 17
        let d = e.degree(33).unwrap();
        assert!((d - 17.0).abs() < 2.0, "{d}");
        assert_eq!(e.degree(999), None);
    }

    #[test]
    fn union_queries() {
        let e = engine();
        // union of the two hubs' adjacency covers most of the club
        let u = e.union_cardinality(&[0, 33]).unwrap();
        assert!(u > 25.0 && u < 40.0, "{u}");
        assert_eq!(e.union_cardinality(&[777]), None);
    }

    #[test]
    fn intersection_and_jaccard() {
        let e = engine();
        let est = e.intersection(0, 33).unwrap();
        assert!(est.intersection >= 0.0);
        let j = e.jaccard(0, 33).unwrap();
        assert!((0.0..=1.0).contains(&j));
    }

    #[test]
    fn save_load_round_trip() {
        let e = engine();
        let dir = std::env::temp_dir().join("degreesketch_engine_test");
        let _ = std::fs::remove_dir_all(&dir);
        e.save(&dir).unwrap();
        let loaded = QueryEngine::load(&dir).unwrap();
        assert_eq!(
            loaded.sketch_data().num_vertices(),
            e.sketch_data().num_vertices()
        );
        for (v, h) in e.sketch_data().iter() {
            assert_eq!(loaded.sketch_data().sketch(v), Some(h), "vertex {v}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_rejects_corruption() {
        let e = engine();
        let dir = std::env::temp_dir().join("degreesketch_engine_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        e.save(&dir).unwrap();
        std::fs::write(dir.join("meta.txt"), "lol").unwrap();
        assert!(QueryEngine::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
