//! The persistent query engine — DegreeSketch's "leave-behind" property.
//!
//! After accumulation, `D` is saved once and answers graph queries forever
//! after without touching the edge stream: degree estimates, pairwise
//! intersection (edge-local triangle) estimates, Jaccard similarity, and
//! cardinalities of arbitrary adjacency-set unions — the "more general
//! queries that can be phrased as unions and possibly an intersection of
//! adjacency sets" of the paper's conclusion.
//!
//! Two on-disk formats:
//!
//! * **Snapshot** (preferred) — a single mappable file; see
//!   [`crate::snapshot`] for the byte-level layout. `open`/`load` on a
//!   file path maps it and serves borrowed register views directly out of
//!   the file — O(1) startup (map + index validation, no per-sketch
//!   deserialization) and one shared page-cache copy across processes.
//! * **Legacy shard directory** — the PR-1 era layout, still readable
//!   (and migratable via [`QueryEngine::migrate_legacy`]):
//!   ```text
//!   meta.txt          p seed ranks partitioner-name
//!   shard_<r>.bin     u32 count, then count × (u64 vertex, HLL blob)
//!   ```
//!
//! Whichever way the engine was opened, queries run over borrowed
//! [`SketchRef`] views, so a mapped engine answers DEG / TRI / JACCARD /
//! UNION **bit-identically** to a heap-loaded one (property-tested in
//! `tests/snapshot.rs`).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::comm::CommStats;
use crate::hll::{
    mle_intersect_ref, view_of, Estimator, Hll, HllConfig,
    IntersectionEstimate, MleOptions, SketchRef,
};
use crate::snapshot::{
    MappedSnapshot, SnapshotMode, SnapshotStats, SnapshotWriter,
};

use super::partition::Partitioner;
use super::sketch::{DegreeSketch, Shard};

/// What backs an engine: an owned in-heap `DegreeSketch` or a mapped
/// snapshot file.
enum EngineData {
    Heap(DegreeSketch),
    Mapped(MappedSnapshot),
}

/// A loaded (or freshly accumulated) DegreeSketch plus query methods.
pub struct QueryEngine {
    data: EngineData,
    mle: MleOptions,
    estimator: Estimator,
    /// Where a snapshot-backed engine was opened from (path + backing
    /// mode) — what the serving tier's `RELOAD` verb reopens to flip to
    /// the next snapshot generation. `None` for heap engines and for
    /// snapshots wrapped without a path.
    origin: Option<(std::path::PathBuf, SnapshotMode)>,
}

impl QueryEngine {
    pub fn new(ds: DegreeSketch) -> Self {
        Self {
            data: EngineData::Heap(ds),
            mle: MleOptions::default(),
            estimator: Estimator::default(),
            origin: None,
        }
    }

    /// Wrap an already-opened snapshot.
    pub fn from_snapshot(snap: MappedSnapshot) -> Self {
        Self {
            data: EngineData::Mapped(snap),
            mle: MleOptions::default(),
            estimator: Estimator::default(),
            origin: None,
        }
    }

    /// The snapshot path + mode this engine can be reopened from, when
    /// it was opened via [`QueryEngine::open_snapshot`]/`load`.
    pub fn reload_origin(&self) -> Option<(&Path, SnapshotMode)> {
        self.origin.as_ref().map(|(p, m)| (p.as_path(), *m))
    }

    /// Reopen the origin snapshot as a fresh engine — the `RELOAD`
    /// primitive. The current engine keeps serving untouched; on error
    /// (e.g. a half-written file) nothing changes.
    pub fn reopen(&self) -> Result<Self> {
        let Some((path, mode)) = self.reload_origin() else {
            bail!(
                "engine has no reload origin (heap-accumulated or wrapped \
                 without a path); RELOAD needs a snapshot-served engine"
            );
        };
        Self::open_snapshot_with(path, mode)
    }

    /// The heap-resident sketch, when this engine owns one (`None` for
    /// mapped engines, which serve straight from the file).
    pub fn sketch_data(&self) -> Option<&DegreeSketch> {
        match &self.data {
            EngineData::Heap(ds) => Some(ds),
            EngineData::Mapped(_) => None,
        }
    }

    /// The mapped snapshot, when this engine serves from one.
    pub fn snapshot(&self) -> Option<&MappedSnapshot> {
        match &self.data {
            EngineData::Mapped(s) => Some(s),
            EngineData::Heap(_) => None,
        }
    }

    /// Borrowed register view of `v`'s adjacency sketch.
    pub fn view(&self, v: u64) -> Option<SketchRef<'_>> {
        match &self.data {
            EngineData::Heap(ds) => ds.sketch(v).map(view_of),
            EngineData::Mapped(snap) => snap.get(v),
        }
    }

    pub fn num_vertices(&self) -> usize {
        match &self.data {
            EngineData::Heap(ds) => ds.num_vertices(),
            EngineData::Mapped(snap) => snap.num_vertices(),
        }
    }

    pub fn num_ranks(&self) -> usize {
        match &self.data {
            EngineData::Heap(ds) => ds.num_ranks(),
            EngineData::Mapped(snap) => snap.num_ranks(),
        }
    }

    pub fn config(&self) -> &HllConfig {
        match &self.data {
            EngineData::Heap(ds) => ds.config(),
            EngineData::Mapped(snap) => snap.config(),
        }
    }

    pub fn num_dense_sketches(&self) -> usize {
        match &self.data {
            EngineData::Heap(ds) => ds.num_dense_sketches(),
            EngineData::Mapped(snap) => snap.num_dense_sketches(),
        }
    }

    /// `"heap"` or `"mmap"` — how the sketches are backed (surfaced by
    /// the server's `STATS` so operators can confirm page-cache sharing).
    pub fn backing_mode(&self) -> &'static str {
        match &self.data {
            EngineData::Heap(_) => "heap",
            EngineData::Mapped(snap) => snap.mode(),
        }
    }

    /// Comm statistics of the epoch that accumulated this engine's
    /// sketch, when it was accumulated in this process: comm backend
    /// (`sequential`/`threaded`/`process`) plus per-rank message, byte
    /// and flush counts. `None` for mapped or disk-loaded engines, whose
    /// accumulation happened elsewhere.
    pub fn accumulation_stats(&self) -> Option<&CommStats> {
        match &self.data {
            // a real epoch always records per-rank counters (one entry
            // per rank, even for an empty stream); disk-load paths leave
            // the default stats with an empty per_rank vector
            EngineData::Heap(ds)
                if !ds.accumulation_stats.per_rank.is_empty() =>
            {
                Some(&ds.accumulation_stats)
            }
            _ => None,
        }
    }

    /// Private heap bytes holding sketch data. Mapped engines report 0 —
    /// their registers live in the (shared, demand-paged) file mapping,
    /// which is what makes N processes on one snapshot cheap.
    pub fn heap_bytes(&self) -> usize {
        match &self.data {
            EngineData::Heap(ds) => ds.memory_bytes(),
            EngineData::Mapped(_) => 0,
        }
    }

    /// Bytes of the mapped snapshot backing (0 for heap engines). Shared
    /// address space, not private heap.
    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            EngineData::Heap(_) => 0,
            EngineData::Mapped(snap) => snap.resident_bytes(),
        }
    }

    /// `|D[x]|` — degree estimate (None if x never appeared).
    pub fn degree(&self, x: u64) -> Option<f64> {
        self.view(x).map(|s| s.estimate_with(self.estimator))
    }

    /// `|D̃[x] ∩ D̃[y]|` — edge-local triangle estimate for any vertex pair
    /// (Eq. 10); also reports the union and domination status.
    pub fn intersection(&self, x: u64, y: u64) -> Option<IntersectionEstimate> {
        let a = self.view(x)?;
        let b = self.view(y)?;
        Some(mle_intersect_ref(a, b, &self.mle))
    }

    /// Jaccard similarity of two adjacency sets — the paper's triangle
    /// density (Figure 3).
    pub fn jaccard(&self, x: u64, y: u64) -> Option<f64> {
        self.intersection(x, y).map(|e| e.jaccard())
    }

    /// `|∪̃_i D[x_i]|` — cardinality of a union of adjacency sets, e.g.
    /// "how many distinct accounts are adjacent to this suspect set?".
    pub fn union_cardinality(&self, xs: &[u64]) -> Option<f64> {
        let mut it = xs.iter().filter_map(|&x| self.view(x));
        let first = it.next()?;
        let mut acc = first.to_hll();
        for s in it {
            acc.merge_view(s);
        }
        Some(acc.estimate_with(self.estimator))
    }

    /// Persist in the legacy shard-directory format (created if needed).
    /// Mapped engines are already persistent — copy the file instead.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let EngineData::Heap(ds) = &self.data else {
            bail!(
                "engine is snapshot-backed; the snapshot file IS the \
                 persistent form (copy it, or accumulate anew to re-save)"
            );
        };
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let meta = format!(
            "{} {} {} {}\n",
            ds.config().p(),
            ds.config().hasher().seed(),
            ds.num_ranks(),
            ds.partitioner().name(),
        );
        std::fs::write(dir.join("meta.txt"), meta)?;
        for (rank, shard) in ds.shards().iter().enumerate() {
            let f = File::create(dir.join(format!("shard_{rank}.bin")))?;
            let mut w = BufWriter::with_capacity(1 << 20, f);
            w.write_all(&(shard.len() as u32).to_le_bytes())?;
            // frozen shards already iterate in ascending vertex order, so
            // files are reproducible without re-sorting
            for (v, h) in shard.iter() {
                w.write_all(&v.to_le_bytes())?;
                h.write_to(&mut w)?;
            }
            w.flush()?;
        }
        Ok(())
    }

    /// Persist as a single-file snapshot (see [`crate::snapshot`]).
    pub fn save_snapshot(&self, path: &Path) -> Result<SnapshotStats> {
        let EngineData::Heap(ds) = &self.data else {
            bail!("engine is already snapshot-backed ({})", self.backing_mode());
        };
        SnapshotWriter::write(ds, path)
    }

    /// Load from either format: a file path opens as a mapped snapshot, a
    /// directory as a legacy shard directory.
    pub fn load(path: &Path) -> Result<Self> {
        if path.is_dir() {
            Self::load_legacy(path)
        } else {
            Self::open_snapshot(path)
        }
    }

    /// Map a snapshot file (`mmap` where available, heap fallback).
    pub fn open_snapshot(path: &Path) -> Result<Self> {
        Self::open_snapshot_with(path, SnapshotMode::Auto)
    }

    /// Map a snapshot file with an explicit backing mode. The path and
    /// mode are remembered as the engine's reload origin.
    pub fn open_snapshot_with(path: &Path, mode: SnapshotMode) -> Result<Self> {
        let mut engine =
            Self::from_snapshot(MappedSnapshot::open_with(path, mode)?);
        engine.origin = Some((path.to_path_buf(), mode));
        Ok(engine)
    }

    /// Convert a legacy shard directory into a snapshot file without
    /// re-accumulating — the migration helper for pre-snapshot saves.
    pub fn migrate_legacy(dir: &Path, out: &Path) -> Result<SnapshotStats> {
        let engine = Self::load_legacy(dir)?;
        engine.save_snapshot(out)
    }

    /// Load a legacy shard directory into a heap engine.
    pub fn load_legacy(dir: &Path) -> Result<Self> {
        let meta = std::fs::read_to_string(dir.join("meta.txt"))
            .with_context(|| format!("reading {}/meta.txt", dir.display()))?;
        let parts: Vec<&str> = meta.split_whitespace().collect();
        if parts.len() != 4 {
            bail!("malformed meta.txt: {meta:?}");
        }
        let p: u8 = parts[0].parse().context("bad p")?;
        let seed: u64 = parts[1].parse().context("bad seed")?;
        let ranks: usize = parts[2].parse().context("bad ranks")?;
        let partitioner = Partitioner::from_name(parts[3])
            .with_context(|| format!("bad partitioner {:?}", parts[3]))?;
        let config = HllConfig::new(p, seed);

        let mut shards: Vec<Shard> = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            let f = File::open(dir.join(format!("shard_{rank}.bin")))?;
            let mut r = BufReader::with_capacity(1 << 20, f);
            let mut count_buf = [0u8; 4];
            r.read_exact(&mut count_buf)?;
            let count = u32::from_le_bytes(count_buf) as usize;
            let mut entries: Vec<(u64, Hll)> = Vec::with_capacity(count);
            let mut prev: Option<u64> = None;
            for _ in 0..count {
                let mut vbuf = [0u8; 8];
                r.read_exact(&mut vbuf)?;
                let v = u64::from_le_bytes(vbuf);
                let h = Hll::read_from(&mut r)?;
                if h.config() != &config {
                    bail!("shard {rank}: sketch config mismatch for vertex {v}");
                }
                if partitioner.rank_of(v, ranks) != rank {
                    bail!("shard {rank}: vertex {v} stored on wrong rank");
                }
                if prev.is_some_and(|p| p >= v) {
                    bail!("shard {rank}: vertex ids not strictly increasing");
                }
                prev = Some(v);
                entries.push((v, h));
            }
            shards.push(Shard::from_sorted_entries(entries));
        }
        Ok(Self::new(DegreeSketch::from_parts(
            config,
            partitioner,
            shards,
            CommStats::default(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::gen::karate;
    use crate::graph::stream::MemoryStream;

    fn engine() -> QueryEngine {
        let stream = MemoryStream::new(karate::edges());
        let ds = accumulate_stream(
            &stream,
            3,
            HllConfig::new(12, 0xE0),
            AccumulateOptions::default(),
        );
        QueryEngine::new(ds)
    }

    #[test]
    fn degree_queries() {
        let e = engine();
        // vertex 33 (1-indexed 34) has degree 17
        let d = e.degree(33).unwrap();
        assert!((d - 17.0).abs() < 2.0, "{d}");
        assert_eq!(e.degree(999), None);
        assert_eq!(e.backing_mode(), "heap");
    }

    #[test]
    fn union_queries() {
        let e = engine();
        // union of the two hubs' adjacency covers most of the club
        let u = e.union_cardinality(&[0, 33]).unwrap();
        assert!(u > 25.0 && u < 40.0, "{u}");
        assert_eq!(e.union_cardinality(&[777]), None);
    }

    #[test]
    fn intersection_and_jaccard() {
        let e = engine();
        let est = e.intersection(0, 33).unwrap();
        assert!(est.intersection >= 0.0);
        let j = e.jaccard(0, 33).unwrap();
        assert!((0.0..=1.0).contains(&j));
    }

    #[test]
    fn save_load_round_trip() {
        let e = engine();
        let dir = std::env::temp_dir().join("degreesketch_engine_test");
        let _ = std::fs::remove_dir_all(&dir);
        e.save(&dir).unwrap();
        let loaded = QueryEngine::load(&dir).unwrap();
        let (a, b) = (
            loaded.sketch_data().unwrap(),
            e.sketch_data().unwrap(),
        );
        assert_eq!(a.num_vertices(), b.num_vertices());
        for (v, h) in b.iter() {
            assert_eq!(a.sketch(v), Some(h), "vertex {v}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshot_round_trip_is_query_identical() {
        let e = engine();
        let path = std::env::temp_dir().join("degreesketch_engine_test.snap");
        let _ = std::fs::remove_file(&path);
        let stats = e.save_snapshot(&path).unwrap();
        assert_eq!(stats.vertices as usize, e.num_vertices());
        let mapped = QueryEngine::load(&path).unwrap();
        assert!(mapped.sketch_data().is_none());
        assert_eq!(mapped.num_vertices(), e.num_vertices());
        assert_eq!(mapped.num_ranks(), e.num_ranks());
        for v in 0..40u64 {
            assert_eq!(
                mapped.degree(v).map(f64::to_bits),
                e.degree(v).map(f64::to_bits),
                "DEG {v}"
            );
        }
        let a = e.intersection(0, 33).unwrap();
        let b = mapped.intersection(0, 33).unwrap();
        assert_eq!(a.intersection.to_bits(), b.intersection.to_bits());
        assert_eq!(
            e.union_cardinality(&[0, 1, 33]).unwrap().to_bits(),
            mapped.union_cardinality(&[0, 1, 33]).unwrap().to_bits()
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn migrate_legacy_to_snapshot() {
        let e = engine();
        let dir = std::env::temp_dir().join("degreesketch_engine_migrate");
        let snap = std::env::temp_dir().join("degreesketch_engine_migrate.snap");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file(&snap);
        e.save(&dir).unwrap();
        let stats = QueryEngine::migrate_legacy(&dir, &snap).unwrap();
        assert_eq!(stats.vertices as usize, e.num_vertices());
        let mapped = QueryEngine::load(&snap).unwrap();
        for v in 0..34u64 {
            assert_eq!(
                mapped.degree(v).map(f64::to_bits),
                e.degree(v).map(f64::to_bits)
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_file(&snap).unwrap();
    }

    #[test]
    fn load_rejects_corruption() {
        let e = engine();
        let dir = std::env::temp_dir().join("degreesketch_engine_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        e.save(&dir).unwrap();
        std::fs::write(dir.join("meta.txt"), "lol").unwrap();
        assert!(QueryEngine::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
