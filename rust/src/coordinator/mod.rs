//! The DegreeSketch coordinator — the paper's system contribution.
//!
//! * [`partition`] — the vertex→processor mapping `f` (§2; round-robin as
//!   in the paper's experiments, plus a hashed alternative).
//! * [`sketch`] — the distributed `D` dictionary and **Algorithm 1**
//!   (single-pass accumulation).
//! * [`anf`] — **Algorithm 2**: local t-neighborhood estimation, the
//!   distributed HyperANF generalization.
//! * [`triangles`] — **Algorithms 3–5**: edge- and vertex-local triangle
//!   count heavy hitters via sketch intersection.
//! * [`heap`] — the bounded max-k heaps `H_k` and their REDUCE merge.
//! * [`engine`] — persistence + the "leave-behind queryable data
//!   structure": save/load an accumulated DegreeSketch and answer degree /
//!   intersection / union queries without touching σ again.
//! * [`serve`] — the query-serving tier over the engine: an event-driven
//!   reactor (one thread, every socket), request batching into the
//!   intersect kernels, a generation-tagged hot-vertex result cache,
//!   zero-downtime snapshot swaps (`RELOAD`), and the `loadgen` client
//!   fleet that benchmarks it all.
//! * [`server`] — compatibility shim re-exporting the serve tier's
//!   `QueryServer` under its historical path.
//!
//! Layering: [`sketch`]/[`anf`]/[`triangles`] *build* estimates over the
//! comm fabric; [`engine`] *persists* them; [`serve`] *answers* for them
//! at high QPS. Queries never touch the fabric — a served engine is
//! read-only and shared, so the serving tier scales with sockets and
//! cores, not ranks.

pub mod anf;
pub mod engine;
pub mod heap;
pub mod partition;
pub mod serve;
pub mod server;
pub mod sketch;
pub mod triangles;

pub use anf::{neighborhood_approximation, AnfResult};
pub use engine::QueryEngine;
pub use heap::TopK;
pub use partition::Partitioner;
pub use sketch::{accumulate, DegreeSketch};
pub use triangles::{
    edge_triangle_heavy_hitters, vertex_triangle_heavy_hitters,
    IntersectBackend, TriangleOptions, TriangleResult,
};

/// The standard tcp-worker dispatch: every coordinator actor kind a
/// fabric driver can send — Algorithm 1 accumulation (`deg-accum`),
/// Algorithm 2 ANF passes (`anf-pass`), and the Algorithm 3–5 triangle
/// chassis (`tri-chassis`). Hand it to [`crate::comm::tcp::run_worker`]
/// (the `degreesketch worker` subcommand does exactly this).
pub fn worker_dispatch() -> crate::comm::tcp::WorkerDispatch {
    let dispatch = crate::comm::tcp::WorkerDispatch::new();
    let dispatch = sketch::register_fabric(dispatch);
    let dispatch = anf::register_fabric(dispatch);
    triangles::register_fabric(dispatch)
}
