//! **Algorithms 3–5**: edge- and vertex-local triangle count heavy hitters.
//!
//! The shared chassis (Algorithm 3): every processor reads its substream
//! and forwards each edge `uv` as an EDGE message to `f(u)`. The owner
//! responds with a SKETCH message carrying `D[u]` to `f(v)`, which
//! estimates `T̃(uv) = |D̃[v] ∩ D̃[u]|` and updates its local counter `T̃`
//! plus either a top-k heap of edges (**Algorithm 4**) or the per-vertex
//! accumulators `T̃(x)` — forwarding an EST message to the other endpoint's
//! owner (**Algorithm 5**). Final REDUCEs merge heaps and sum `T̃/3`.
//!
//! Intersection estimation is pluggable ([`IntersectBackend`]): the native
//! joint-MLE, inclusion-exclusion (the paper's Figure 8 baseline), or a
//! *batched* executor (the PJRT path — pairs buffer per rank and flush
//! through the AOT-compiled artifact, with `on_idle` draining partial
//! batches at quiescence).
//!
//! Cross-rank SKETCH responses are batched per destination rank: the
//! owner buffers `(x, y)` forwards, groups them by `x` at flush, and
//! ships one FAN message (one `D[x]` clone) per group instead of one
//! SKETCH message per edge.

use std::collections::HashMap;
use std::sync::Arc;

use crate::comm::codec::{
    self, decode_hll, encode_hll_into, get_f64, get_u32, get_u64, get_u8,
    put_f64, put_u32, put_u64, put_u8,
};
use crate::comm::{
    run_epoch_wire_full, Actor, Backend, CommStats, FabricActor, FaultPolicy,
    FlushPolicy, Outbox, WireActor, WireError, WireMsg,
};
use crate::graph::stream::{EdgeStream, MemoryStream};
use crate::graph::{canonical, Edge, VertexId};
use crate::hll::{
    inclusion_exclusion, mle_intersect, Domination, Hll,
    IntersectionEstimate, MleOptions,
};

use super::heap::TopK;
use super::sketch::DegreeSketch;

/// A batched intersection executor (implemented by `runtime::PjrtIntersect`).
pub trait BatchIntersect: Send + Sync {
    /// Estimate |A∩B| (and friends) for each pair.
    fn intersect(&self, pairs: &[(Hll, Hll)]) -> Vec<IntersectionEstimate>;
}

/// Which estimator the triangle algorithms use per sketch pair.
#[derive(Clone)]
pub enum IntersectBackend {
    /// Native joint Poisson MLE (the default; mirrors the paper's §4.1).
    Mle(MleOptions),
    /// Inclusion-exclusion (Eq. 18) — the high-variance baseline.
    InclusionExclusion,
    /// Batched executor (PJRT artifact); `batch` pairs buffer per rank.
    Batched {
        batch: usize,
        exec: Arc<dyn BatchIntersect>,
    },
}

impl Default for IntersectBackend {
    fn default() -> Self {
        Self::Mle(MleOptions::default())
    }
}

impl std::fmt::Debug for IntersectBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Mle(o) => write!(f, "Mle({o:?})"),
            Self::InclusionExclusion => write!(f, "InclusionExclusion"),
            Self::Batched { batch, .. } => write!(f, "Batched({batch})"),
        }
    }
}

/// Options shared by Algorithms 4 and 5.
#[derive(Debug, Clone)]
pub struct TriangleOptions {
    pub backend: Backend,
    /// Heavy-hitter count k.
    pub k: usize,
    pub intersect: IntersectBackend,
    /// Appendix B mitigation: skip pairs where one sketch dominates the
    /// other (their estimates are unreliable). Off by default, as in the
    /// paper's main algorithms; the fig7 bench ablates it.
    pub discard_dominated: bool,
    /// Comm-plane flush policy (ignored by the sequential backend).
    pub flush: FlushPolicy,
    /// Fault-tolerance policy (socket backends): the chassis epoch is
    /// checkpointed and survives worker death. Default: off.
    pub fault: FaultPolicy,
}

impl Default for TriangleOptions {
    fn default() -> Self {
        Self {
            backend: Backend::Sequential,
            k: 100,
            intersect: IntersectBackend::default(),
            discard_dominated: false,
            flush: FlushPolicy::default(),
            fault: FaultPolicy::default(),
        }
    }
}

/// Output of Algorithms 4/5. `I` is the heavy-hitter identity: a canonical
/// edge for Algorithm 4, a vertex id for Algorithm 5.
#[derive(Debug, Clone)]
pub struct TriangleResult<I> {
    /// `T̃` — the global triangle count estimate (already divided by 3).
    pub global_estimate: f64,
    /// `H̃_k` — descending (estimate, item).
    pub heavy_hitters: Vec<(f64, I)>,
    /// Per-pair estimates count and Appendix-B domination tallies.
    pub pairs_estimated: u64,
    pub pairs_dominated: u64,
    pub comm: CommStats,
    /// Wall-clock of the estimation epoch (Figures 5/6).
    pub seconds: f64,
}

/// Cross-rank EDGE forwards buffered per destination before a FAN flush.
const TRI_FAN_BATCH: usize = 1024;

/// Algorithms 3–5's message alphabet (public so the comm-plane property
/// tests can round-trip it through the wire codec).
#[derive(Debug, Clone, PartialEq)]
pub enum TriMsg {
    /// (x, y) delivered to f(x).
    Edge(VertexId, VertexId),
    /// (D[x], x, targets) delivered to f(y). Sent only when f(y) is a
    /// remote rank — rank-local pairs borrow both sketches from the
    /// shared `D` without cloning into a message — and grouped by source:
    /// one carried sketch covers every pending pair (x, y) whose `y`
    /// lives on the destination rank.
    Fan(Hll, VertexId, Vec<VertexId>),
    /// (x, T̃(xy)) delivered to f(x) — Algorithm 5 only.
    Est(VertexId, f64),
}

const TRI_TAG_EDGE: u8 = 0;
const TRI_TAG_FAN: u8 = 1;
const TRI_TAG_EST: u8 = 2;

impl WireMsg for TriMsg {
    fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            TriMsg::Edge(x, y) => {
                put_u8(buf, TRI_TAG_EDGE);
                put_u64(buf, *x);
                put_u64(buf, *y);
            }
            TriMsg::Fan(sketch, x, targets) => {
                put_u8(buf, TRI_TAG_FAN);
                encode_hll_into(sketch, buf);
                put_u64(buf, *x);
                put_u32(buf, targets.len() as u32);
                for &t in targets {
                    put_u64(buf, t);
                }
            }
            TriMsg::Est(x, t_xy) => {
                put_u8(buf, TRI_TAG_EST);
                put_u64(buf, *x);
                put_f64(buf, *t_xy);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> Result<Self, WireError> {
        match get_u8(input)? {
            TRI_TAG_EDGE => {
                Ok(TriMsg::Edge(get_u64(input)?, get_u64(input)?))
            }
            TRI_TAG_FAN => {
                let sketch = decode_hll(input)?;
                let x = get_u64(input)?;
                let n = get_u32(input)? as usize;
                let mut targets = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    targets.push(get_u64(input)?);
                }
                Ok(TriMsg::Fan(sketch, x, targets))
            }
            TRI_TAG_EST => Ok(TriMsg::Est(get_u64(input)?, get_f64(input)?)),
            other => Err(WireError::Invalid(format!("bad TriMsg tag {other}"))),
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    EdgeHH,
    VertexHH,
}

struct TriActor {
    rank: usize,
    ranks: usize,
    mode: Mode,
    ds: Arc<DegreeSketch>,
    substream: MemoryStream,
    opts: TriangleOptions,
    // Alg 3 state
    tri_sum: f64,
    edge_heap: TopK<(VertexId, VertexId)>,
    vertex_counts: HashMap<VertexId, f64>,
    pairs_estimated: u64,
    pairs_dominated: u64,
    /// Deferred pairs for the batched backend: `(x, y, D[x])`, where the
    /// sketch is `None` for rank-local pairs (fetched from `D` at flush).
    pending: Vec<(VertexId, VertexId, Option<Hll>)>,
    /// Per-destination-rank buffers of pending cross-rank `(x, y)` edges,
    /// flushed as per-source FAN messages.
    fwd: Vec<Vec<(VertexId, VertexId)>>,
}

impl TriActor {
    fn estimate_now(&self, a: &Hll, b: &Hll) -> IntersectionEstimate {
        match &self.opts.intersect {
            IntersectBackend::Mle(o) => mle_intersect(a, b, o),
            IntersectBackend::InclusionExclusion => inclusion_exclusion(a, b),
            IntersectBackend::Batched { .. } => unreachable!("batched path"),
        }
    }

    /// Record T̃(xy) (and route EST for Algorithm 5).
    fn record(
        &mut self,
        x: VertexId,
        y: VertexId,
        est: IntersectionEstimate,
        out: &mut Outbox<TriMsg>,
    ) {
        self.pairs_estimated += 1;
        if est.domination != Domination::None {
            self.pairs_dominated += 1;
            if self.opts.discard_dominated {
                return;
            }
        }
        let t_xy = est.intersection;
        self.tri_sum += t_xy;
        match self.mode {
            Mode::EdgeHH => {
                self.edge_heap.insert(t_xy, canonical((x, y)));
            }
            Mode::VertexHH => {
                *self.vertex_counts.entry(y).or_insert(0.0) += t_xy;
                out.send(
                    self.ds.partitioner().rank_of(x, self.ranks),
                    TriMsg::Est(x, t_xy),
                );
            }
        }
    }

    /// Buffer a pair for the batched backend, flushing at the batch size.
    fn push_pending(
        &mut self,
        x: VertexId,
        y: VertexId,
        skx: Option<Hll>,
        out: &mut Outbox<TriMsg>,
    ) {
        self.pending.push((x, y, skx));
        let IntersectBackend::Batched { batch, .. } = &self.opts.intersect
        else {
            unreachable!()
        };
        if self.pending.len() >= *batch {
            self.flush_pending(out);
        }
    }

    /// Flush one destination's cross-rank edge buffer: group by source
    /// vertex and emit one FAN (one `D[x]` clone) per group.
    fn flush_fwd(&mut self, dst: usize, out: &mut Outbox<TriMsg>) {
        let mut buf = std::mem::take(&mut self.fwd[dst]);
        if buf.is_empty() {
            return;
        }
        buf.sort_unstable();
        let mut i = 0;
        while i < buf.len() {
            let x = buf[i].0;
            let mut targets = Vec::new();
            while i < buf.len() && buf[i].0 == x {
                targets.push(buf[i].1);
                i += 1;
            }
            let skx = self
                .ds
                .sketch(x)
                .expect("buffered forwards only for present sketches")
                .clone();
            out.send(dst, TriMsg::Fan(skx, x, targets));
        }
        buf.clear();
        self.fwd[dst] = buf;
    }

    fn flush_pending(&mut self, out: &mut Outbox<TriMsg>) {
        if self.pending.is_empty() {
            return;
        }
        let IntersectBackend::Batched { exec, .. } = &self.opts.intersect
        else {
            unreachable!()
        };
        let exec = Arc::clone(exec);
        let pending = std::mem::take(&mut self.pending);
        // assemble (D[y], D[x]) pairs; y's sketch is rank-local, and so is
        // x's when the deferred entry carries no sketch
        let pairs: Vec<(Hll, Hll)> = pending
            .iter()
            .map(|(x, y, skx)| {
                let sky = self
                    .ds
                    .sketch(*y)
                    .expect("endpoint with an edge must have a sketch")
                    .clone();
                let skx = match skx {
                    Some(s) => s.clone(),
                    None => self
                        .ds
                        .sketch(*x)
                        .expect("rank-local pair sketch present")
                        .clone(),
                };
                (sky, skx)
            })
            .collect();
        let results = exec.intersect(&pairs);
        assert_eq!(results.len(), pending.len());
        for ((x, y, _), est) in pending.into_iter().zip(results) {
            self.record(x, y, est, out);
        }
    }
}

impl Actor for TriActor {
    type Msg = TriMsg;

    fn seed(&mut self, out: &mut Outbox<TriMsg>) {
        // Algorithm 3: forward each stream edge to f(u).
        let ranks = self.ranks;
        let part = self.ds.partitioner();
        self.substream.for_each(&mut |(u, v)| {
            if u == v {
                return;
            }
            out.send(part.rank_of(u, ranks), TriMsg::Edge(u, v));
        });
    }

    fn on_message(&mut self, msg: TriMsg, out: &mut Outbox<TriMsg>) {
        match msg {
            TriMsg::Edge(x, y) => {
                let dst = self.ds.partitioner().rank_of(y, self.ranks);
                let Some(skx) = self.ds.sketch(x) else {
                    return;
                };
                if dst == self.rank {
                    // both sketches live in the local shard of the shared
                    // `D`: estimate from borrowed views, no clone, no
                    // SKETCH round trip
                    if matches!(
                        self.opts.intersect,
                        IntersectBackend::Batched { .. }
                    ) {
                        self.push_pending(x, y, None, out);
                    } else if let Some(sky) = self.ds.sketch(y) {
                        let est = self.estimate_now(sky, skx);
                        self.record(x, y, est, out);
                    }
                } else {
                    // cross-rank: buffer and fan D[x] to f(y) in groups
                    self.fwd[dst].push((x, y));
                    if self.fwd[dst].len() >= TRI_FAN_BATCH {
                        self.flush_fwd(dst, out);
                    }
                }
            }
            TriMsg::Fan(skx, x, targets) => {
                let batched = matches!(
                    self.opts.intersect,
                    IntersectBackend::Batched { .. }
                );
                let last = targets.len().saturating_sub(1);
                // move the carried sketch into the final pending entry so
                // the batched path clones N-1 times for N targets (clone
                // count per pair stays at parity with the unfanned path)
                let mut skx = Some(skx);
                for (i, y) in targets.into_iter().enumerate() {
                    if batched {
                        let sk = if i == last {
                            skx.take().expect("fan sketch moved once")
                        } else {
                            skx.as_ref().expect("fan sketch present").clone()
                        };
                        self.push_pending(x, y, Some(sk), out);
                    } else if let Some(sky) = self.ds.sketch(y) {
                        let sk = skx.as_ref().expect("fan sketch present");
                        let est = self.estimate_now(sky, sk);
                        self.record(x, y, est, out);
                    }
                }
            }
            TriMsg::Est(x, t_xy) => {
                *self.vertex_counts.entry(x).or_insert(0.0) += t_xy;
            }
        }
    }

    fn on_idle(&mut self, out: &mut Outbox<TriMsg>) {
        for dst in 0..self.ranks {
            self.flush_fwd(dst, out);
        }
        if matches!(self.opts.intersect, IntersectBackend::Batched { .. }) {
            self.flush_pending(out);
        }
    }

    fn heat_vertex(msg: &TriMsg) -> Option<u64> {
        match msg {
            // EDGE and EST route on f(x)
            TriMsg::Edge(x, _) | TriMsg::Est(x, _) => Some(*x),
            // a FAN's targets share one destination rank; the first
            // target names the range
            TriMsg::Fan(_, _, targets) => targets.first().copied(),
        }
    }
}

impl WireActor for TriActor {
    fn write_state(&self, buf: &mut Vec<u8>) {
        // on_idle drained every deferred buffer before Stop
        debug_assert!(self.pending.is_empty());
        debug_assert!(self.fwd.iter().all(Vec::is_empty));
        put_f64(buf, self.tri_sum);
        put_u64(buf, self.pairs_estimated);
        put_u64(buf, self.pairs_dominated);
        let heap = self.edge_heap.clone().into_sorted_vec();
        put_u32(buf, heap.len() as u32);
        for (score, (u, v)) in heap {
            put_f64(buf, score);
            put_u64(buf, u);
            put_u64(buf, v);
        }
        let mut counts: Vec<(VertexId, f64)> = self
            .vertex_counts
            .iter()
            .map(|(&v, &c)| (v, c))
            .collect();
        counts.sort_unstable_by_key(|&(v, _)| v);
        put_u32(buf, counts.len() as u32);
        for (v, c) in counts {
            put_u64(buf, v);
            put_f64(buf, c);
        }
    }

    fn read_state(&mut self, input: &mut &[u8]) -> Result<(), WireError> {
        self.tri_sum = get_f64(input)?;
        self.pairs_estimated = get_u64(input)?;
        self.pairs_dominated = get_u64(input)?;
        let n = get_u32(input)? as usize;
        let mut heap = TopK::new(self.opts.k);
        for _ in 0..n {
            let score = get_f64(input)?;
            let u = get_u64(input)?;
            let v = get_u64(input)?;
            heap.insert(score, (u, v));
        }
        self.edge_heap = heap;
        let m = get_u32(input)? as usize;
        let mut counts = HashMap::with_capacity(m.min(1 << 20));
        for _ in 0..m {
            let v = get_u64(input)?;
            counts.insert(v, get_f64(input)?);
        }
        self.vertex_counts = counts;
        // read_state must land the actor exactly in the written state:
        // a checkpoint rollback applies it to a mid-epoch actor whose
        // deferred buffers may hold post-barrier work
        self.pending.clear();
        for buf in &mut self.fwd {
            buf.clear();
        }
        Ok(())
    }
}

/// seed_state leg: a triangle epoch's inputs are the chassis context
/// (mode, k, intersect estimator, discard flag), the partition/config,
/// **this rank's shard of `D`** (the only shard the chassis ever reads
/// locally — EDGE arrives at `f(x)`, FAN targets live at `f(y)`), and
/// the rank's substream. The batched (PJRT) estimator holds a live
/// service handle and cannot cross a process boundary; `run_chassis`
/// rejects that combination up front.
impl FabricActor for TriActor {
    const KIND: &'static str = "tri-chassis";

    fn write_seed(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.rank as u64);
        put_u64(buf, self.ranks as u64);
        put_u8(buf, matches!(self.mode, Mode::VertexHH) as u8);
        put_u64(buf, self.opts.k as u64);
        put_u8(buf, u8::from(self.opts.discard_dominated));
        match &self.opts.intersect {
            IntersectBackend::Mle(o) => {
                put_u8(buf, 0);
                put_u64(buf, o.iterations as u64);
                put_f64(buf, o.lr_initial);
                put_f64(buf, o.lr_final);
                put_f64(buf, o.tolerance);
            }
            IntersectBackend::InclusionExclusion => put_u8(buf, 1),
            IntersectBackend::Batched { .. } => unreachable!(
                "run_chassis rejects batched intersect on socket backends"
            ),
        }
        self.ds.partitioner().encode_into(buf);
        codec::encode_config_into(self.ds.config(), buf);
        let shard = &self.ds.shards()[self.rank];
        put_u64(buf, shard.len() as u64);
        for (v, h) in shard.iter() {
            put_u64(buf, v);
            encode_hll_into(h, buf);
        }
        codec::encode_edges_into(self.substream.edges(), buf);
    }

    fn read_seed(input: &mut &[u8]) -> Result<Self, WireError> {
        let rank = get_u64(input)? as usize;
        let ranks = get_u64(input)? as usize;
        if ranks == 0 || rank >= ranks {
            return Err(WireError::Invalid(format!(
                "seed rank {rank} outside 0..{ranks}"
            )));
        }
        let mode = if get_u8(input)? != 0 {
            Mode::VertexHH
        } else {
            Mode::EdgeHH
        };
        let k = get_u64(input)? as usize;
        let discard_dominated = get_u8(input)? != 0;
        let intersect = match get_u8(input)? {
            0 => IntersectBackend::Mle(MleOptions {
                iterations: get_u64(input)? as usize,
                lr_initial: get_f64(input)?,
                lr_final: get_f64(input)?,
                tolerance: get_f64(input)?,
            }),
            1 => IntersectBackend::InclusionExclusion,
            other => {
                return Err(WireError::Invalid(format!(
                    "bad intersect tag {other}"
                )))
            }
        };
        let partitioner = super::Partitioner::decode(input)?;
        let config = codec::decode_config(input)?;
        let n = get_u64(input)? as usize;
        let mut entries: Vec<(VertexId, Hll)> =
            Vec::with_capacity(n.min(1 << 20));
        let mut prev: Option<VertexId> = None;
        for _ in 0..n {
            let v = get_u64(input)?;
            if prev.is_some_and(|p| p >= v) {
                return Err(WireError::Invalid(
                    "shard vertices not strictly increasing".into(),
                ));
            }
            prev = Some(v);
            let h = decode_hll(input)?;
            if h.config() != &config {
                return Err(WireError::Invalid(format!(
                    "shard sketch config mismatch for vertex {v}"
                )));
            }
            entries.push((v, h));
        }
        let edges = codec::decode_edges(input)?;
        // Rebuild a DegreeSketch holding only this rank's shard — the
        // only one the chassis reads (see the impl docs above).
        let mut shards = vec![super::sketch::Shard::default(); ranks];
        shards[rank] = super::sketch::Shard::from_sorted_entries(entries);
        let ds = Arc::new(DegreeSketch::from_parts(
            config,
            partitioner,
            shards,
            CommStats::default(),
        ));
        Ok(Self {
            rank,
            ranks,
            mode,
            ds,
            substream: MemoryStream::new(edges),
            opts: TriangleOptions {
                // the worker's comm backend/flush/fault policies come
                // from the SEED head, not from TriangleOptions; only the
                // chassis knobs matter here
                backend: Backend::Sequential,
                k,
                intersect,
                discard_dominated,
                flush: FlushPolicy::default(),
                fault: FaultPolicy::default(),
            },
            tri_sum: 0.0,
            edge_heap: TopK::new(k),
            vertex_counts: HashMap::new(),
            pairs_estimated: 0,
            pairs_dominated: 0,
            pending: Vec::new(),
            fwd: vec![Vec::new(); ranks],
        })
    }

    fn input_len(&self) -> usize {
        self.substream.edges().len()
    }

    fn seed_range(
        &mut self,
        start: usize,
        end: usize,
        out: &mut Outbox<TriMsg>,
    ) {
        let ranks = self.ranks;
        let part = self.ds.partitioner();
        for &(u, v) in &self.substream.edges()[start..end] {
            if u == v {
                continue;
            }
            out.send(part.rank_of(u, ranks), TriMsg::Edge(u, v));
        }
    }
}

/// Register Algorithms 3–5's actor kind on a tcp worker dispatch.
pub(crate) fn register_fabric(
    dispatch: crate::comm::tcp::WorkerDispatch,
) -> crate::comm::tcp::WorkerDispatch {
    dispatch.register::<TriActor>()
}

fn run_chassis(
    ds: &Arc<DegreeSketch>,
    substreams: &[MemoryStream],
    opts: &TriangleOptions,
    mode: Mode,
) -> (Vec<TriActor>, CommStats, f64) {
    assert_eq!(substreams.len(), ds.num_ranks());
    assert!(
        !(matches!(opts.backend, Backend::Process | Backend::Tcp)
            && matches!(opts.intersect, IntersectBackend::Batched { .. })),
        "a batched intersect executor (PJRT service) cannot be shared \
         across worker processes; use the mle/ix backends with the \
         process/tcp backends"
    );
    let start = std::time::Instant::now();
    let mut actors: Vec<TriActor> = substreams
        .iter()
        .cloned()
        .enumerate()
        .map(|(rank, substream)| TriActor {
            rank,
            ranks: ds.num_ranks(),
            mode,
            ds: Arc::clone(ds),
            substream,
            opts: opts.clone(),
            tri_sum: 0.0,
            edge_heap: TopK::new(opts.k),
            vertex_counts: HashMap::new(),
            pairs_estimated: 0,
            pairs_dominated: 0,
            pending: Vec::new(),
            fwd: vec![Vec::new(); ds.num_ranks()],
        })
        .collect();
    let comm = run_epoch_wire_full(
        opts.backend,
        &mut actors,
        opts.flush,
        &[],
        opts.fault,
    );
    let seconds = start.elapsed().as_secs_f64();
    (actors, comm, seconds)
}

/// **Algorithm 4**: top-k edge-local triangle count heavy hitters.
pub fn edge_triangle_heavy_hitters(
    ds: &Arc<DegreeSketch>,
    substreams: &[MemoryStream],
    opts: &TriangleOptions,
) -> TriangleResult<Edge> {
    let (actors, comm, seconds) = run_chassis(ds, substreams, opts, Mode::EdgeHH);
    // REDUCE: global T̃ and the global max-k heap.
    let mut heap = TopK::new(opts.k);
    let mut tri = 0.0;
    let mut pairs_estimated = 0;
    let mut pairs_dominated = 0;
    for a in &actors {
        heap.merge(&a.edge_heap);
        tri += a.tri_sum;
        pairs_estimated += a.pairs_estimated;
        pairs_dominated += a.pairs_dominated;
    }
    TriangleResult {
        global_estimate: tri / 3.0,
        heavy_hitters: heap.into_sorted_vec(),
        pairs_estimated,
        pairs_dominated,
        comm,
        seconds,
    }
}

/// **Algorithm 5**: top-k vertex-local triangle count heavy hitters.
/// Reported counts are `T̃(x) = ½ Σ_{xy} T̃(xy)` (Eq. 12).
pub fn vertex_triangle_heavy_hitters(
    ds: &Arc<DegreeSketch>,
    substreams: &[MemoryStream],
    opts: &TriangleOptions,
) -> TriangleResult<VertexId> {
    let (actors, comm, seconds) =
        run_chassis(ds, substreams, opts, Mode::VertexHH);
    let mut heap = TopK::new(opts.k);
    let mut tri = 0.0;
    let mut pairs_estimated = 0;
    let mut pairs_dominated = 0;
    for a in &actors {
        for (&v, &t2) in &a.vertex_counts {
            heap.insert(t2 / 2.0, v);
        }
        tri += a.tri_sum;
        pairs_estimated += a.pairs_estimated;
        pairs_dominated += a.pairs_dominated;
    }
    TriangleResult {
        global_estimate: tri / 3.0,
        heavy_hitters: heap.into_sorted_vec(),
        pairs_estimated,
        pairs_dominated,
        comm,
        seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::csr::Csr;
    use crate::graph::exact;
    use crate::graph::gen::{karate, GraphSpec};
    use crate::hll::HllConfig;

    fn setup(
        edges: &[Edge],
        ranks: usize,
        p: u8,
        backend: Backend,
    ) -> (Arc<DegreeSketch>, Vec<MemoryStream>) {
        let stream = MemoryStream::new(edges.to_vec());
        let ds = accumulate_stream(
            &stream,
            ranks,
            HllConfig::new(p, 0x7121),
            AccumulateOptions {
                backend,
                ..Default::default()
            },
        );
        (Arc::new(ds), stream.shard(ranks))
    }

    #[test]
    fn vertex_counts_cover_both_endpoints() {
        // Every stream edge must contribute to BOTH endpoint accumulators
        // (direct at f(y), EST at f(x)): total vertex mass = 2·edge mass.
        let edges = karate::edges();
        let (ds, shards) = setup(&edges, 3, 12, Backend::Sequential);
        let (actors, _, _) = run_chassis(
            &ds,
            &shards,
            &TriangleOptions::default(),
            Mode::VertexHH,
        );
        let vertex_mass: f64 = actors
            .iter()
            .flat_map(|a| a.vertex_counts.values())
            .sum();
        let edge_mass: f64 = actors.iter().map(|a| a.tri_sum).sum();
        assert!((vertex_mass - 2.0 * edge_mass).abs() < 1e-6);
    }

    #[test]
    fn karate_edge_heavy_hitters_mostly_real() {
        let edges = karate::edges();
        let csr = Csr::from_edges(&edges);
        let truth: HashMap<Edge, usize> = exact::edge_triangles(&csr)
            .into_iter()
            .map(|(u, v, c)| {
                ((csr.original_id(u).min(csr.original_id(v)),
                  csr.original_id(u).max(csr.original_id(v))), c)
            })
            .collect();
        let (ds, shards) = setup(&edges, 4, 12, Backend::Sequential);
        let opts = TriangleOptions {
            k: 10,
            ..Default::default()
        };
        let res = edge_triangle_heavy_hitters(&ds, &shards, &opts);
        assert_eq!(res.pairs_estimated, edges.len() as u64);
        // top-10 returned edges should mostly have high true counts
        let mut true_counts: Vec<usize> = truth.values().copied().collect();
        true_counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10_floor = true_counts[9];
        let hits = res
            .heavy_hitters
            .iter()
            .filter(|(_, e)| truth[e] >= top10_floor.saturating_sub(1))
            .count();
        assert!(hits >= 5, "only {hits} of top-10 are near-true HHs");
        // global estimate in the right ballpark (45 triangles)
        assert!(
            res.global_estimate > 15.0 && res.global_estimate < 135.0,
            "global {}",
            res.global_estimate
        );
    }

    #[test]
    fn karate_vertex_heavy_hitters_find_hubs() {
        let edges = karate::edges();
        let csr = Csr::from_edges(&edges);
        let vt = exact::vertex_triangles(&csr);
        let (ds, shards) = setup(&edges, 4, 12, Backend::Sequential);
        let opts = TriangleOptions {
            k: 5,
            ..Default::default()
        };
        let res = vertex_triangle_heavy_hitters(&ds, &shards, &opts);
        // true top-5 vertices by triangle count
        let mut ranked: Vec<(usize, u32)> = vt
            .iter()
            .enumerate()
            .map(|(v, &t)| (t, v as u32))
            .collect();
        ranked.sort_unstable_by(|a, b| b.cmp(a));
        let true_top: std::collections::HashSet<u64> = ranked[..5]
            .iter()
            .map(|&(_, v)| csr.original_id(v))
            .collect();
        let found = res
            .heavy_hitters
            .iter()
            .filter(|(_, v)| true_top.contains(v))
            .count();
        assert!(found >= 3, "found only {found} of the true top-5");
    }

    #[test]
    fn backends_agree_on_global_estimate() {
        let edges = GraphSpec::parse("ws:200:6:5").unwrap().generate(2);
        let (ds_a, sh_a) = setup(&edges, 3, 10, Backend::Sequential);
        let (ds_b, sh_b) = setup(&edges, 3, 10, Backend::Threaded);
        let (ds_c, sh_c) = setup(&edges, 3, 10, Backend::Process);
        let mk = |backend| TriangleOptions {
            backend,
            k: 20,
            ..Default::default()
        };
        let a = edge_triangle_heavy_hitters(&ds_a, &sh_a, &mk(Backend::Sequential));
        let b = edge_triangle_heavy_hitters(&ds_b, &sh_b, &mk(Backend::Threaded));
        let c = edge_triangle_heavy_hitters(&ds_c, &sh_c, &mk(Backend::Process));
        assert!((a.global_estimate - b.global_estimate).abs() < 1e-9);
        assert!((a.global_estimate - c.global_estimate).abs() < 1e-9);
        assert_eq!(a.heavy_hitters.len(), b.heavy_hitters.len());
        assert_eq!(a.heavy_hitters.len(), c.heavy_hitters.len());
        // same estimates per returned edge (identical sketches both ways)
        let to_map = |r: &TriangleResult<Edge>| -> HashMap<Edge, u64> {
            r.heavy_hitters
                .iter()
                .map(|&(s, e)| (e, s.to_bits()))
                .collect()
        };
        assert_eq!(to_map(&a), to_map(&b));
        assert_eq!(to_map(&a), to_map(&c));
    }

    #[test]
    fn batched_backend_matches_inline_mle() {
        struct NativeBatch;
        impl BatchIntersect for NativeBatch {
            fn intersect(&self, pairs: &[(Hll, Hll)]) -> Vec<IntersectionEstimate> {
                pairs
                    .iter()
                    .map(|(a, b)| mle_intersect(a, b, &MleOptions::default()))
                    .collect()
            }
        }
        let edges = karate::edges();
        let (ds, shards) = setup(&edges, 2, 10, Backend::Sequential);
        let inline = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                k: 10,
                ..Default::default()
            },
        );
        let batched = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                k: 10,
                intersect: IntersectBackend::Batched {
                    batch: 7, // deliberately not a divisor: exercises on_idle
                    exec: Arc::new(NativeBatch),
                },
                ..Default::default()
            },
        );
        assert!(
            (inline.global_estimate - batched.global_estimate).abs() < 1e-9
        );
        assert_eq!(inline.pairs_estimated, batched.pairs_estimated);
    }

    #[test]
    fn fan_batching_reduces_sketch_traffic() {
        // per-(destination, source) grouping must beat one-SKETCH-per-edge
        let edges = GraphSpec::parse("ba:400:6").unwrap().generate(4);
        let m = edges.len() as u64;
        let (ds, shards) = setup(&edges, 4, 8, Backend::Sequential);
        let res = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                k: 10,
                ..Default::default()
            },
        );
        assert_eq!(res.pairs_estimated, m);
        // m EDGE seeds + grouped FANs (≤ |V|·(ranks-1)); the old path sent
        // ~0.75·m extra SKETCH messages on 4 ranks
        assert!(
            res.comm.messages < 2 * m,
            "fan batching regressed: {} messages for m={m}",
            res.comm.messages
        );
        assert!(res.comm.messages > m, "cross-rank fans must still flow");
    }

    #[test]
    fn inclusion_exclusion_backend_runs() {
        let edges = karate::edges();
        let (ds, shards) = setup(&edges, 2, 12, Backend::Sequential);
        let res = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                k: 10,
                intersect: IntersectBackend::InclusionExclusion,
                ..Default::default()
            },
        );
        assert_eq!(res.pairs_estimated, edges.len() as u64);
        assert!(res.global_estimate >= 0.0);
    }

    #[test]
    fn discard_dominated_reduces_pairs() {
        // Huge hub vs degree-1 leaves: D[0] has ~50k inserts so every
        // register sits near log2(50k/256) ≈ 7.6, while each leaf sketch
        // has a single small register — the hub (register-wise) dominates
        // almost every leaf (Appendix B's |A| >> |B| regime).
        let edges: Vec<Edge> = (1..8_000u64).map(|v| (0, v)).collect();
        let (ds, shards) = setup(&edges, 2, 8, Backend::Sequential);
        let res = edge_triangle_heavy_hitters(
            &ds,
            &shards,
            &TriangleOptions {
                k: 10,
                discard_dominated: true,
                ..Default::default()
            },
        );
        assert!(
            res.pairs_dominated > 0,
            "star graph must produce dominations"
        );
    }
}
