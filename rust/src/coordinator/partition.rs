//! The vertex partition `f : V → P` (paper §2).
//!
//! The paper treats partitioning as an external concern ("our algorithms
//! are designed to work alongside any reasonable f") and uses simple
//! round-robin assignment in its experiments (§5 "Hardware"). We provide
//! that plus a seeded hash partition for skew resistance.

use crate::comm::codec::{get_u64, get_u8, put_u64, put_u8};
use crate::comm::{WireError, WireMsg};
use crate::hash::xxh64_u64;

/// A cheap, cloneable vertex→rank mapping shared by every processor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// `f(v) = v mod |P|` — the paper's experimental choice.
    RoundRobin,
    /// `f(v) = xxh64(v, seed) mod |P|` — destroys id-locality skew.
    Hashed { seed: u64 },
}

impl Default for Partitioner {
    fn default() -> Self {
        Self::RoundRobin
    }
}

impl Partitioner {
    #[inline]
    pub fn rank_of(&self, v: u64, ranks: usize) -> usize {
        debug_assert!(ranks > 0);
        match *self {
            Self::RoundRobin => (v % ranks as u64) as usize,
            Self::Hashed { seed } => (xxh64_u64(v, seed) % ranks as u64) as usize,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round-robin" | "rr" => Some(Self::RoundRobin),
            "hash" | "hashed" => Some(Self::Hashed { seed: 0x9E37 }),
            _ => None,
        }
    }

    /// Stable name for serialization.
    pub fn name(&self) -> String {
        match self {
            Self::RoundRobin => "round-robin".into(),
            Self::Hashed { seed } => format!("hashed:{seed}"),
        }
    }

    /// Inverse of [`Partitioner::name`].
    pub fn from_name(s: &str) -> Option<Self> {
        if s == "round-robin" {
            return Some(Self::RoundRobin);
        }
        if let Some(rest) = s.strip_prefix("hashed:") {
            return rest.parse().ok().map(|seed| Self::Hashed { seed });
        }
        None
    }
}

/// Wire format for the seed_state leg: every epoch seed carries the
/// partition `f` so a remote worker routes identically to the driver.
impl WireMsg for Partitioner {
    fn encode_into(&self, buf: &mut Vec<u8>) {
        match *self {
            Self::RoundRobin => put_u8(buf, 0),
            Self::Hashed { seed } => {
                put_u8(buf, 1);
                put_u64(buf, seed);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> Result<Self, WireError> {
        match get_u8(input)? {
            0 => Ok(Self::RoundRobin),
            1 => Ok(Self::Hashed {
                seed: get_u64(input)?,
            }),
            other => Err(WireError::Invalid(format!(
                "bad partitioner tag {other}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_round_trips() {
        for p in [Partitioner::RoundRobin, Partitioner::Hashed { seed: 42 }] {
            let mut buf = Vec::new();
            p.encode_into(&mut buf);
            let mut input = buf.as_slice();
            assert_eq!(Partitioner::decode(&mut input).unwrap(), p);
            assert!(input.is_empty());
        }
        assert!(Partitioner::decode(&mut [9u8].as_slice()).is_err());
    }

    #[test]
    fn round_robin_covers_all_ranks() {
        let p = Partitioner::RoundRobin;
        let mut seen = vec![false; 7];
        for v in 0..100u64 {
            seen[p.rank_of(v, 7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn hashed_is_balanced() {
        let p = Partitioner::Hashed { seed: 1 };
        let ranks = 8;
        let mut counts = vec![0usize; ranks];
        for v in 0..80_000u64 {
            counts[p.rank_of(v, ranks)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 1_000.0, "{counts:?}");
        }
    }

    #[test]
    fn name_round_trips() {
        for p in [Partitioner::RoundRobin, Partitioner::Hashed { seed: 42 }] {
            assert_eq!(Partitioner::from_name(&p.name()), Some(p));
        }
    }
}
