//! TCP query server: a line protocol over the persistent [`QueryEngine`].
//!
//! This is the deployment face of the "leave-behind query engine": a
//! saved DegreeSketch is loaded once and served to clients. Protocol
//! (request → response, one line each):
//!
//! ```text
//! DEG <x>              → <estimate> | NONE
//! TRI <x> <y>          → <intersection> <union> <dominated:0|1> | NONE
//! JACCARD <x> <y>      → <jaccard> | NONE
//! UNION <x> [<y> ...]  → <estimate> | NONE
//! STATS                → vertices=<n> ranks=<p> p=<p> mem=<bytes>
//!                        dense=<n> mode=<heap|mmap> resident=<bytes>
//!                        evicted=<n>
//!                        comm=<sequential|threaded|process|tcp|none>
//!                        [ckpts=<n> restores=<n> hb_stale_ms=<ms>]
//!                        [rank<i>=<msgs>/<bytes>/<flushes> ...]
//! METRICS              → Prometheus text exposition, terminated by a
//!                        `# EOF` line (the one multi-line response)
//! QUIT                 → BYE (closes the connection)
//! ```
//!
//! `METRICS` scrapes the server's own registry (per-query-kind request
//! counters and log2-bucketed latency histograms with p50/p90/p99
//! quantile summaries, engine gauges, comm/checkpoint/recovery and
//! heartbeat-staleness gauges) concatenated with the process-global
//! [`telemetry::registry`] (fabric counters merged from worker TELEM
//! deltas). Clients read until the `# EOF` line — it is both the
//! OpenMetrics terminator and the framing for this one multi-line verb.
//!
//! `mem` is the engine's *private heap* sketch bytes and `resident` the
//! *mapped snapshot* bytes (shared address space): a heap-loaded server
//! reports `mem=<bytes> mode=heap resident=0`, a snapshot-backed one
//! `mem=0 mode=mmap resident=<file len>` — so operators can confirm that
//! N processes serving one snapshot share a single page-cache copy.
//!
//! `comm` names the comm backend that accumulated the sketch, and each
//! `rank<i>` field reports that rank's inbound accumulation traffic
//! (messages/bytes/flushes), so operators can spot partition skew from a
//! live server. Engines loaded from disk report `comm=none` — their
//! accumulation happened in another process.
//!
//! Unknown commands answer `ERR <reason>`. One thread per connection; the
//! engine is shared read-only. Finished connection threads are reaped in
//! the accept loop (not hoarded until shutdown), so long-lived servers
//! hold O(live connections) handles.
//!
//! Connections are additionally bounded by [`ConnLimits`]: reads carry a
//! socket-level timeout, and a client silent for longer than the idle cap
//! is evicted (answered `ERR idle timeout, closing` and disconnected)
//! rather than pinning a thread forever — the defense against half-open
//! peers that vanished without a FIN. Evictions are counted and reported
//! as `evicted=<n>` in `STATS`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::hll::Domination;
use crate::telemetry::{self, prom, Registry};

use super::engine::QueryEngine;

/// Join every finished worker, keeping only live ones.
fn reap_finished(workers: &mut Vec<JoinHandle<()>>) {
    let mut i = 0;
    while i < workers.len() {
        if workers[i].is_finished() {
            let _ = workers.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

/// Per-connection read bounds: `read_timeout` is the socket-level poll
/// granularity; a client silent for longer than `idle_cap` is evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnLimits {
    pub read_timeout: Duration,
    pub idle_cap: Duration,
}

impl Default for ConnLimits {
    fn default() -> Self {
        Self {
            read_timeout: Duration::from_millis(250),
            idle_cap: Duration::from_secs(300),
        }
    }
}

/// A running server handle (listener thread spawns per-connection threads).
pub struct QueryServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// Connection threads currently tracked by the accept loop (post-reap).
    live: Arc<AtomicUsize>,
    /// Connections evicted for exceeding the idle cap (reported in STATS).
    evicted: Arc<AtomicU64>,
    /// This server's metric series (query counters + latency histograms),
    /// exposed by the `METRICS` verb alongside the process-global registry.
    metrics: Arc<Registry>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl QueryServer {
    /// Bind and start serving. `addr` like `"127.0.0.1:0"` (0 = ephemeral).
    pub fn start(engine: Arc<QueryEngine>, addr: &str) -> Result<Self> {
        Self::start_with_limits(engine, addr, ConnLimits::default())
    }

    /// [`QueryServer::start`] with explicit per-connection read bounds.
    pub fn start_with_limits(
        engine: Arc<QueryEngine>,
        addr: &str,
        limits: ConnLimits,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let live = Arc::new(AtomicUsize::new(0));
        let evicted = Arc::new(AtomicU64::new(0));
        let metrics = Arc::new(Registry::new());
        let stop = Arc::clone(&shutdown);
        let live_in = Arc::clone(&live);
        let evicted_in = Arc::clone(&evicted);
        let metrics_in = Arc::clone(&metrics);
        let handle = std::thread::spawn(move || {
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let engine = Arc::clone(&engine);
                        let evictions = Arc::clone(&evicted_in);
                        let metrics = Arc::clone(&metrics_in);
                        workers.push(std::thread::spawn(move || {
                            let _ = serve_connection(
                                stream, &engine, limits, &evictions, &metrics,
                            );
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
                // reap completed connections so the handle vector tracks
                // live connections instead of growing for the server's
                // whole lifetime
                reap_finished(&mut workers);
                live_in.store(workers.len(), Ordering::Relaxed);
            }
            for w in workers {
                let _ = w.join();
            }
            live_in.store(0, Ordering::Relaxed);
        });
        Ok(Self {
            addr: local,
            shutdown,
            live,
            evicted,
            metrics,
            handle: Some(handle),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Connection-thread handles currently held by the accept loop. Stays
    /// bounded by the number of live connections thanks to in-loop reaping.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// Connections evicted so far for exceeding the idle cap.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// This server's metric registry (query counters, latency histograms).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Stop accepting and join the listener thread.
    pub fn stop(mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_connection(
    stream: TcpStream,
    engine: &QueryEngine,
    limits: ConnLimits,
    evictions: &AtomicU64,
    metrics: &Registry,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(limits.read_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        let last_activity = Instant::now();
        // Deadline-bounded line read: a socket-level timeout makes each
        // read_until attempt return WouldBlock/TimedOut, and silence past
        // the idle cap evicts the client. A half-written line counts as
        // silence too — partial bytes never reset the idle clock.
        let eof = loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break true,
                Ok(_) if buf.ends_with(b"\n") => break false,
                Ok(_) => {} // partial line: keep reading toward the cap
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if last_activity.elapsed() >= limits.idle_cap {
                        evictions.fetch_add(1, Ordering::Relaxed);
                        let _ = writeln!(writer, "ERR idle timeout, closing");
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        };
        if buf.is_empty() {
            return Ok(()); // clean EOF between lines
        }
        let line = String::from_utf8_lossy(&buf);
        match respond(line.trim_end(), engine, evictions, metrics) {
            Response::Line(s) => writeln!(writer, "{s}")?,
            // Multi-line payloads carry their own framing (the final
            // `# EOF` line) and their own trailing newline.
            Response::Multi(s) => writer.write_all(s.as_bytes())?,
            Response::Bye => {
                writeln!(writer, "BYE")?;
                return Ok(());
            }
        }
        if eof {
            return Ok(()); // final line arrived without a trailing newline
        }
    }
}

enum Response {
    Line(String),
    /// A multi-line body that ends with its own framing (`# EOF\n`).
    Multi(String),
    Bye,
}

/// Record one served query into the per-server registry: a request
/// counter and a latency histogram sample (microseconds), both labeled
/// with the query kind so `METRICS` exposes p50/p90/p99 per verb.
fn record_query(metrics: &Registry, kind: &str, started: Instant) {
    metrics
        .counter("degreesketch_queries_total", &[("kind", kind)])
        .inc();
    metrics
        .histogram("degreesketch_query_latency_us", &[("kind", kind)])
        .observe(started.elapsed().as_micros() as u64);
}

/// Refresh scrape-time gauges: engine sizing, eviction count, and — when
/// this engine was accumulated in-process — the comm fabric's message,
/// checkpoint, recovery and heartbeat-staleness totals (per-rank traffic
/// under a `rank` label).
fn scrape_gauges(metrics: &Registry, engine: &QueryEngine, evictions: &AtomicU64) {
    let g = |name: &str, v: u64| metrics.gauge(name, &[]).set(v);
    g("degreesketch_server_vertices", engine.num_vertices() as u64);
    g("degreesketch_server_heap_bytes", engine.heap_bytes() as u64);
    g(
        "degreesketch_server_resident_bytes",
        engine.resident_bytes() as u64,
    );
    g(
        "degreesketch_server_dense_sketches",
        engine.num_dense_sketches() as u64,
    );
    g(
        "degreesketch_server_evicted_connections",
        evictions.load(Ordering::Relaxed),
    );
    if let Some(cs) = engine.accumulation_stats() {
        g("degreesketch_comm_messages", cs.messages);
        g("degreesketch_comm_bytes", cs.bytes);
        g("degreesketch_comm_flushes", cs.flushes);
        g("degreesketch_comm_checkpoints", cs.checkpoints);
        g("degreesketch_comm_restores", cs.restores);
        g("degreesketch_comm_hb_stale_ms", cs.max_stale_ms);
        for (r, pr) in cs.per_rank.iter().enumerate() {
            let rank = r.to_string();
            metrics
                .gauge("degreesketch_comm_rank_messages", &[("rank", &rank)])
                .set(pr.messages);
            metrics
                .gauge("degreesketch_comm_rank_bytes", &[("rank", &rank)])
                .set(pr.bytes);
        }
    }
}

fn respond(
    line: &str,
    engine: &QueryEngine,
    evictions: &AtomicU64,
    metrics: &Registry,
) -> Response {
    let mut it = line.split_whitespace();
    let cmd = match it.next() {
        Some(c) => c.to_ascii_uppercase(),
        None => return Response::Line("ERR empty".into()),
    };
    let parse_ids = |it: std::str::SplitWhitespace| -> Result<Vec<u64>, String> {
        it.map(|t| t.parse::<u64>().map_err(|_| format!("bad id {t:?}")))
            .collect()
    };
    let started = Instant::now();
    match cmd.as_str() {
        "DEG" => match parse_ids(it) {
            Ok(ids) if ids.len() == 1 => {
                let resp = Response::Line(
                    engine
                        .degree(ids[0])
                        .map(|d| format!("{d:.3}"))
                        .unwrap_or_else(|| "NONE".into()),
                );
                record_query(metrics, "deg", started);
                resp
            }
            Ok(_) => Response::Line("ERR usage: DEG <x>".into()),
            Err(e) => Response::Line(format!("ERR {e}")),
        },
        "TRI" => match parse_ids(it) {
            Ok(ids) if ids.len() == 2 => {
                let resp = match engine.intersection(ids[0], ids[1]) {
                    Some(est) => Response::Line(format!(
                        "{:.3} {:.3} {}",
                        est.intersection,
                        est.union,
                        u8::from(est.domination != Domination::None)
                    )),
                    None => Response::Line("NONE".into()),
                };
                record_query(metrics, "tri", started);
                resp
            }
            Ok(_) => Response::Line("ERR usage: TRI <x> <y>".into()),
            Err(e) => Response::Line(format!("ERR {e}")),
        },
        "JACCARD" => match parse_ids(it) {
            Ok(ids) if ids.len() == 2 => {
                let resp = Response::Line(
                    engine
                        .jaccard(ids[0], ids[1])
                        .map(|j| format!("{j:.6}"))
                        .unwrap_or_else(|| "NONE".into()),
                );
                record_query(metrics, "jaccard", started);
                resp
            }
            Ok(_) => Response::Line("ERR usage: JACCARD <x> <y>".into()),
            Err(e) => Response::Line(format!("ERR {e}")),
        },
        "UNION" => match parse_ids(it) {
            Ok(ids) if !ids.is_empty() => {
                let resp = Response::Line(
                    engine
                        .union_cardinality(&ids)
                        .map(|u| format!("{u:.3}"))
                        .unwrap_or_else(|| "NONE".into()),
                );
                record_query(metrics, "union", started);
                resp
            }
            Ok(_) => Response::Line("ERR usage: UNION <x> [<y> ...]".into()),
            Err(e) => Response::Line(format!("ERR {e}")),
        },
        "METRICS" => {
            scrape_gauges(metrics, engine, evictions);
            Response::Multi(prom::render(&[metrics, telemetry::registry()]))
        }
        "STATS" => {
            let mut line = format!(
                "vertices={} ranks={} p={} mem={} dense={} mode={} \
                 resident={} evicted={}",
                engine.num_vertices(),
                engine.num_ranks(),
                engine.config().p(),
                engine.heap_bytes(),
                engine.num_dense_sketches(),
                engine.backing_mode(),
                engine.resident_bytes(),
                evictions.load(Ordering::Relaxed)
            );
            match engine.accumulation_stats() {
                Some(cs) => {
                    line.push_str(&format!(
                        " comm={} ckpts={} restores={} hb_stale_ms={}",
                        cs.mode.name(),
                        cs.checkpoints,
                        cs.restores,
                        cs.max_stale_ms
                    ));
                    for (r, pr) in cs.per_rank.iter().enumerate() {
                        line.push_str(&format!(
                            " rank{r}={}/{}/{}",
                            pr.messages, pr.bytes, pr.flushes
                        ));
                    }
                }
                None => line.push_str(" comm=none"),
            }
            Response::Line(line)
        }
        "QUIT" => Response::Bye,
        other => Response::Line(format!("ERR unknown command {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sketch::{accumulate_stream, AccumulateOptions};
    use crate::graph::gen::karate;
    use crate::graph::stream::MemoryStream;
    use crate::hll::HllConfig;
    use std::io::{BufRead, BufReader, Write};

    fn test_engine() -> Arc<QueryEngine> {
        let stream = MemoryStream::new(karate::edges());
        let ds = accumulate_stream(
            &stream,
            2,
            HllConfig::new(12, 0x5E),
            AccumulateOptions::default(),
        );
        Arc::new(QueryEngine::new(ds))
    }

    fn ask(addr: std::net::SocketAddr, lines: &[&str]) -> Vec<String> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(w, "{l}").unwrap();
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            out.push(resp.trim().to_string());
        }
        out
    }

    /// One METRICS scrape: reads the multi-line body through its `# EOF`
    /// framing line (inclusive).
    fn scrape_metrics(addr: std::net::SocketAddr) -> String {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "METRICS").unwrap();
        let mut text = String::new();
        loop {
            let mut line = String::new();
            assert!(r.read_line(&mut line).unwrap() > 0, "closed before # EOF");
            text.push_str(&line);
            if line.trim_end() == "# EOF" {
                break;
            }
        }
        writeln!(w, "QUIT").unwrap();
        text
    }

    #[test]
    fn serves_queries_over_tcp() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let resp = ask(
            addr,
            &[
                "DEG 33",
                "DEG 999",
                "TRI 0 33",
                "JACCARD 0 1",
                "UNION 0 33",
                "STATS",
                "NOPE",
                "QUIT",
            ],
        );
        let d: f64 = resp[0].parse().unwrap();
        assert!((d - 17.0).abs() < 2.0, "{resp:?}");
        assert_eq!(resp[1], "NONE");
        assert_eq!(resp[2].split_whitespace().count(), 3);
        let j: f64 = resp[3].parse().unwrap();
        assert!((0.0..=1.0).contains(&j));
        assert!(resp[4].parse::<f64>().unwrap() > 20.0);
        assert!(resp[5].starts_with("vertices=34"), "{:?}", resp[5]);
        assert!(resp[5].contains("mode=heap"), "{:?}", resp[5]);
        assert!(resp[5].contains("resident="), "{:?}", resp[5]);
        // accumulated in-process on 2 sequential ranks: comm backend and
        // both ranks' message/byte/flush counters are reported
        assert!(resp[5].contains("comm=sequential"), "{:?}", resp[5]);
        assert!(resp[5].contains("rank0="), "{:?}", resp[5]);
        assert!(resp[5].contains("rank1="), "{:?}", resp[5]);
        assert!(resp[6].starts_with("ERR"));
        assert_eq!(resp[7], "BYE");
        server.stop();
    }

    #[test]
    fn metrics_verb_serves_valid_prometheus_text_with_quantiles() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        // Exercise each timed verb so every per-kind series exists.
        let _ = ask(
            addr,
            &["DEG 0", "DEG 33", "TRI 0 33", "JACCARD 0 1", "UNION 0 33", "QUIT"],
        );
        let text = scrape_metrics(addr);
        // Must pass the minimal Prometheus checker (TYPE lines, cumulative
        // buckets, # EOF framing).
        let samples = prom::check_text(&text)
            .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
        assert!(samples > 10, "suspiciously few samples:\n{text}");
        for kind in ["deg", "tri", "jaccard", "union"] {
            assert!(
                text.contains(&format!(
                    "degreesketch_queries_total{{kind=\"{kind}\"}}"
                )),
                "missing counter for {kind}:\n{text}"
            );
            for q in ["0.5", "0.99"] {
                assert!(
                    text.contains(&format!(
                        "degreesketch_query_latency_us_quantiles\
                         {{kind=\"{kind}\",quantile=\"{q}\"}}"
                    )),
                    "missing p{q} for {kind}:\n{text}"
                );
            }
        }
        // Comm gauges from the in-process accumulation are scraped too.
        assert!(text.contains("degreesketch_comm_messages"), "{text}");
        assert!(text.contains("degreesketch_comm_hb_stale_ms"), "{text}");
        // DEG ran twice above; the counter must say so.
        assert!(
            text.contains("degreesketch_queries_total{kind=\"deg\"} 2"),
            "{text}"
        );
        server.stop();
    }

    #[test]
    fn stats_reports_hb_staleness_alongside_recovery_counts() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let resp = ask(server.addr(), &["STATS", "QUIT"]);
        assert!(resp[0].contains("ckpts="), "{:?}", resp[0]);
        assert!(resp[0].contains("restores="), "{:?}", resp[0]);
        assert!(resp[0].contains("hb_stale_ms=0"), "{:?}", resp[0]);
        server.stop();
    }

    #[test]
    fn stats_reports_mmap_backing_for_snapshot_engines() {
        let path = std::env::temp_dir().join("ds_server_stats.snap");
        let _ = std::fs::remove_file(&path);
        test_engine().save_snapshot(&path).unwrap();
        let engine = Arc::new(QueryEngine::load(&path).unwrap());
        let expected_mode = format!("mode={}", engine.backing_mode());
        let server = QueryServer::start(engine, "127.0.0.1:0").unwrap();
        let resp = ask(server.addr(), &["STATS", "QUIT"]);
        // mmap on 64-bit unix; the heap fallback elsewhere — either way the
        // snapshot resident size (the file length) is reported
        assert!(resp[0].contains(&expected_mode), "{:?}", resp[0]);
        // loaded engines weren't accumulated here: no comm stats to report
        assert!(resp[0].contains("comm=none"), "{:?}", resp[0]);
        let resident: u64 = resp[0]
            .split_whitespace()
            .find_map(|t| t.strip_prefix("resident="))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(resident, std::fs::metadata(&path).unwrap().len());
        server.stop();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn finished_workers_are_reaped_in_the_accept_loop() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        for _ in 0..16 {
            let resp = ask(addr, &["DEG 0", "QUIT"]);
            assert!(resp[0].parse::<f64>().is_ok());
        }
        // every connection above is closed; after the next accept-loop
        // tick the tracked handle count must fall back to ~0 rather than
        // accumulating one handle per historical connection
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        loop {
            // poke the loop so it runs a reap pass even if idle
            let _ = ask(addr, &["QUIT"]);
            if server.live_workers() <= 2 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "workers never reaped: {}",
                server.live_workers()
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        server.stop();
    }

    #[test]
    fn idle_connections_are_evicted_and_counted() {
        let limits = ConnLimits {
            read_timeout: Duration::from_millis(10),
            idle_cap: Duration::from_millis(80),
        };
        let server =
            QueryServer::start_with_limits(test_engine(), "127.0.0.1:0", limits)
                .unwrap();
        let addr = server.addr();
        // A silent client — and a half-open one that wrote a partial line
        // (no newline) — must both be evicted, not parked forever.
        let silent = TcpStream::connect(addr).unwrap();
        let half_open = TcpStream::connect(addr).unwrap();
        {
            let mut w = half_open.try_clone().unwrap();
            write!(w, "DEG ").unwrap(); // never finishes the line
        }
        for stream in [silent, half_open] {
            let mut r = BufReader::new(stream);
            let mut resp = String::new();
            r.read_line(&mut resp).unwrap();
            assert!(resp.starts_with("ERR idle"), "{resp:?}");
            resp.clear();
            assert_eq!(r.read_line(&mut resp).unwrap(), 0, "not closed");
        }
        // A live client still works and sees the eviction counter in STATS.
        let out = ask(addr, &["STATS", "QUIT"]);
        assert!(out[0].contains("evicted=2"), "{:?}", out[0]);
        assert_eq!(server.evicted(), 2);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let server = QueryServer::start(test_engine(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let resp = ask(addr, &["DEG 0", "QUIT"]);
                    resp[0].parse::<f64>().unwrap()
                })
            })
            .collect();
        for h in handles {
            let d = h.join().unwrap();
            assert!((d - 16.0).abs() < 2.0);
        }
        server.stop();
    }
}
