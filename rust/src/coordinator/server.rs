//! Compatibility shim: the TCP query server now lives in the serving
//! tier ([`super::serve`]).
//!
//! The original thread-per-connection server grew into an event-driven
//! reactor + batcher + cache stack; this module keeps the old import
//! path (`coordinator::server::QueryServer`) and the old API
//! (`start`/`start_with_limits`/`stop`, `ConnLimits`) stable for
//! existing callers and tests. New code should import from
//! [`crate::coordinator::serve`] directly.

pub use super::serve::{ConnLimits, QueryServer};
