//! Bounded max-k heaps `H̃_k` (paper Algorithms 3–5) and their REDUCE.
//!
//! Each processor keeps the top-k scored items it has seen; the global
//! result is the merge of all per-rank heaps ("REDUCE ... the creation of
//! a global max heap", §2). Implemented as a size-k min-heap on score so
//! insertion is `O(log k)` and eviction is the root.

use std::collections::BinaryHeap;
use std::cmp::Ordering;

/// A score with total order (ties broken by the item's `Ord`, so results
/// are deterministic across backends).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry<T> {
    score: f64,
    item: T,
}

impl<T: Eq> Eq for Entry<T> {}

impl<T: Ord + Eq> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via Reverse at usage sites; here: natural ascending
        self.score
            .partial_cmp(&other.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.item.cmp(&other.item))
    }
}

impl<T: Ord + Eq> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Top-k tracker by f64 score (NaN scores are rejected).
#[derive(Debug, Clone)]
pub struct TopK<T: Ord + Eq + Clone> {
    k: usize,
    heap: BinaryHeap<std::cmp::Reverse<Entry<T>>>,
}

impl<T: Ord + Eq + Clone> TopK<T> {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            heap: BinaryHeap::with_capacity(k + 1),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// "Try to insert" (Alg. 4 line 16): keeps the item only if it beats
    /// the current k-th score.
    pub fn insert(&mut self, score: f64, item: T) {
        if self.k == 0 || score.is_nan() {
            return;
        }
        self.heap.push(std::cmp::Reverse(Entry { score, item }));
        if self.heap.len() > self.k {
            self.heap.pop();
        }
    }

    /// REDUCE: merge another heap into this one.
    pub fn merge(&mut self, other: &TopK<T>) {
        for std::cmp::Reverse(e) in other.heap.iter() {
            self.insert(e.score, e.item.clone());
        }
    }

    /// Descending (score, item) list.
    pub fn into_sorted_vec(self) -> Vec<(f64, T)> {
        let mut v: Vec<(f64, T)> = self
            .heap
            .into_iter()
            .map(|std::cmp::Reverse(e)| (e.score, e.item))
            .collect();
        v.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(Ordering::Equal)
                .then_with(|| a.1.cmp(&b.1))
        });
        v
    }

    /// Smallest retained score (the admission threshold).
    pub fn threshold(&self) -> Option<f64> {
        self.heap.peek().map(|std::cmp::Reverse(e)| e.score)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_top_k() {
        let mut h = TopK::new(3);
        for (s, v) in [(1.0, 1u64), (5.0, 5), (3.0, 3), (2.0, 2), (4.0, 4)] {
            h.insert(s, v);
        }
        let top = h.into_sorted_vec();
        assert_eq!(
            top,
            vec![(5.0, 5), (4.0, 4), (3.0, 3)]
        );
    }

    #[test]
    fn merge_is_global_topk() {
        let mut a = TopK::new(2);
        let mut b = TopK::new(2);
        a.insert(10.0, 1u64);
        a.insert(1.0, 2);
        b.insert(5.0, 3);
        b.insert(7.0, 4);
        a.merge(&b);
        let top = a.into_sorted_vec();
        assert_eq!(top, vec![(10.0, 1), (7.0, 4)]);
    }

    #[test]
    fn ties_break_deterministically() {
        let mut h = TopK::new(2);
        h.insert(1.0, 30u64);
        h.insert(1.0, 10);
        h.insert(1.0, 20);
        // larger items win ties (Entry orders by item after score)
        let top = h.into_sorted_vec();
        assert_eq!(top, vec![(1.0, 20), (1.0, 30)]);
    }

    #[test]
    fn nan_rejected_zero_k_noop() {
        let mut h = TopK::new(0);
        h.insert(1.0, 1u64);
        assert!(h.is_empty());
        let mut h = TopK::new(2);
        h.insert(f64::NAN, 1u64);
        assert!(h.is_empty());
    }

    #[test]
    fn threshold_tracks_kth() {
        let mut h = TopK::new(2);
        assert_eq!(h.threshold(), None);
        h.insert(3.0, 1u64);
        h.insert(9.0, 2);
        h.insert(5.0, 3);
        assert_eq!(h.threshold(), Some(5.0));
    }
}
