//! **Layer 2 of the comm plane: the transport trait.**
//!
//! A [`Transport`] is where a flushed batch goes: the sequential
//! scheduler's in-process queues, the threaded scheduler's in-memory
//! channels, or the process backend's framed Unix-domain sockets. The
//! schedulers never move batches themselves — every [`Outbox`] flush
//! (eager threshold crossings and forced drains alike) funnels through
//! [`flush_outbox`], which applies the outbox's flush policy and hands
//! each `(destination, batch)` pair to the transport.
//!
//! Quiescence accounting contract: [`Transport::note_queued`] is called
//! with the number of newly queued messages *before* any of them ship, so
//! a backend's outstanding-message counter can never observe a message
//! "in a channel" that it hasn't first seen "queued" — the invariant the
//! threaded backend's termination detector (and the process backend's
//! token accounting) are built on.

use super::outbox::Outbox;
use crate::telemetry::heatmap::HeatSampler;

/// Destination of flushed batches for one rank (one instance per worker).
pub(crate) trait Transport<M> {
    /// Account `n` newly queued messages. Runs before the batches holding
    /// them are shipped (see module docs).
    fn note_queued(&mut self, n: u64);

    /// Ship one batch toward `to`'s receive queue.
    fn ship(&mut self, to: usize, batch: Vec<M>);
}

/// Move outbox contents into the transport. `force`: drain everything;
/// otherwise only buffers that crossed their per-destination threshold.
/// `sent_base` is the caller-held cursor into `outbox.total_sent()` (what
/// `note_queued` has already accounted). `heat` is the rank's traffic
/// sampler when a heat grid is armed (`None` on untraced runs): every
/// shipped batch is classified into the per-range heatmap right before it
/// leaves, so the grid sees exactly what the transport sees.
pub(crate) fn flush_outbox<M, T: Transport<M>>(
    outbox: &mut Outbox<M>,
    sent_base: &mut u64,
    transport: &mut T,
    force: bool,
    heat: Option<&HeatSampler<M>>,
) {
    let queued = outbox.total_sent();
    if queued > *sent_base {
        transport.note_queued(queued - *sent_base);
        *sent_base = queued;
    }
    if force {
        for (to, batch) in outbox.drain_all() {
            if let Some(h) = heat {
                h.record(to, &batch);
            }
            transport.ship(to, batch);
        }
    } else {
        for to in outbox.take_hot() {
            let batch = outbox.take_buf_eager(to);
            if !batch.is_empty() {
                if let Some(h) = heat {
                    h.record(to, &batch);
                }
                transport.ship(to, batch);
            }
        }
    }
}

/// Estimated payload bytes of an in-memory batch (the in-memory backends
/// never serialize, so `CommStats::bytes` uses this size-of estimate).
#[inline]
pub(crate) fn batch_bytes_estimate<M>(len: usize) -> u64 {
    (len * std::mem::size_of::<M>()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::FlushPolicy;

    #[derive(Default)]
    struct Recorder {
        queued: u64,
        shipped: Vec<(usize, Vec<u32>)>,
    }

    impl Transport<u32> for Recorder {
        fn note_queued(&mut self, n: u64) {
            self.queued += n;
        }

        fn ship(&mut self, to: usize, batch: Vec<u32>) {
            self.shipped.push((to, batch));
        }
    }

    #[test]
    fn queued_accounting_precedes_shipping() {
        let mut outbox: Outbox<u32> = Outbox::new(2, FlushPolicy::pinned(2));
        let mut t = Recorder::default();
        let mut base = 0u64;
        outbox.send(1, 10);
        outbox.send(1, 11); // crosses threshold
        outbox.send(0, 12);
        flush_outbox(&mut outbox, &mut base, &mut t, false, None);
        assert_eq!(t.queued, 3, "all queued messages accounted");
        assert_eq!(t.shipped, vec![(1, vec![10, 11])], "only the hot lane");
        flush_outbox(&mut outbox, &mut base, &mut t, true, None);
        assert_eq!(t.queued, 3, "no double accounting");
        assert_eq!(t.shipped.len(), 2);
        assert_eq!(t.shipped[1], (0, vec![12]));
    }
}
