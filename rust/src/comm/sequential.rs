//! Deterministic single-threaded scheduler: round-robin over ranks,
//! mirroring the paper's pseudocode structure (drain `R[P]` per rank, loop
//! to quiescence, then idle rounds).
//!
//! Batches move through a [`Transport`] like every other backend, but the
//! transport is a plain in-process queue set and the flush policy is
//! pinned unbounded (whole-context batches): delivery order — hence every
//! floating-point reduction downstream — is a pure function of the input,
//! which is what makes this backend the bit-deterministic anchor for the
//! parity tests.

use std::collections::VecDeque;

use super::outbox::FlushPolicy;
use super::transport::{batch_bytes_estimate, flush_outbox, Transport};
use super::{Actor, Backend, CommStats, Outbox};
use crate::telemetry::heatmap::HeatSampler;

/// The sequential transport: per-rank `VecDeque` receive queues.
struct QueueTransport<'a, M> {
    queues: &'a mut [VecDeque<M>],
    stats: &'a mut CommStats,
}

impl<M> Transport<M> for QueueTransport<'_, M> {
    fn note_queued(&mut self, _n: u64) {}

    fn ship(&mut self, to: usize, batch: Vec<M>) {
        let bytes = batch_bytes_estimate::<M>(batch.len());
        self.stats.flushes += 1;
        self.stats.bytes += bytes;
        let pr = &mut self.stats.per_rank[to];
        pr.flushes += 1;
        pr.bytes += bytes;
        self.queues[to].extend(batch);
    }
}

/// Run one epoch deterministically. Used by accuracy experiments and as
/// the semantic reference for the threaded and process backends.
pub fn run_sequential<A: Actor>(actors: &mut [A]) -> CommStats {
    let ranks = actors.len();
    assert!(ranks > 0);
    let mut stats = CommStats::new(Backend::Sequential, ranks);
    let mut queues: Vec<VecDeque<A::Msg>> =
        (0..ranks).map(|_| VecDeque::new()).collect();

    // unbounded threshold: sequential delivery needs no mid-context
    // flushing, and a pinned policy keeps the schedule deterministic
    let mut outbox: Outbox<A::Msg> = Outbox::new(ranks, FlushPolicy::unbounded());
    let mut sent_base = 0u64;

    // Per-rank heat samplers (None unless a heat grid is armed). The
    // outbox is shared across ranks here, so the acting rank's sampler is
    // passed at each drain to keep src attribution honest.
    let heats: Vec<Option<HeatSampler<A::Msg>>> = (0..ranks)
        .map(|r| HeatSampler::new(r, A::heat_vertex))
        .collect();

    // Computation context (σ_P read) for every rank.
    for (rank, actor) in actors.iter_mut().enumerate() {
        actor.seed(&mut outbox);
        drain(
            &mut outbox,
            &mut sent_base,
            &mut queues,
            &mut stats,
            heats[rank].as_ref(),
        );
    }

    loop {
        // message storm to quiescence
        let mut progressed = true;
        while progressed {
            progressed = false;
            for rank in 0..ranks {
                while let Some(msg) = queues[rank].pop_front() {
                    actors[rank].on_message(msg, &mut outbox);
                    stats.messages += 1;
                    stats.per_rank[rank].messages += 1;
                    progressed = true;
                    drain(
                        &mut outbox,
                        &mut sent_base,
                        &mut queues,
                        &mut stats,
                        heats[rank].as_ref(),
                    );
                }
            }
        }
        // global idle round
        stats.idle_rounds += 1;
        let before = outbox.total_sent();
        for (rank, actor) in actors.iter_mut().enumerate() {
            actor.on_idle(&mut outbox);
            drain(
                &mut outbox,
                &mut sent_base,
                &mut queues,
                &mut stats,
                heats[rank].as_ref(),
            );
        }
        if outbox.total_sent() == before {
            break;
        }
    }
    stats
}

fn drain<M>(
    outbox: &mut Outbox<M>,
    sent_base: &mut u64,
    queues: &mut [VecDeque<M>],
    stats: &mut CommStats,
    heat: Option<&HeatSampler<M>>,
) {
    let mut transport = QueueTransport { queues, stats };
    flush_outbox(outbox, sent_base, &mut transport, true, heat);
}
