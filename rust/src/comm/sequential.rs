//! Deterministic single-threaded scheduler: round-robin over ranks,
//! mirroring the paper's pseudocode structure (drain `R[P]` per rank, loop
//! to quiescence, then idle rounds).

use std::collections::VecDeque;

use super::{Actor, CommStats, Outbox};

/// Run one epoch deterministically. Used by accuracy experiments and as
/// the semantic reference for the threaded backend.
pub fn run_sequential<A: Actor>(actors: &mut [A]) -> CommStats {
    let ranks = actors.len();
    assert!(ranks > 0);
    let mut stats = CommStats::default();
    let mut queues: Vec<VecDeque<A::Msg>> =
        (0..ranks).map(|_| VecDeque::new()).collect();

    // large threshold: sequential delivery needs no mid-context flushing
    let mut outbox: Outbox<A::Msg> = Outbox::new(ranks, usize::MAX);

    // Computation context (σ_P read) for every rank.
    for (rank, actor) in actors.iter_mut().enumerate() {
        let _ = rank;
        actor.seed(&mut outbox);
        drain(&mut outbox, &mut queues, &mut stats);
    }

    loop {
        // message storm to quiescence
        let mut progressed = true;
        while progressed {
            progressed = false;
            for rank in 0..ranks {
                while let Some(msg) = queues[rank].pop_front() {
                    actors[rank].on_message(msg, &mut outbox);
                    stats.messages += 1;
                    progressed = true;
                    drain(&mut outbox, &mut queues, &mut stats);
                }
            }
        }
        // global idle round
        stats.idle_rounds += 1;
        let before = outbox.total_sent();
        for actor in actors.iter_mut() {
            actor.on_idle(&mut outbox);
            drain(&mut outbox, &mut queues, &mut stats);
        }
        if outbox.total_sent() == before {
            break;
        }
    }
    stats
}

fn drain<M>(
    outbox: &mut Outbox<M>,
    queues: &mut [VecDeque<M>],
    stats: &mut CommStats,
) {
    for (to, batch) in outbox.drain_all() {
        stats.flushes += 1;
        queues[to].extend(batch);
    }
}
