//! **Layer 1 of the comm plane: wire codecs.**
//!
//! Everything an actor sends can leave the process: each coordinator
//! message enum implements [`WireMsg`] — a little-endian, append-only
//! binary encoding — and batches of messages travel in CRC'd,
//! length-prefixed [frames](encode_frame_into) whose header carries the
//! channel's cumulative message counter (the *termination token* the
//! process backend's quiescence protocol rides on).
//!
//! Carried-HLL payloads (the ANF/triangle FAN messages) reuse the
//! snapshot layout's two register encodings (see `snapshot::mod` §file
//! layout): dense sketches ship their raw `r`-byte register array (the
//! histogram is derived state, rebuilt on decode), sparse sketches ship
//! packed 4-byte `[idx lo, idx hi, value, 0]` pair records. The `(p,
//! seed)` config travels with each sketch so a decoded frame is
//! self-contained.
//!
//! Decoding is defensive: every length, index, register value and pad
//! byte is validated, and the frame CRC (computed over header *and*
//! payload) rejects corruption before any message reaches an actor.

use super::outbox::FlushPolicy;
use crate::hll::{kernels, Hll, HllConfig, SketchRef, SketchStore};
use crate::util::crc32::Crc32;

/// Why a decode failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the value (or frame) was complete.
    Truncated,
    /// Frame did not start with [`FRAME_MAGIC`].
    BadMagic(u32),
    /// Frame CRC mismatch (header or payload corrupted).
    BadCrc { stored: u32, actual: u32 },
    /// Structurally invalid content (bad tag, index, range, pad...).
    Invalid(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated input"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            WireError::BadCrc { stored, actual } => {
                write!(f, "frame crc mismatch: stored {stored:#010x}, actual {actual:#010x}")
            }
            WireError::Invalid(msg) => write!(f, "invalid wire data: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

fn invalid(msg: impl Into<String>) -> WireError {
    WireError::Invalid(msg.into())
}

// ---------------------------------------------------------------------------
// Little-endian primitives
// ---------------------------------------------------------------------------

#[inline]
pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

#[inline]
pub fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

/// Split `n` bytes off the front of `input`, advancing it.
#[inline]
pub fn take<'a>(input: &mut &'a [u8], n: usize) -> Result<&'a [u8], WireError> {
    if input.len() < n {
        return Err(WireError::Truncated);
    }
    let (head, rest) = input.split_at(n);
    *input = rest;
    Ok(head)
}

#[inline]
pub fn get_u8(input: &mut &[u8]) -> Result<u8, WireError> {
    Ok(take(input, 1)?[0])
}

#[inline]
pub fn get_u16(input: &mut &[u8]) -> Result<u16, WireError> {
    let b = take(input, 2)?;
    Ok(u16::from_le_bytes([b[0], b[1]]))
}

#[inline]
pub fn get_u32(input: &mut &[u8]) -> Result<u32, WireError> {
    let b = take(input, 4)?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

#[inline]
pub fn get_u64(input: &mut &[u8]) -> Result<u64, WireError> {
    let b = take(input, 8)?;
    Ok(u64::from_le_bytes([
        b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
    ]))
}

#[inline]
pub fn get_f64(input: &mut &[u8]) -> Result<f64, WireError> {
    Ok(f64::from_bits(get_u64(input)?))
}

// ---------------------------------------------------------------------------
// WireMsg
// ---------------------------------------------------------------------------

/// A message with a wire format: appended to a buffer by `encode_into`,
/// split off the front of a slice by `decode`. Round-trip law:
/// `decode(encode(m)) == m` with the input advanced exactly past `m`.
pub trait WireMsg: Send + Sized + 'static {
    fn encode_into(&self, buf: &mut Vec<u8>);
    fn decode(input: &mut &[u8]) -> Result<Self, WireError>;
}

/// Algorithm 1's accumulation message `(x, y)` = INSERT(D[x], y).
impl WireMsg for (u64, u64) {
    fn encode_into(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.0);
        put_u64(buf, self.1);
    }

    fn decode(input: &mut &[u8]) -> Result<Self, WireError> {
        Ok((get_u64(input)?, get_u64(input)?))
    }
}

// ---------------------------------------------------------------------------
// Carried-HLL payloads
// ---------------------------------------------------------------------------

const HLL_SPARSE: u8 = 0;
const HLL_DENSE: u8 = 1;

fn encode_sparse_into(config: &HllConfig, pairs: &[(u16, u8)], buf: &mut Vec<u8>) {
    put_u8(buf, HLL_SPARSE);
    put_u8(buf, config.p());
    put_u64(buf, config.hasher().seed());
    put_u32(buf, pairs.len() as u32);
    for &(j, x) in pairs {
        // 4-byte pair record, as in the snapshot pairs section
        buf.extend_from_slice(&[j as u8, (j >> 8) as u8, x, 0]);
    }
}

fn encode_dense_into(config: &HllConfig, regs: &[u8], buf: &mut Vec<u8>) {
    put_u8(buf, HLL_DENSE);
    put_u8(buf, config.p());
    put_u64(buf, config.hasher().seed());
    buf.extend_from_slice(regs);
}

/// Encode a sketch: tag, `(p, seed)`, then the snapshot-layout register
/// encoding (packed 4-byte pair records or the raw dense register array).
pub fn encode_hll_into(h: &Hll, buf: &mut Vec<u8>) {
    match h.sparse_pairs() {
        Some(pairs) => encode_sparse_into(h.config(), pairs, buf),
        None => encode_dense_into(
            h.config(),
            h.dense_registers().expect("dense sketch"),
            buf,
        ),
    }
}

/// Encode a borrowed register view — byte-identical to
/// [`encode_hll_into`] of the materialized sketch, without materializing
/// it (the histogram is derived state, never shipped).
pub fn encode_sketch_ref_into(view: SketchRef<'_>, buf: &mut Vec<u8>) {
    match view {
        SketchRef::Sparse { config, pairs } => {
            encode_sparse_into(&config, pairs, buf)
        }
        SketchRef::Dense { config, regs, .. } => {
            encode_dense_into(&config, regs, buf)
        }
    }
}

/// Does the in-memory `(u16, u8)` tuple match the packed 4-byte
/// `[idx_lo, idx_hi, val, pad]` record on the wire (modulo the padding
/// byte)? Shared with the snapshot reader — the wire pair encoding *is*
/// the snapshot pair encoding, so one probe gates both zero-copy casts.
pub(crate) fn pair_abi_matches() -> bool {
    if cfg!(target_endian = "big")
        || std::mem::size_of::<(u16, u8)>() != 4
        || std::mem::align_of::<(u16, u8)>() != 2
    {
        return false;
    }
    let probe: (u16, u8) = (0x0102, 0x03);
    let base = std::ptr::addr_of!(probe) as usize;
    let o0 = std::ptr::addr_of!(probe.0) as usize - base;
    let o1 = std::ptr::addr_of!(probe.1) as usize - base;
    o0 == 0 && o1 == 2
}

/// A validated sparse pair run: borrowed straight from the receive
/// buffer when the host's `(u16, u8)` ABI matches the packed record and
/// the bytes land 2-aligned, otherwise decoded to an owned copy (the
/// portable fallback, same policy as the snapshot reader).
#[derive(Debug, Clone)]
pub enum PairRun<'a> {
    Borrowed(&'a [(u16, u8)]),
    Owned(Vec<(u16, u8)>),
}

impl PairRun<'_> {
    pub fn as_slice(&self) -> &[(u16, u8)] {
        match self {
            PairRun::Borrowed(p) => p,
            PairRun::Owned(p) => p,
        }
    }
}

/// A decoded carried-HLL payload served as a **borrowed view into the
/// receive buffer**: dense registers are always a borrowed byte slice,
/// sparse pairs borrow when the LE/ABI cast gate passes (see
/// [`PairRun`]). Merging a `SketchView` into a [`SketchStore`] touches
/// no intermediate `Hll` — the allocation-free cross-rank merge path
/// used by [`decode_store`] for seed/state payloads.
#[derive(Debug, Clone)]
pub enum SketchView<'a> {
    Sparse {
        config: HllConfig,
        pairs: PairRun<'a>,
    },
    Dense {
        config: HllConfig,
        regs: &'a [u8],
    },
}

impl SketchView<'_> {
    pub fn config(&self) -> HllConfig {
        match self {
            SketchView::Sparse { config, .. }
            | SketchView::Dense { config, .. } => *config,
        }
    }

    /// Merge this view into `store[v]` — no owned `Hll`, no histogram
    /// rebuild (the store's arenas maintain their own).
    pub fn merge_into(&self, store: &mut SketchStore, v: u64) {
        match self {
            SketchView::Sparse { pairs, .. } => {
                store.merge_pairs(v, pairs.as_slice())
            }
            SketchView::Dense { regs, .. } => store.merge_dense_regs(v, regs),
        }
    }

    /// Materialize an owned sketch (the dense histogram is rebuilt —
    /// derived state, never shipped).
    pub fn to_hll(&self) -> Hll {
        match self {
            SketchView::Sparse { config, pairs } => {
                Hll::from_sparse_parts(*config, pairs.as_slice().to_vec())
            }
            SketchView::Dense { config, regs } => {
                let hist = kernels::histogram(regs, config.kmax());
                Hll::from_dense_parts(*config, regs.to_vec(), hist)
            }
        }
    }
}

/// Decode a sketch as a borrowed [`SketchView`], validating every
/// field. This is the zero-copy FAN/state decode path: the returned
/// view aliases `input`'s buffer (pair runs fall back to an owned copy
/// only when the ABI/alignment gate fails).
pub fn decode_sketch_view<'a>(
    input: &mut &'a [u8],
) -> Result<SketchView<'a>, WireError> {
    let tag = get_u8(input)?;
    let p = get_u8(input)?;
    if !(4..=16).contains(&p) {
        return Err(invalid(format!("sketch p {p} out of range")));
    }
    let seed = get_u64(input)?;
    let config = HllConfig::new(p, seed);
    let r = config.num_registers();
    let kmax = config.kmax();
    match tag {
        HLL_SPARSE => {
            let count = get_u32(input)? as usize;
            // a sparse sketch past the saturation threshold would have
            // been stored dense — reject rather than build an impossible
            // representation
            if count > config.saturation_threshold() {
                return Err(invalid(format!(
                    "sparse count {count} exceeds saturation threshold"
                )));
            }
            let recs = take(input, count * 4)?;
            let mut prev: i32 = -1;
            for rec in recs.chunks_exact(4) {
                let j = u16::from_le_bytes([rec[0], rec[1]]);
                let x = rec[2];
                if rec[3] != 0 {
                    return Err(invalid("nonzero pair record pad"));
                }
                if j as usize >= r {
                    return Err(invalid(format!("register index {j} >= r")));
                }
                if (j as i32) <= prev {
                    return Err(invalid("pair indices not strictly increasing"));
                }
                if x == 0 || x > kmax {
                    return Err(invalid(format!(
                        "register value {x} out of range"
                    )));
                }
                prev = j as i32;
            }
            let pairs = if pair_abi_matches() && recs.as_ptr() as usize % 2 == 0
            {
                // SAFETY: the `(u16, u8)` ABI was probed (size 4, u16 at
                // offset 0, u8 at offset 2, LE host), the pointer is
                // 2-aligned, `recs` holds exactly `count * 4` validated
                // bytes, and the padding byte of every record is zero.
                // The slice borrows from `input`'s buffer, which outlives
                // the returned view by construction.
                PairRun::Borrowed(unsafe {
                    std::slice::from_raw_parts(
                        recs.as_ptr() as *const (u16, u8),
                        count,
                    )
                })
            } else {
                PairRun::Owned(
                    recs.chunks_exact(4)
                        .map(|rec| {
                            (u16::from_le_bytes([rec[0], rec[1]]), rec[2])
                        })
                        .collect(),
                )
            };
            Ok(SketchView::Sparse { config, pairs })
        }
        HLL_DENSE => {
            let regs = take(input, r)?;
            if regs.iter().any(|&x| x > kmax) {
                return Err(invalid("dense register value out of range"));
            }
            Ok(SketchView::Dense { config, regs })
        }
        other => Err(invalid(format!("bad sketch tag {other}"))),
    }
}

/// Decode a sketch to an owned [`Hll`], validating every field; the
/// dense histogram is rebuilt (derived state, as in snapshot load and
/// `hll::serde`). One validation implementation: this is
/// [`decode_sketch_view`] + materialize.
pub fn decode_hll(input: &mut &[u8]) -> Result<Hll, WireError> {
    Ok(decode_sketch_view(input)?.to_hll())
}

// ---------------------------------------------------------------------------
// Sketch-store state (process-backend actor state payloads)
// ---------------------------------------------------------------------------

/// Encode a whole [`SketchStore`] as `count` + sorted `(vertex, sketch)`
/// entries, straight from borrowed arena views (no per-vertex `Hll`
/// materialization). Used by [`crate::comm::WireActor`] state codecs:
/// the wire form is exactly what `into_sorted_hlls` would yield, so a
/// store rebuilt by [`decode_store`] is representation-identical (the
/// arena's sparse/dense transitions mirror `Hll`'s).
pub fn encode_store_into(store: &SketchStore, buf: &mut Vec<u8>) {
    let verts = store.vertices_sorted();
    put_u64(buf, verts.len() as u64);
    for v in verts {
        put_u64(buf, v);
        let view = store.get(v).expect("listed vertex has a sketch");
        encode_sketch_ref_into(view, buf);
    }
}

/// Decode a [`SketchStore`] produced by [`encode_store_into`]. Every
/// sketch must carry the expected `config`; vertex ids must be strictly
/// increasing. Each sketch is decoded as a borrowed [`SketchView`] and
/// merged straight from the input buffer into the store's arenas — the
/// rebuild allocates nothing per sketch beyond the arenas themselves.
pub fn decode_store(
    config: HllConfig,
    input: &mut &[u8],
) -> Result<SketchStore, WireError> {
    let n = get_u64(input)?;
    let mut store = SketchStore::new(config);
    let mut prev: Option<u64> = None;
    for _ in 0..n {
        let v = get_u64(input)?;
        if prev.is_some_and(|p| p >= v) {
            return Err(invalid("store vertices not strictly increasing"));
        }
        prev = Some(v);
        let view = decode_sketch_view(input)?;
        if view.config() != config {
            return Err(invalid(format!(
                "store sketch config mismatch for vertex {v}"
            )));
        }
        view.merge_into(&mut store, v);
    }
    Ok(store)
}

// ---------------------------------------------------------------------------
// seed_state leg: epoch-input codecs (policy, config, edge partitions)
// ---------------------------------------------------------------------------

/// Encode a [`FlushPolicy`] (rides in every SEED frame so remote
/// workers run the driver's flush policy instead of a default).
pub fn encode_policy_into(policy: &FlushPolicy, buf: &mut Vec<u8>) {
    put_u64(buf, policy.threshold as u64);
    put_u8(buf, u8::from(policy.adaptive));
    put_u64(buf, policy.min as u64);
    put_u64(buf, policy.max as u64);
}

/// Decode a [`FlushPolicy`] produced by [`encode_policy_into`].
pub fn decode_policy(input: &mut &[u8]) -> Result<FlushPolicy, WireError> {
    let threshold = get_u64(input)? as usize;
    let adaptive = match get_u8(input)? {
        0 => false,
        1 => true,
        other => {
            return Err(invalid(format!("bad policy adaptive byte {other}")))
        }
    };
    let min = get_u64(input)? as usize;
    let max = get_u64(input)? as usize;
    Ok(FlushPolicy {
        threshold,
        adaptive,
        min,
        max,
    })
}

/// Encode the shared `(p, seed)` sketch config.
pub fn encode_config_into(config: &HllConfig, buf: &mut Vec<u8>) {
    put_u8(buf, config.p());
    put_u64(buf, config.hasher().seed());
}

/// Decode a config written by [`encode_config_into`] (validates `p`).
pub fn decode_config(input: &mut &[u8]) -> Result<HllConfig, WireError> {
    let p = get_u8(input)?;
    if !(4..=16).contains(&p) {
        return Err(invalid(format!("config p {p} out of range")));
    }
    let seed = get_u64(input)?;
    Ok(HllConfig::new(p, seed))
}

/// Encode an edge partition (a rank's substream σ_P).
pub fn encode_edges_into(edges: &[(u64, u64)], buf: &mut Vec<u8>) {
    put_u64(buf, edges.len() as u64);
    for &(u, v) in edges {
        put_u64(buf, u);
        put_u64(buf, v);
    }
}

/// Decode an edge partition written by [`encode_edges_into`].
pub fn decode_edges(input: &mut &[u8]) -> Result<Vec<(u64, u64)>, WireError> {
    let n = get_u64(input)? as usize;
    // cap the pre-allocation: `n` is attacker-controlled until the
    // loop actually yields that many edges
    let mut edges = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        edges.push((get_u64(input)?, get_u64(input)?));
    }
    Ok(edges)
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// `"DSKF"` read as a little-endian u32.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"DSKF");
/// Fixed frame header length in bytes.
pub const FRAME_HEADER_LEN: usize = 28;
/// Upper bound on a single frame payload (sanity guard against a
/// corrupted length field committing us to a gigantic read).
pub const MAX_FRAME_PAYLOAD: usize = 1 << 30;

/// A decoded frame, borrowing its payload from the input buffer.
///
/// Header layout (little-endian, 28 bytes):
/// ```text
/// [0..4)   magic   "DSKF"
/// [4]      kind    transport-defined discriminator
/// [5]      pad     must be zero
/// [6..8)   gen     epoch qualifier: the recovery generation the frame
///                  was sent in. Non-resilient epochs always stamp 0.
///                  After a checkpoint rollback, stale frames from an
///                  older generation are identified (and discarded) by
///                  this field instead of colliding with the resumed
///                  channel's token sequence.
/// [8..12)  count   messages in the payload (0 for raw frames)
/// [12..16) len     payload bytes
/// [16..24) token   cumulative per-channel message counter — the
///                  termination token the quiescence protocol reads.
///                  Token arithmetic is defined **wrapping** mod 2^64:
///                  validation compares `recv_seq.wrapping_add(count)`,
///                  so an arbitrarily long (resumable) epoch crossing the
///                  counter boundary stays consistent.
/// [24..28) crc     CRC-32 over header bytes [0..24) ++ payload
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Frame<'a> {
    pub kind: u8,
    pub gen: u16,
    pub count: u32,
    pub token: u64,
    pub payload: &'a [u8],
}

/// Header (including the CRC, which covers header bytes `[0..24)` ++
/// payload) for a frame whose payload will be written separately —
/// multi-megabyte payloads (SEED frames carrying whole stores) ship as
/// header-then-payload without being copied into one buffer first.
pub fn encode_frame_header(
    kind: u8,
    count: u32,
    token: u64,
    payload: &[u8],
) -> [u8; FRAME_HEADER_LEN] {
    encode_frame_header_gen(kind, 0, count, token, payload)
}

/// [`encode_frame_header`] with an explicit generation qualifier (see the
/// [`Frame`] header docs). Control and rendezvous frames stamp 0; MSGS
/// frames on a resilient epoch stamp the current recovery generation.
pub fn encode_frame_header_gen(
    kind: u8,
    gen: u16,
    count: u32,
    token: u64,
    payload: &[u8],
) -> [u8; FRAME_HEADER_LEN] {
    assert!(payload.len() <= MAX_FRAME_PAYLOAD, "oversized frame");
    let mut head = [0u8; FRAME_HEADER_LEN];
    head[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    head[4] = kind;
    // [5] pad stays zero
    head[6..8].copy_from_slice(&gen.to_le_bytes());
    head[8..12].copy_from_slice(&count.to_le_bytes());
    head[12..16].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[16..24].copy_from_slice(&token.to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&head[..24]);
    crc.update(payload);
    head[24..28].copy_from_slice(&crc.finish().to_le_bytes());
    head
}

/// Append one framed payload to `out`.
pub fn encode_frame_into(
    kind: u8,
    count: u32,
    token: u64,
    payload: &[u8],
    out: &mut Vec<u8>,
) {
    let head = encode_frame_header(kind, count, token, payload);
    out.extend_from_slice(&head);
    out.extend_from_slice(payload);
}

/// [`encode_frame_into`] with an explicit generation qualifier.
pub fn encode_frame_into_gen(
    kind: u8,
    gen: u16,
    count: u32,
    token: u64,
    payload: &[u8],
    out: &mut Vec<u8>,
) {
    let head = encode_frame_header_gen(kind, gen, count, token, payload);
    out.extend_from_slice(&head);
    out.extend_from_slice(payload);
}

/// Total length of the frame at the head of `buf`, once the header is
/// readable: `Ok(None)` means "need more bytes", errors mean the stream
/// is unrecoverably corrupt (bad magic / absurd length).
pub fn frame_len(buf: &[u8]) -> Result<Option<usize>, WireError> {
    if buf.len() < FRAME_HEADER_LEN {
        return Ok(None);
    }
    let magic = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != FRAME_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let len = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(invalid(format!("frame payload length {len} too large")));
    }
    Ok(Some(FRAME_HEADER_LEN + len))
}

/// Decode (and CRC-check) one frame off the front of `input`, advancing
/// it past the frame. `Err(Truncated)` if the frame is incomplete.
pub fn decode_frame<'a>(input: &mut &'a [u8]) -> Result<Frame<'a>, WireError> {
    let total = frame_len(input)?.ok_or(WireError::Truncated)?;
    if input.len() < total {
        return Err(WireError::Truncated);
    }
    let head = &input[..FRAME_HEADER_LEN];
    if head[5] != 0 {
        return Err(invalid("nonzero header pad"));
    }
    let kind = head[4];
    let gen = u16::from_le_bytes([head[6], head[7]]);
    let count = u32::from_le_bytes([head[8], head[9], head[10], head[11]]);
    let token = u64::from_le_bytes([
        head[16], head[17], head[18], head[19], head[20], head[21], head[22],
        head[23],
    ]);
    let stored = u32::from_le_bytes([head[24], head[25], head[26], head[27]]);
    let payload = &input[FRAME_HEADER_LEN..total];
    let mut crc = Crc32::new();
    crc.update(&head[..24]);
    crc.update(payload);
    let actual = crc.finish();
    if actual != stored {
        return Err(WireError::BadCrc { stored, actual });
    }
    *input = &input[total..];
    Ok(Frame {
        kind,
        gen,
        count,
        token,
        payload,
    })
}

/// Encode a batch of messages as one frame. `scratch` is a reusable
/// payload buffer (cleared here) so steady-state framing allocates
/// nothing.
pub fn encode_msg_frame<M: WireMsg>(
    kind: u8,
    token: u64,
    msgs: &[M],
    scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    encode_msg_frame_gen(kind, 0, token, msgs, scratch, out);
}

/// [`encode_msg_frame`] stamping an explicit generation qualifier.
pub fn encode_msg_frame_gen<M: WireMsg>(
    kind: u8,
    gen: u16,
    token: u64,
    msgs: &[M],
    scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) {
    scratch.clear();
    for m in msgs {
        m.encode_into(scratch);
    }
    encode_frame_into_gen(kind, gen, msgs.len() as u32, token, scratch, out);
}

/// Decode the `count` messages carried by a frame's payload. The payload
/// must be consumed exactly — trailing bytes are rejected.
pub fn decode_msgs<M: WireMsg>(frame: &Frame<'_>) -> Result<Vec<M>, WireError> {
    let mut p = frame.payload;
    // cap the pre-allocation: `count` is attacker-controlled until the
    // decode loop below actually produces that many messages
    let mut out = Vec::with_capacity((frame.count as usize).min(1 << 16));
    for _ in 0..frame.count {
        out.push(M::decode(&mut p)?);
    }
    if !p.is_empty() {
        return Err(invalid(format!(
            "{} trailing payload bytes after {} messages",
            p.len(),
            frame.count
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    fn random_hll(rng: &mut crate::hash::Xoshiro256ss, p: u8) -> Hll {
        let mut h = Hll::new(HllConfig::new(p, rng.next_u64()));
        for _ in 0..rng.next_below(2000) {
            h.insert(rng.next_u64());
        }
        h
    }

    #[test]
    fn edge_batches_round_trip() {
        Cases::new("codec_edge_roundtrip", 30).run(|rng| {
            let msgs: Vec<(u64, u64)> = (0..rng.next_below(200))
                .map(|_| (rng.next_u64(), rng.next_u64()))
                .collect();
            let token = rng.next_u64();
            let (mut scratch, mut wire) = (Vec::new(), Vec::new());
            encode_msg_frame(0, token, &msgs, &mut scratch, &mut wire);
            let mut input = wire.as_slice();
            let frame = decode_frame(&mut input).unwrap();
            assert!(input.is_empty());
            assert_eq!(frame.token, token);
            assert_eq!(frame.count as usize, msgs.len());
            let back: Vec<(u64, u64)> = decode_msgs(&frame).unwrap();
            assert_eq!(back, msgs);
        });
    }

    #[test]
    fn hll_round_trips_sparse_and_dense() {
        Cases::new("codec_hll_roundtrip", 30).run(|rng| {
            let p = 6 + (rng.next_below(7) as u8); // 6..=12
            let h = random_hll(rng, p);
            let mut buf = Vec::new();
            encode_hll_into(&h, &mut buf);
            let mut input = buf.as_slice();
            let back = decode_hll(&mut input).unwrap();
            assert!(input.is_empty());
            assert_eq!(h, back, "p={p} dense={}", h.is_dense());
        });
    }

    #[test]
    fn hll_rejects_truncation() {
        let mut rng = crate::hash::Xoshiro256ss::new(7);
        for _ in 0..8 {
            let h = random_hll(&mut rng, 8);
            let mut buf = Vec::new();
            encode_hll_into(&h, &mut buf);
            for cut in [0, 1, 5, buf.len() / 2, buf.len() - 1] {
                let mut input = &buf[..cut];
                assert!(decode_hll(&mut input).is_err(), "cut at {cut}");
            }
        }
    }

    #[test]
    fn store_state_round_trips_representation_identically() {
        Cases::new("codec_store_roundtrip", 10).run(|rng| {
            let config = HllConfig::new(6, 0xC0DE); // r = 64: saturation happens
            let mut store = SketchStore::new(config);
            for _ in 0..rng.next_below(3000) {
                store.insert_element(rng.next_below(40), rng.next_u64());
            }
            let mut buf = Vec::new();
            encode_store_into(&store, &mut buf);
            let mut input = buf.as_slice();
            let back = decode_store(config, &mut input).unwrap();
            assert!(input.is_empty());
            assert_eq!(store.len(), back.len());
            assert_eq!(store.dense_count(), back.dense_count());
            for v in store.vertices_sorted() {
                assert_eq!(store.to_hll(v), back.to_hll(v), "vertex {v}");
            }
        });
    }

    #[test]
    fn frame_rejects_any_single_byte_corruption() {
        let msgs: Vec<(u64, u64)> = (0..17).map(|i| (i, i * 31)).collect();
        let (mut scratch, mut wire) = (Vec::new(), Vec::new());
        encode_msg_frame(3, 99, &msgs, &mut scratch, &mut wire);
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x40;
            let mut input = bad.as_slice();
            let outcome = decode_frame(&mut input)
                .and_then(|f| decode_msgs::<(u64, u64)>(&f).map(|_| ()));
            // flipping count/len may also surface as Truncated — any error
            // is a rejection; silent acceptance is the failure mode
            assert!(outcome.is_err(), "corrupt byte {i} accepted");
        }
    }

    #[test]
    fn frame_rejects_truncation() {
        let msgs = vec![(1u64, 2u64), (3, 4)];
        let (mut scratch, mut wire) = (Vec::new(), Vec::new());
        encode_msg_frame(0, 5, &msgs, &mut scratch, &mut wire);
        for cut in 0..wire.len() {
            let mut input = &wire[..cut];
            match decode_frame(&mut input) {
                Err(_) => {}
                Ok(_) => panic!("truncation at {cut} accepted"),
            }
        }
    }

    #[test]
    fn frame_len_streams_incrementally() {
        let msgs = vec![(10u64, 20u64)];
        let (mut scratch, mut wire) = (Vec::new(), Vec::new());
        encode_msg_frame(1, 42, &msgs, &mut scratch, &mut wire);
        for have in 0..FRAME_HEADER_LEN {
            assert_eq!(frame_len(&wire[..have]).unwrap(), None);
        }
        assert_eq!(frame_len(&wire).unwrap(), Some(wire.len()));
        assert!(frame_len(b"XXXXmore bytes follow here..1234567890").is_err());
    }

    #[test]
    fn sketch_view_decode_matches_owned_decode() {
        // the borrowed view path must be observationally identical to
        // the owned decode, aligned or not
        Cases::new("codec_view_parity", 30).run(|rng| {
            let p = 6 + (rng.next_below(7) as u8);
            let h = random_hll(rng, p);
            let mut buf = vec![0u8; rng.next_below(2) as usize]; // 0/1 pad
            let pad = buf.len();
            encode_hll_into(&h, &mut buf);

            let mut owned_in = &buf[pad..];
            let owned = decode_hll(&mut owned_in).unwrap();
            assert_eq!(owned, h);

            let mut view_in = &buf[pad..];
            let view = decode_sketch_view(&mut view_in).unwrap();
            assert!(view_in.is_empty());
            assert_eq!(view.config(), *h.config());
            assert_eq!(view.to_hll(), h, "pad={pad}");

            // merging the view into a store equals merging the sketch
            let mut a = SketchStore::new(*h.config());
            let mut b = SketchStore::new(*h.config());
            view.merge_into(&mut a, 7);
            b.merge_hll(7, &h);
            assert_eq!(a.to_hll(7), b.to_hll(7));
        });
    }

    #[test]
    fn sketch_view_borrows_when_aligned() {
        // on a matching-ABI LE host, 2-aligned sparse records must come
        // back borrowed; the 1-byte-shifted decode must still be correct
        if !pair_abi_matches() {
            return; // exotic host: owned fallback everywhere, covered above
        }
        let config = HllConfig::new(10, 0xA11);
        let mut h = Hll::new(config);
        for i in 0..20u64 {
            h.insert(i.wrapping_mul(0x9E3779B97F4A7C15));
        }
        assert!(!h.is_dense());
        for pad in [0usize, 1] {
            let mut buf = vec![0u8; pad];
            encode_hll_into(&h, &mut buf);
            let mut input = &buf[pad..];
            let view = decode_sketch_view(&mut input).unwrap();
            let SketchView::Sparse { pairs, .. } = &view else {
                panic!("sparse sketch must decode sparse");
            };
            // records start at pad + tag(1) + p(1) + seed(8) + count(4)
            let rec_off = pad + 14;
            let aligned = (buf[rec_off..].as_ptr() as usize) % 2 == 0;
            match pairs {
                PairRun::Borrowed(_) => assert!(aligned, "pad={pad}"),
                PairRun::Owned(_) => assert!(!aligned, "pad={pad}"),
            }
            assert_eq!(view.to_hll(), h, "pad={pad}");
        }
    }

    #[test]
    fn policy_config_and_edges_round_trip() {
        let policy = FlushPolicy {
            threshold: 513,
            adaptive: true,
            min: 3,
            max: 9999,
        };
        let mut buf = Vec::new();
        encode_policy_into(&policy, &mut buf);
        let config = HllConfig::new(11, 0xFACE);
        encode_config_into(&config, &mut buf);
        let edges = vec![(1u64, 2u64), (3, 4), (u64::MAX, 0)];
        encode_edges_into(&edges, &mut buf);
        let mut input = buf.as_slice();
        assert_eq!(decode_policy(&mut input).unwrap(), policy);
        assert_eq!(decode_config(&mut input).unwrap(), config);
        assert_eq!(decode_edges(&mut input).unwrap(), edges);
        assert!(input.is_empty());
        // truncations reject
        for cut in 0..buf.len() {
            let mut short = &buf[..cut];
            let outcome = decode_policy(&mut short)
                .and_then(|_| decode_config(&mut short))
                .and_then(|_| decode_edges(&mut short).map(|_| ()));
            assert!(outcome.is_err(), "cut {cut} accepted");
        }
    }

    #[test]
    fn generation_qualifier_round_trips_and_is_zero_for_legacy() {
        let (mut scratch, mut wire) = (Vec::new(), Vec::new());
        encode_msg_frame(0, 5, &[(1u64, 2u64)], &mut scratch, &mut wire);
        let mut input = wire.as_slice();
        assert_eq!(decode_frame(&mut input).unwrap().gen, 0);
        let mut wire2 = Vec::new();
        encode_msg_frame_gen(0, 7, 5, &[(1u64, 2u64)], &mut scratch, &mut wire2);
        let mut input = wire2.as_slice();
        let f = decode_frame(&mut input).unwrap();
        assert_eq!((f.gen, f.token, f.count), (7, 5, 1));
        // the gen field is covered by the frame CRC
        let mut bad = wire2.clone();
        bad[6] ^= 1;
        assert!(decode_frame(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn two_frames_decode_back_to_back() {
        let (mut scratch, mut wire) = (Vec::new(), Vec::new());
        encode_msg_frame(0, 1, &[(1u64, 2u64)], &mut scratch, &mut wire);
        encode_frame_into(7, 0, 9, b"raw payload", &mut wire);
        let mut input = wire.as_slice();
        let a = decode_frame(&mut input).unwrap();
        assert_eq!(a.count, 1);
        let b = decode_frame(&mut input).unwrap();
        assert_eq!((b.kind, b.token, b.payload), (7, 9, &b"raw payload"[..]));
        assert!(input.is_empty());
    }
}
