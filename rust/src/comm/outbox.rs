//! Per-destination buffered send queues — the `S[P]` of the paper — plus
//! **layer 3 of the comm plane: the flush policy**.
//!
//! Each destination has its own flush threshold, seeded from a
//! [`FlushPolicy`] and (when `adaptive` is on) steered per destination by
//! observed traffic:
//!
//! * **grow under pressure** — every time a destination's buffer crosses
//!   its threshold between drains (an *eager* flush), the threshold
//!   doubles (capped at `policy.max`): heavy lanes amortize framing and
//!   channel overhead over bigger batches;
//! * **shrink when drains lag** — when a *forced* drain (end of context,
//!   idle round, scheduler timeout) finds a buffer sitting below half its
//!   threshold, the threshold halves (floored at `policy.min`): the lane
//!   never reaches its threshold, so waiting for it only adds latency.
//!
//! Thresholds only move at drain points, when the affected buffer is
//! empty, so the `len == threshold` crossing detection in [`Outbox::send`]
//! stays exact. Pin the policy (`adaptive = false`, or
//! [`FlushPolicy::pinned`]) for deterministic flush counts in benches.

/// Flush-threshold policy for one epoch (layer 3 of the comm plane).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlushPolicy {
    /// Initial per-destination threshold (messages buffered before an
    /// eager flush).
    pub threshold: usize,
    /// Adapt thresholds per destination (see module docs). When `false`
    /// the threshold is pinned — the deterministic-bench escape hatch.
    pub adaptive: bool,
    /// Lower bound an adaptive threshold can shrink to.
    pub min: usize,
    /// Upper bound an adaptive threshold can grow to.
    pub max: usize,
}

impl Default for FlushPolicy {
    fn default() -> Self {
        Self {
            threshold: 1024,
            adaptive: true,
            min: 64,
            max: 16384,
        }
    }
}

impl FlushPolicy {
    /// A fixed threshold: no adaptation, ever.
    pub fn pinned(threshold: usize) -> Self {
        Self {
            threshold,
            adaptive: false,
            min: threshold,
            max: threshold,
        }
    }

    /// Start at `threshold` with adaptation on (default bounds, clamped
    /// so `min <= threshold <= max`).
    pub fn adaptive(threshold: usize) -> Self {
        let d = Self::default();
        Self {
            threshold,
            adaptive: true,
            min: d.min.min(threshold),
            max: d.max.max(threshold),
        }
    }

    /// The sequential scheduler's policy: buffers are drained after every
    /// context, so eager flushing (and adaptation) is pointless — and
    /// keeping it off keeps the backend bit-deterministic.
    pub(crate) fn unbounded() -> Self {
        Self::pinned(usize::MAX)
    }

    /// **Warm start**: derive per-destination threshold seeds for the
    /// next epoch from a finished epoch's [`CommStats`] — the observed
    /// mean batch size toward each rank (messages/flushes, bounded to
    /// `[min, max]`). Epoch N+1's outboxes start from what epoch N
    /// learned instead of re-learning from `threshold` (destinations
    /// with no recorded traffic keep the default). Only meaningful for
    /// adaptive policies; [`Outbox::with_seeds`] ignores seeds when the
    /// policy is pinned.
    pub fn seeds_from_stats(&self, stats: &super::CommStats) -> Vec<usize> {
        stats
            .per_rank
            .iter()
            .map(|r| {
                if r.flushes == 0 {
                    self.threshold
                } else {
                    (r.messages.div_ceil(r.flushes) as usize)
                        .max(self.min)
                        .min(self.max)
                }
            })
            .collect()
    }
}

/// Buffered sends from one rank. The scheduler drains it after each
/// context runs; eager backends additionally flush buffers that cross
/// their per-destination threshold mid-context to bound memory.
pub struct Outbox<M> {
    bufs: Vec<Vec<M>>,
    sent: u64,
    policy: FlushPolicy,
    /// Live per-destination thresholds (start at `policy.threshold`).
    thresholds: Vec<usize>,
    /// Destinations whose buffer crossed the threshold (eager backends
    /// drain these mid-context).
    hot: Vec<usize>,
}

impl<M> Outbox<M> {
    pub(crate) fn new(ranks: usize, policy: FlushPolicy) -> Self {
        Self {
            bufs: (0..ranks).map(|_| Vec::new()).collect(),
            sent: 0,
            policy,
            thresholds: vec![policy.threshold; ranks],
            hot: Vec::new(),
        }
    }

    /// [`Outbox::new`] with warm-start threshold seeds (one per
    /// destination, from [`FlushPolicy::seeds_from_stats`]). Seeds are
    /// applied only when the policy is adaptive and the vector matches
    /// the rank count; they are bounded to `[policy.min, policy.max]`.
    pub(crate) fn with_seeds(
        ranks: usize,
        policy: FlushPolicy,
        seeds: &[usize],
    ) -> Self {
        let mut out = Self::new(ranks, policy);
        if policy.adaptive && seeds.len() == ranks {
            for (t, &s) in out.thresholds.iter_mut().zip(seeds) {
                *t = s.max(policy.min).min(policy.max);
            }
        }
        out
    }

    /// Number of ranks addressable from this outbox.
    pub fn num_ranks(&self) -> usize {
        self.bufs.len()
    }

    /// Queue `msg` for delivery to `to`.
    #[inline]
    pub fn send(&mut self, to: usize, msg: M) {
        let buf = &mut self.bufs[to];
        buf.push(msg);
        self.sent += 1;
        if buf.len() == self.thresholds[to] {
            self.hot.push(to);
        }
    }

    /// Total messages ever queued through this outbox.
    pub fn total_sent(&self) -> u64 {
        self.sent
    }

    /// The live flush threshold for `to` (moves when adaptive).
    pub fn threshold_of(&self, to: usize) -> usize {
        self.thresholds[to]
    }

    pub(crate) fn take_hot(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.hot)
    }

    /// Take `to`'s buffer for an *eager* (threshold-crossing) flush and
    /// apply the pressure rule: the lane is hot, so grow its threshold.
    pub(crate) fn take_buf_eager(&mut self, to: usize) -> Vec<M> {
        let buf = std::mem::take(&mut self.bufs[to]);
        if self.policy.adaptive && !buf.is_empty() {
            let t = &mut self.thresholds[to];
            let grown = t.saturating_mul(2).min(self.policy.max);
            if grown != *t {
                crate::telemetry::count("degreesketch_flush_grow_total", 1);
                if crate::telemetry::enabled() {
                    crate::telemetry::event(
                        "flush.grow",
                        &[("channel", to as u64), ("threshold", grown as u64)],
                    );
                }
            }
            *t = grown;
        }
        buf
    }

    /// Drain all buffers as `(destination, batch)` pairs — a *forced*
    /// drain (end of context / idle round / timeout). Lanes that never
    /// reached half their threshold get it halved: their drains lag their
    /// sends, so a smaller batch ships sooner next time.
    pub(crate) fn drain_all(&mut self) -> Vec<(usize, Vec<M>)> {
        self.hot.clear();
        let adaptive = self.policy.adaptive;
        let min = self.policy.min;
        let thresholds = &mut self.thresholds;
        self.bufs
            .iter_mut()
            .enumerate()
            .filter(|(_, b)| !b.is_empty())
            .map(|(to, b)| {
                if adaptive && b.len() < thresholds[to] / 2 {
                    let shrunk = (thresholds[to] / 2).max(min);
                    if shrunk != thresholds[to] {
                        crate::telemetry::count("degreesketch_flush_shrink_total", 1);
                        if crate::telemetry::enabled() {
                            crate::telemetry::event(
                                "flush.shrink",
                                &[("channel", to as u64), ("threshold", shrunk as u64)],
                            );
                        }
                    }
                    thresholds[to] = shrunk;
                }
                (to, std::mem::take(b))
            })
            .collect()
    }

    #[cfg(test)]
    pub(crate) fn is_empty(&self) -> bool {
        self.bufs.iter().all(|b| b.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_per_destination() {
        let mut out: Outbox<u32> = Outbox::new(3, FlushPolicy::default());
        out.send(0, 1);
        out.send(2, 2);
        out.send(2, 3);
        assert_eq!(out.total_sent(), 3);
        let drained = out.drain_all();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], (0, vec![1]));
        assert_eq!(drained[1], (2, vec![2, 3]));
        assert!(out.is_empty());
    }

    #[test]
    fn hot_marks_threshold_crossing() {
        let mut out: Outbox<u32> = Outbox::new(2, FlushPolicy::pinned(3));
        for i in 0..3 {
            out.send(1, i);
        }
        assert_eq!(out.take_hot(), vec![1]);
        assert_eq!(out.take_buf_eager(1).len(), 3);
        // pinned: no growth
        assert_eq!(out.threshold_of(1), 3);
    }

    #[test]
    fn pressure_grows_only_the_hot_lane() {
        let policy = FlushPolicy {
            threshold: 4,
            adaptive: true,
            min: 2,
            max: 64,
        };
        let mut out: Outbox<u32> = Outbox::new(3, policy);
        for round in 0..3 {
            for i in 0..out.threshold_of(1) {
                out.send(1, i as u32);
            }
            assert_eq!(out.take_hot(), vec![1], "round {round}");
            out.take_buf_eager(1);
        }
        assert_eq!(out.threshold_of(1), 32); // 4 → 8 → 16 → 32
        assert_eq!(out.threshold_of(0), 4);
        assert_eq!(out.threshold_of(2), 4);
    }

    #[test]
    fn growth_caps_at_policy_max() {
        let policy = FlushPolicy {
            threshold: 4,
            adaptive: true,
            min: 2,
            max: 8,
        };
        let mut out: Outbox<u32> = Outbox::new(1, policy);
        for _ in 0..5 {
            let t = out.threshold_of(0);
            for i in 0..t {
                out.send(0, i as u32);
            }
            out.take_hot();
            out.take_buf_eager(0);
        }
        assert_eq!(out.threshold_of(0), 8);
    }

    #[test]
    fn lagging_drains_shrink_toward_min() {
        let policy = FlushPolicy {
            threshold: 16,
            adaptive: true,
            min: 4,
            max: 64,
        };
        let mut out: Outbox<u32> = Outbox::new(2, policy);
        // destination 0 trickles (1 message per forced drain): shrink
        for _ in 0..4 {
            out.send(0, 9);
            out.drain_all();
        }
        assert_eq!(out.threshold_of(0), 4); // 16 → 8 → 4 → 4 (floored)
        // destination 1 drains at >= half threshold: stable
        for _ in 0..3 {
            for i in 0..10 {
                out.send(1, i);
            }
            out.drain_all();
        }
        assert_eq!(out.threshold_of(1), 16);
    }

    #[test]
    fn warm_start_seeds_thresholds_within_bounds() {
        use crate::comm::{Backend, CommStats, RankStats};
        let policy = FlushPolicy {
            threshold: 16,
            adaptive: true,
            min: 4,
            max: 64,
        };
        let mut stats = CommStats::new(Backend::Threaded, 4);
        stats.per_rank[0] = RankStats {
            messages: 1000,
            bytes: 0,
            flushes: 10,
        }; // mean 100 → capped at 64
        stats.per_rank[1] = RankStats {
            messages: 7,
            bytes: 0,
            flushes: 6,
        }; // mean 2 → floored at 4
        stats.per_rank[2] = RankStats {
            messages: 90,
            bytes: 0,
            flushes: 9,
        }; // mean 10
           // rank 3: no traffic → default threshold
        let seeds = policy.seeds_from_stats(&stats);
        assert_eq!(seeds, vec![64, 4, 10, 16]);

        let out: Outbox<u32> = Outbox::with_seeds(4, policy, &seeds);
        for (d, want) in [(0, 64), (1, 4), (2, 10), (3, 16)] {
            assert_eq!(out.threshold_of(d), want, "dest {d}");
        }
        // pinned policies ignore seeds entirely
        let pinned: Outbox<u32> =
            Outbox::with_seeds(4, FlushPolicy::pinned(8), &seeds);
        for d in 0..4 {
            assert_eq!(pinned.threshold_of(d), 8);
        }
        // a mismatched seed vector is ignored, not misapplied
        let mismatched: Outbox<u32> = Outbox::with_seeds(4, policy, &[1, 2]);
        for d in 0..4 {
            assert_eq!(mismatched.threshold_of(d), 16);
        }
    }

    #[test]
    fn adaptation_off_pins_thresholds() {
        let mut out: Outbox<u32> = Outbox::new(1, FlushPolicy::pinned(4));
        for _ in 0..3 {
            for i in 0..4 {
                out.send(0, i);
            }
            out.take_hot();
            out.take_buf_eager(0);
            out.send(0, 0);
            out.drain_all();
        }
        assert_eq!(out.threshold_of(0), 4);
    }
}
