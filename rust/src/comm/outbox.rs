//! Per-destination buffered send queues — the `S[P]` of the paper.

/// Buffered sends from one rank. The scheduler drains it after each
/// context runs; the threaded backend additionally flushes buffers that
/// exceed [`Outbox::flush_threshold`] mid-context to bound memory.
pub struct Outbox<M> {
    bufs: Vec<Vec<M>>,
    sent: u64,
    flush_threshold: usize,
    /// Destinations whose buffer crossed the threshold (threaded backend
    /// drains these eagerly).
    hot: Vec<usize>,
}

impl<M> Outbox<M> {
    pub(crate) fn new(ranks: usize, flush_threshold: usize) -> Self {
        Self {
            bufs: (0..ranks).map(|_| Vec::new()).collect(),
            sent: 0,
            flush_threshold,
            hot: Vec::new(),
        }
    }

    /// Number of ranks addressable from this outbox.
    pub fn num_ranks(&self) -> usize {
        self.bufs.len()
    }

    /// Queue `msg` for delivery to `to`.
    #[inline]
    pub fn send(&mut self, to: usize, msg: M) {
        let buf = &mut self.bufs[to];
        buf.push(msg);
        self.sent += 1;
        if buf.len() == self.flush_threshold {
            self.hot.push(to);
        }
    }

    /// Total messages ever queued through this outbox.
    pub fn total_sent(&self) -> u64 {
        self.sent
    }

    pub(crate) fn take_hot(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.hot)
    }

    pub(crate) fn take_buf(&mut self, to: usize) -> Vec<M> {
        std::mem::take(&mut self.bufs[to])
    }

    /// Drain all buffers as `(destination, batch)` pairs.
    pub(crate) fn drain_all(&mut self) -> Vec<(usize, Vec<M>)> {
        self.hot.clear();
        self.bufs
            .iter_mut()
            .enumerate()
            .filter(|(_, b)| !b.is_empty())
            .map(|(to, b)| (to, std::mem::take(b)))
            .collect()
    }

    #[cfg(test)]
    pub(crate) fn is_empty(&self) -> bool {
        self.bufs.iter().all(|b| b.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_per_destination() {
        let mut out: Outbox<u32> = Outbox::new(3, 1024);
        out.send(0, 1);
        out.send(2, 2);
        out.send(2, 3);
        assert_eq!(out.total_sent(), 3);
        let drained = out.drain_all();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], (0, vec![1]));
        assert_eq!(drained[1], (2, vec![2, 3]));
        assert!(out.is_empty());
    }

    #[test]
    fn hot_marks_threshold_crossing() {
        let mut out: Outbox<u32> = Outbox::new(2, 3);
        for i in 0..3 {
            out.send(1, i);
        }
        assert_eq!(out.take_hot(), vec![1]);
        assert_eq!(out.take_buf(1).len(), 3);
    }
}
