//! **Socket-generic framed worker loop** — the one implementation of
//! buffered non-blocking framed IO, per-channel token validation, SEED
//! shipping, and the two-wave counter termination protocol that both
//! socket backends run on. [`super::process`] instantiates it over
//! `UnixStream`s between forked workers; [`super::tcp`] instantiates the
//! exact same code over `TcpStream`s between hosts. There is no second
//! copy of the framing or termination logic anywhere.
//!
//! Split of responsibilities:
//!
//! * [`Conn`] — one buffered non-blocking framed connection: inbound
//!   byte buffer with a frame-parse cursor, outbound pending-write queue
//!   (a worker never blocks on a write while a peer is blocked writing to
//!   *it* — the classic all-to-all deadlock cannot form).
//! * [`PeerConn`] — a mesh connection plus the channel's cumulative
//!   send/receive message counters (the termination tokens stamped into
//!   and validated against every MSGS frame).
//! * [`SocketTransport`] — the [`Transport`] a worker's outbox flushes
//!   into: rank-local batches short-circuit through an in-process queue,
//!   remote batches are framed and queued on the peer connection.
//! * [`worker_epoch`] — the worker side of one epoch: decode the actor
//!   from its SEED payload ([`FabricActor::read_seed`] — inputs arrive
//!   over the wire, never through fork copy-on-write), run the message
//!   loop to Stop, ship the result state back in a STATE frame.
//! * [`DriverCtrl`] + [`drive_to_stop`] + [`collect_state`] — the driver
//!   side: blocking framed control channels with per-step deadlines (a
//!   [`Liveness`] hook decides whether an expired deadline re-arms — the
//!   process backend checks `waitpid`, the tcp backend fails fast with a
//!   clear timeout), probe waves to quiescence, idle rounds, Stop, and
//!   result-state collection.
//!
//! Termination (two-wave counter protocol): the driver polls every
//! worker with PROBE frames; each worker replies with its monotone
//! `(sent, delivered)` totals. When `Σsent == Σdelivered` for two
//! consecutive waves with unchanged totals, there was a real instant
//! between the waves at which every channel was empty and every worker
//! idle — no message existed anywhere, so none can ever be sent again
//! without driver action. The driver then runs a global idle round
//! (IDLE → `on_idle` → flush → ack), re-probes to quiescence, and stops
//! once an idle round produces no new sends — the exact epoch semantics
//! of the sequential and threaded schedulers.

#![allow(clippy::type_complexity)]

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

use super::codec::{
    decode_frame, decode_msgs, decode_policy, encode_frame_into,
    encode_msg_frame, encode_policy_into, frame_len, get_u32, get_u64,
    put_u32, put_u64, put_u8, WireError, WireMsg, FRAME_HEADER_LEN,
};
use super::outbox::FlushPolicy;
use super::transport::{flush_outbox, Transport};
use super::{CommStats, FabricActor, Outbox, RankStats, WireActor};

/// Frame kinds on the wire (mesh, control, and rendezvous channels).
pub(crate) mod kind {
    /// Peer → peer: a batch of application messages.
    pub const MSGS: u8 = 0;
    /// Driver → worker: report your counters (token = wave id).
    pub const PROBE: u8 = 1;
    /// Worker → driver: `[sent, delivered]` (token echoes the wave id).
    pub const REPORT: u8 = 2;
    /// Driver → worker: run `on_idle`, flush, then report.
    pub const IDLE: u8 = 3;
    /// Driver → worker: serialize state and finish the epoch.
    pub const STOP: u8 = 4;
    /// Worker → driver: final `[delivered, bytes_in, frames_in, sent]`
    /// followed by the actor state bytes.
    pub const STATE: u8 = 5;
    /// Driver → worker: epoch inputs — actor kind, flush policy,
    /// warm-start seeds, and the [`FabricActor::write_seed`] bytes.
    pub const SEED: u8 = 6;
    /// Worker → registrar: "I am rank `token`" (tcp rendezvous step 1).
    pub const JOIN: u8 = 7;
    /// Registrar → worker: the full `rank → host:port` map.
    pub const WELCOME: u8 = 8;
    /// Worker → registrar: "listener bound at <payload addr>".
    pub const BOUND: u8 = 9;
    /// Registrar → worker: final map — go form the mesh.
    pub const MESH: u8 = 10;
    /// Dialing worker → accepting worker: "I am rank `token`".
    pub const HELLO: u8 = 11;
    /// Worker → registrar: mesh complete, ready for epochs.
    pub const MESHED: u8 = 12;
    /// Driver → worker: no more epochs, exit cleanly.
    pub const SHUTDOWN: u8 = 13;
}

/// How long a blocked control-channel read may go silent before the
/// driver consults its [`Liveness`] hook. Generous: CI machines stall.
pub(crate) const CTRL_DEADLINE: Duration = Duration::from_secs(120);

/// The stream capabilities the socket loop needs — implemented by
/// `UnixStream` (process backend) and `TcpStream` (tcp backend).
pub(crate) trait SocketLike: Read + Write + Send {
    fn set_nonblocking_mode(&self, nonblocking: bool) -> std::io::Result<()>;
    fn set_read_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()>;
    fn set_write_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()>;
}

#[cfg(unix)]
impl SocketLike for std::os::unix::net::UnixStream {
    fn set_nonblocking_mode(&self, nonblocking: bool) -> std::io::Result<()> {
        self.set_nonblocking(nonblocking)
    }

    fn set_read_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        self.set_read_timeout(timeout)
    }

    fn set_write_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        self.set_write_timeout(timeout)
    }
}

impl SocketLike for std::net::TcpStream {
    fn set_nonblocking_mode(&self, nonblocking: bool) -> std::io::Result<()> {
        self.set_nonblocking(nonblocking)
    }

    fn set_read_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        self.set_read_timeout(timeout)
    }

    fn set_write_timeout_opt(
        &self,
        timeout: Option<Duration>,
    ) -> std::io::Result<()> {
        self.set_write_timeout(timeout)
    }
}

// ---------------------------------------------------------------------
// Buffered non-blocking framed connection (worker side)
// ---------------------------------------------------------------------

/// Outcome of one [`Conn::fill`]: did bytes arrive, and did the stream
/// reach end-of-file? (EOF is not always an error — a tcp worker idling
/// between epochs treats a cleanly closed control channel as shutdown.)
pub(crate) struct FillOutcome {
    pub progressed: bool,
    pub eof: bool,
}

pub(crate) struct Conn<S> {
    stream: S,
    /// Inbound bytes; frames are parsed from `rpos`.
    rbuf: Vec<u8>,
    rpos: usize,
    /// Encoded frames not yet fully written (front is in flight).
    wqueue: VecDeque<Vec<u8>>,
    /// Bytes of the front frame already written.
    wpos: usize,
}

impl<S: SocketLike> Conn<S> {
    pub fn new(stream: S) -> Result<Self, String> {
        Self::with_leftover(stream, Vec::new())
    }

    /// Wrap a stream that a blocking rendezvous reader already pulled
    /// `leftover` unparsed bytes from (they stay at the front of the
    /// inbound buffer — nothing on the wire is ever dropped).
    pub fn with_leftover(stream: S, leftover: Vec<u8>) -> Result<Self, String> {
        stream
            .set_nonblocking_mode(true)
            .map_err(|e| format!("set_nonblocking: {e}"))?;
        Ok(Self {
            stream,
            rbuf: leftover,
            rpos: 0,
            wqueue: VecDeque::new(),
            wpos: 0,
        })
    }

    /// Unparsed inbound bytes (used to re-check buffers are empty at
    /// epoch boundaries).
    pub fn pending_read_bytes(&self) -> usize {
        self.rbuf.len() - self.rpos
    }

    /// Pull whatever the socket has into the inbound buffer without
    /// blocking.
    pub fn fill(&mut self, what: &str) -> Result<FillOutcome, String> {
        let mut tmp = [0u8; 1 << 16];
        let mut out = FillOutcome {
            progressed: false,
            eof: false,
        };
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    out.eof = true;
                    return Ok(out);
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    out.progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                // a 20ms read timeout surfaces as TimedOut on some
                // platforms even in nonblocking mode; treat it as "no
                // bytes right now"
                Err(e) if e.kind() == ErrorKind::TimedOut => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("{what}: read: {e}")),
            }
        }
        Ok(out)
    }

    /// Total length of the complete frame at the parse cursor, if any.
    pub fn next_frame_bytes(
        &self,
        what: &str,
    ) -> Result<Option<usize>, String> {
        let avail = &self.rbuf[self.rpos..];
        match frame_len(avail).map_err(|e| format!("{what}: {e}"))? {
            Some(total) if avail.len() >= total => Ok(Some(total)),
            _ => Ok(None),
        }
    }

    /// Bytes of the frame at the cursor (caller got `total` from
    /// [`Conn::next_frame_bytes`]).
    pub fn frame_at_cursor(&self, total: usize) -> &[u8] {
        &self.rbuf[self.rpos..self.rpos + total]
    }

    /// Advance the parse cursor past a consumed frame.
    pub fn consume(&mut self, total: usize) {
        self.rpos += total;
    }

    pub fn compact(&mut self) {
        if self.rpos == self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        } else if self.rpos > (1 << 16) {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
    }

    pub fn queue_frame(&mut self, frame: Vec<u8>) {
        self.wqueue.push_back(frame);
    }

    /// Write as much queued data as the socket accepts right now.
    /// `Ok(true)` if any bytes moved.
    pub fn pump_write(&mut self, what: &str) -> Result<bool, String> {
        let mut progressed = false;
        while let Some(front) = self.wqueue.front() {
            match self.stream.write(&front[self.wpos..]) {
                Ok(0) => return Err(format!("{what}: write returned 0")),
                Ok(n) => {
                    progressed = true;
                    self.wpos += n;
                    if self.wpos == front.len() {
                        self.wqueue.pop_front();
                        self.wpos = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::TimedOut => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("{what}: write: {e}")),
            }
        }
        Ok(progressed)
    }

    /// Block (politely) until every queued frame is on the wire.
    pub fn drain_writes(&mut self, what: &str) -> Result<(), String> {
        while !self.wqueue.is_empty() {
            if !self.pump_write(what)? {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
        Ok(())
    }
}

/// Poll `ctrl` until one complete control frame is available and return
/// its `(kind, token, payload)`. `Ok(None)` means the peer closed the
/// channel cleanly (no partial frame pending) — end of the worker's
/// service life. `deadline: None` waits indefinitely (a live driver
/// decides the worker's lifetime; its death surfaces as EOF).
pub(crate) fn next_ctrl_frame<S: SocketLike>(
    ctrl: &mut Conn<S>,
    deadline: Option<Duration>,
) -> Result<Option<(u8, u64, Vec<u8>)>, String> {
    let limit = deadline.map(|d| Instant::now() + d);
    loop {
        if let Some(total) = ctrl.next_frame_bytes("ctrl")? {
            let decoded = {
                let mut input = ctrl.frame_at_cursor(total);
                let frame = decode_frame(&mut input)
                    .map_err(|e| format!("ctrl: {e}"))?;
                (frame.kind, frame.token, frame.payload.to_vec())
            };
            ctrl.consume(total);
            ctrl.compact();
            return Ok(Some(decoded));
        }
        let outcome = ctrl.fill("ctrl")?;
        if outcome.eof {
            if ctrl.pending_read_bytes() == 0 {
                return Ok(None);
            }
            return Err("ctrl: peer closed mid-frame".into());
        }
        if !outcome.progressed {
            if let Some(l) = limit {
                if Instant::now() > l {
                    return Err(format!(
                        "ctrl: no frame within {deadline:?}"
                    ));
                }
            }
            // deadline-bounded waits (a SEED the driver is about to
            // send) poll tightly; open-ended waits (a tcp worker parked
            // between epochs, possibly for minutes) back off so an idle
            // fleet isn't spinning syscalls
            std::thread::sleep(if deadline.is_some() {
                Duration::from_millis(1)
            } else {
                Duration::from_millis(20)
            });
        }
    }
}

// ---------------------------------------------------------------------
// Mesh peer connections + the worker-side transport
// ---------------------------------------------------------------------

pub(crate) struct PeerConn<S> {
    pub conn: Conn<S>,
    /// `"peer <rank>"`, precomputed for error paths.
    label: String,
    /// Cumulative messages sent on this channel this epoch — the token
    /// stamped into each outbound MSGS frame.
    sent_seq: u64,
    /// Cumulative messages received this epoch; each inbound token must
    /// equal `recv_seq + batch len` (FIFO channel, no loss, no reorder).
    recv_seq: u64,
}

impl<S: SocketLike> PeerConn<S> {
    pub fn new(conn: Conn<S>, peer_rank: usize) -> Self {
        Self {
            conn,
            label: format!("peer {peer_rank}"),
            sent_seq: 0,
            recv_seq: 0,
        }
    }

    /// Reset the per-epoch token counters (mesh connections persist
    /// across epochs on the tcp backend).
    fn reset_epoch(&mut self) {
        self.sent_seq = 0;
        self.recv_seq = 0;
        debug_assert_eq!(
            self.conn.pending_read_bytes(),
            0,
            "mesh channel must be drained at an epoch boundary"
        );
    }
}

/// The worker-side [`Transport`]: rank-local batches short-circuit
/// through `selfq`, remote batches are framed onto the peer mesh.
struct SocketTransport<'a, S, M> {
    rank: usize,
    peers: &'a mut [Option<PeerConn<S>>],
    /// Rank-local batches (never serialized).
    selfq: VecDeque<Vec<M>>,
    /// Total messages queued (self lanes included) — the worker's
    /// `sent` counter for the termination protocol.
    sent: u64,
    scratch: Vec<u8>,
    /// First I/O error hit inside `ship` (surfaced by `check`).
    io_error: Option<String>,
}

impl<S: SocketLike, M: WireMsg> SocketTransport<'_, S, M> {
    fn check(&mut self) -> Result<(), String> {
        match self.io_error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn pump_all(&mut self) -> Result<bool, String> {
        let mut progressed = false;
        for peer in self.peers.iter_mut().flatten() {
            progressed |= peer.conn.pump_write(&peer.label)?;
        }
        Ok(progressed)
    }

    /// Read and decode every complete inbound frame from `p`.
    /// Returns `(batch, frame bytes)` pairs in arrival order.
    fn read_frames(&mut self, p: usize) -> Result<Vec<(Vec<M>, u64)>, String> {
        let peer = self.peers[p].as_mut().expect("no self/missing peer");
        let what = peer.label.as_str();
        let outcome = peer.conn.fill(what)?;
        if outcome.eof {
            return Err(format!("{what}: peer closed"));
        }
        let mut out = Vec::new();
        while let Some(total) = peer.conn.next_frame_bytes(what)? {
            let mut input = peer.conn.frame_at_cursor(total);
            let frame =
                decode_frame(&mut input).map_err(|e| format!("{what}: {e}"))?;
            if frame.kind != kind::MSGS {
                return Err(format!(
                    "{what}: unexpected frame kind {}",
                    frame.kind
                ));
            }
            let msgs: Vec<M> =
                decode_msgs(&frame).map_err(|e| format!("{what}: {e}"))?;
            let expect = peer.recv_seq + msgs.len() as u64;
            if frame.token != expect {
                return Err(format!(
                    "{what}: termination token mismatch \
                     (expected {expect}, got {})",
                    frame.token
                ));
            }
            peer.recv_seq = expect;
            peer.conn.consume(total);
            out.push((msgs, total as u64));
        }
        peer.conn.compact();
        Ok(out)
    }
}

impl<S: SocketLike, M: WireMsg> Transport<M> for SocketTransport<'_, S, M> {
    fn note_queued(&mut self, n: u64) {
        self.sent += n;
    }

    fn ship(&mut self, to: usize, batch: Vec<M>) {
        if to == self.rank {
            self.selfq.push_back(batch);
            return;
        }
        let peer = self.peers[to].as_mut().expect("missing peer");
        peer.sent_seq += batch.len() as u64;
        let mut frame =
            Vec::with_capacity(FRAME_HEADER_LEN + 16 * batch.len());
        encode_msg_frame(
            kind::MSGS,
            peer.sent_seq,
            &batch,
            &mut self.scratch,
            &mut frame,
        );
        peer.conn.queue_frame(frame);
        if let Err(e) = peer.conn.pump_write(&peer.label) {
            if self.io_error.is_none() {
                self.io_error = Some(e);
            }
        }
    }
}

// ---------------------------------------------------------------------
// SEED payloads
// ---------------------------------------------------------------------

/// The non-actor half of a SEED frame: which actor kind to construct,
/// and the outbox flush policy (+ per-destination warm-start seeds) the
/// worker's epoch runs under — everything a remote worker needs that
/// used to ride fork copy-on-write.
pub(crate) struct SeedHead {
    pub actor_kind: String,
    pub policy: FlushPolicy,
    pub seeds: Vec<usize>,
}

/// Encode a full SEED payload for one worker.
pub(crate) fn encode_seed<A: FabricActor>(
    actor: &A,
    policy: FlushPolicy,
    seeds: &[usize],
) -> Vec<u8> {
    let mut out = Vec::new();
    let kind_bytes = A::KIND.as_bytes();
    assert!(kind_bytes.len() <= u8::MAX as usize, "actor kind too long");
    put_u8(&mut out, kind_bytes.len() as u8);
    out.extend_from_slice(kind_bytes);
    encode_policy_into(&policy, &mut out);
    put_u32(&mut out, seeds.len() as u32);
    for &s in seeds {
        put_u64(&mut out, s as u64);
    }
    actor.write_seed(&mut out);
    out
}

/// Split a SEED payload into its head and the actor-seed remainder.
pub(crate) fn split_seed(payload: &[u8]) -> Result<(SeedHead, &[u8]), String> {
    let err = |e: WireError| format!("bad seed frame: {e}");
    let mut input = payload;
    let kind_len = super::codec::get_u8(&mut input).map_err(err)? as usize;
    let kind_bytes = super::codec::take(&mut input, kind_len).map_err(err)?;
    let actor_kind = std::str::from_utf8(kind_bytes)
        .map_err(|_| "bad seed frame: non-utf8 actor kind".to_string())?
        .to_string();
    let policy = decode_policy(&mut input).map_err(err)?;
    let n = get_u32(&mut input).map_err(err)? as usize;
    let mut seeds = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        seeds.push(get_u64(&mut input).map_err(err)? as usize);
    }
    Ok((
        SeedHead {
            actor_kind,
            policy,
            seeds,
        },
        input,
    ))
}

// ---------------------------------------------------------------------
// Worker epoch loop
// ---------------------------------------------------------------------

/// Run one epoch on the worker side of a socket backend: construct the
/// actor from its wire seed, run seed → message storm → idle rounds →
/// Stop under driver control, and ship the result state back.
pub(crate) fn worker_epoch<A, S>(
    rank: usize,
    head: &SeedHead,
    actor_seed: &[u8],
    ctrl: &mut Conn<S>,
    peers: &mut [Option<PeerConn<S>>],
) -> Result<(), String>
where
    A: FabricActor,
    A::Msg: WireMsg,
    S: SocketLike,
{
    let ranks = peers.len();
    let mut input = actor_seed;
    let mut actor = A::read_seed(&mut input)
        .map_err(|e| format!("seed decode for {:?}: {e}", A::KIND))?;
    if !input.is_empty() {
        return Err(format!(
            "seed for {:?} left {} trailing bytes",
            A::KIND,
            input.len()
        ));
    }
    for peer in peers.iter_mut().flatten() {
        peer.reset_epoch();
    }

    let mut tp: SocketTransport<'_, S, A::Msg> = SocketTransport {
        rank,
        peers,
        selfq: VecDeque::new(),
        sent: 0,
        scratch: Vec::new(),
        io_error: None,
    };
    let mut outbox: Outbox<A::Msg> =
        Outbox::with_seeds(ranks, head.policy, &head.seeds);
    let mut sent_base = 0u64;
    let mut delivered = 0u64;
    let mut frames_in = 0u64;
    let mut bytes_in = 0u64;

    // Seed context.
    actor.seed(&mut outbox);
    flush_outbox(&mut outbox, &mut sent_base, &mut tp, true);
    tp.check()?;

    let mut stop = false;
    while !stop {
        let mut progressed = false;

        // 1. keep partially written frames moving
        progressed |= tp.pump_all()?;

        // 2. rank-local batches
        while let Some(batch) = tp.selfq.pop_front() {
            progressed = true;
            let n = batch.len() as u64;
            for msg in batch {
                actor.on_message(msg, &mut outbox);
                flush_outbox(&mut outbox, &mut sent_base, &mut tp, false);
            }
            delivered += n;
            frames_in += 1;
            flush_outbox(&mut outbox, &mut sent_base, &mut tp, true);
            tp.check()?;
        }

        // 3. inbound peer frames
        for p in 0..ranks {
            if p == rank {
                continue;
            }
            for (msgs, nbytes) in tp.read_frames(p)? {
                progressed = true;
                let n = msgs.len() as u64;
                for msg in msgs {
                    actor.on_message(msg, &mut outbox);
                    flush_outbox(&mut outbox, &mut sent_base, &mut tp, false);
                }
                delivered += n;
                frames_in += 1;
                bytes_in += nbytes;
                flush_outbox(&mut outbox, &mut sent_base, &mut tp, true);
                tp.check()?;
            }
        }

        // 4. control frames from the driver
        let ctrl_fill = ctrl.fill("ctrl")?;
        if ctrl_fill.eof {
            return Err("ctrl: driver closed mid-epoch".into());
        }
        while let Some(total) = ctrl.next_frame_bytes("ctrl")? {
            progressed = true;
            let (fkind, ftoken) = {
                let mut input = ctrl.frame_at_cursor(total);
                let frame = decode_frame(&mut input)
                    .map_err(|e| format!("ctrl: {e}"))?;
                (frame.kind, frame.token)
            };
            ctrl.consume(total);
            match fkind {
                kind::PROBE => {
                    queue_report(ctrl, ftoken, tp.sent, delivered);
                }
                kind::IDLE => {
                    actor.on_idle(&mut outbox);
                    flush_outbox(&mut outbox, &mut sent_base, &mut tp, true);
                    tp.check()?;
                    queue_report(ctrl, ftoken, tp.sent, delivered);
                }
                kind::STOP => {
                    stop = true;
                    break;
                }
                other => {
                    return Err(format!("ctrl: unexpected frame kind {other}"))
                }
            }
        }
        ctrl.compact();
        progressed |= ctrl.pump_write("ctrl")?;

        if !progressed {
            std::thread::sleep(Duration::from_micros(100));
        }
    }

    // Final state: inbound stats record + serialized actor state.
    let mut payload = Vec::new();
    put_u64(&mut payload, delivered);
    put_u64(&mut payload, bytes_in);
    put_u64(&mut payload, frames_in);
    put_u64(&mut payload, tp.sent);
    actor.write_state(&mut payload);
    let mut frame = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    encode_frame_into(kind::STATE, 0, 0, &payload, &mut frame);
    ctrl.queue_frame(frame);
    ctrl.drain_writes("ctrl")
}

fn queue_report<S: SocketLike>(
    ctrl: &mut Conn<S>,
    wave: u64,
    sent: u64,
    delivered: u64,
) {
    let mut payload = Vec::with_capacity(16);
    put_u64(&mut payload, sent);
    put_u64(&mut payload, delivered);
    let mut frame = Vec::with_capacity(FRAME_HEADER_LEN + 16);
    encode_frame_into(kind::REPORT, 0, wave, &payload, &mut frame);
    ctrl.queue_frame(frame);
}

// ---------------------------------------------------------------------
// Driver side
// ---------------------------------------------------------------------

/// What the driver does when a control read hits its deadline with no
/// frame. `Ok(true)`: the worker was verified alive (e.g. `waitpid`
/// says the child is running a long context) — re-arm and keep waiting.
/// `Ok(false)`: liveness cannot be verified — treat the deadline as
/// fatal. `Err`: the worker is known dead; the message describes how.
pub(crate) trait Liveness {
    fn still_alive(&mut self) -> Result<bool, String>;
}

/// The tcp backend's liveness: a remote worker cannot be probed beyond
/// its socket, so an expired deadline is a clear, immediate error.
pub(crate) struct DeadlineOnly;

impl Liveness for DeadlineOnly {
    fn still_alive(&mut self) -> Result<bool, String> {
        Ok(false)
    }
}

/// Blocking framed reader/writer over one worker's control channel.
pub(crate) struct DriverCtrl<S, L> {
    pub desc: String,
    stream: S,
    liveness: L,
    rbuf: Vec<u8>,
    rpos: usize,
}

impl<S: SocketLike, L: Liveness> DriverCtrl<S, L> {
    pub fn new(stream: S, desc: String, liveness: L) -> Result<Self, String> {
        stream
            .set_read_timeout_opt(Some(Duration::from_millis(20)))
            .map_err(|e| format!("{desc}: set_read_timeout: {e}"))?;
        // writes are deadline-bounded too: a worker that stops draining
        // (wedged host, black-holed network) must surface as an error,
        // not hang the driver inside a multi-megabyte SEED write_all —
        // the same no-hang contract every recv in this module keeps.
        // A slow-but-draining worker is fine: each write syscall that
        // moves bytes restarts the clock.
        stream
            .set_write_timeout_opt(Some(CTRL_DEADLINE))
            .map_err(|e| format!("{desc}: set_write_timeout: {e}"))?;
        Ok(Self {
            desc,
            stream,
            liveness,
            rbuf: Vec::new(),
            rpos: 0,
        })
    }

    /// Take the stream (plus any already-buffered unparsed bytes) back
    /// out — used when a rendezvous control link becomes a worker's
    /// nonblocking [`Conn`].
    pub fn into_parts(mut self) -> (S, Vec<u8>) {
        let leftover = self.rbuf.split_off(self.rpos);
        (self.stream, leftover)
    }

    pub fn send(&mut self, k: u8, token: u64) -> Result<(), String> {
        self.send_payload(k, token, &[])
    }

    pub fn send_payload(
        &mut self,
        k: u8,
        token: u64,
        payload: &[u8],
    ) -> Result<(), String> {
        // header then payload, no concatenation: SEED payloads carry
        // whole stores/shards, and copying them into a second buffer
        // would transiently double the driver's per-rank seed memory
        let head = super::codec::encode_frame_header(k, 0, token, payload);
        self.stream
            .write_all(&head)
            .and_then(|()| self.stream.write_all(payload))
            .map_err(|e| format!("{}: control write: {e}", self.desc))
    }

    /// Read the next control frame (blocking); returns
    /// `(kind, token, payload)`. Every `deadline` of silence the
    /// [`Liveness`] hook decides: re-arm (worker verified alive) or fail
    /// with a clear error naming the worker.
    pub fn recv(
        &mut self,
        deadline: Duration,
    ) -> Result<(u8, u64, Vec<u8>), String> {
        let mut limit = Instant::now() + deadline;
        loop {
            let avail = &self.rbuf[self.rpos..];
            if let Some(total) =
                frame_len(avail).map_err(|e| format!("{}: {e}", self.desc))?
            {
                if avail.len() >= total {
                    let mut input = &self.rbuf[self.rpos..][..total];
                    let frame = decode_frame(&mut input)
                        .map_err(|e| format!("{}: {e}", self.desc))?;
                    let out = (frame.kind, frame.token, frame.payload.to_vec());
                    self.rpos += total;
                    if self.rpos == self.rbuf.len() {
                        self.rbuf.clear();
                        self.rpos = 0;
                    }
                    return Ok(out);
                }
            }
            let mut tmp = [0u8; 1 << 16];
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return Err(format!(
                        "{}: control channel closed mid-protocol",
                        self.desc
                    ))
                }
                Ok(n) => self.rbuf.extend_from_slice(&tmp[..n]),
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut =>
                {
                    if Instant::now() > limit {
                        match self.liveness.still_alive() {
                            Ok(true) => limit = Instant::now() + deadline,
                            Ok(false) => {
                                return Err(format!(
                                    "{}: no control frame within {:?}",
                                    self.desc, deadline
                                ))
                            }
                            Err(msg) => {
                                return Err(format!("{}: {msg}", self.desc))
                            }
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(format!("{}: control read: {e}", self.desc))
                }
            }
        }
    }
}

/// One probe wave: returns global `(sent, delivered)`.
fn probe_wave<S: SocketLike, L: Liveness>(
    ctrls: &mut [DriverCtrl<S, L>],
    wave: u64,
) -> Result<(u64, u64), String> {
    for c in ctrls.iter_mut() {
        c.send(kind::PROBE, wave)?;
    }
    collect_reports(ctrls, wave)
}

/// Collect one REPORT per worker for `wave`; sums `(sent, delivered)`.
pub(crate) fn collect_reports<S: SocketLike, L: Liveness>(
    ctrls: &mut [DriverCtrl<S, L>],
    wave: u64,
) -> Result<(u64, u64), String> {
    let (mut s, mut d) = (0u64, 0u64);
    for c in ctrls.iter_mut() {
        loop {
            let (k, token, payload) = c.recv(CTRL_DEADLINE)?;
            if k != kind::REPORT {
                return Err(format!(
                    "{}: sent unexpected control frame kind {k}",
                    c.desc
                ));
            }
            if token != wave {
                // stale report from an earlier wave; skip it
                continue;
            }
            let mut input = payload.as_slice();
            let err =
                |e: WireError| format!("{}: bad report: {e}", c.desc);
            let sent = get_u64(&mut input).map_err(err)?;
            let delivered = get_u64(&mut input).map_err(err)?;
            s += sent;
            d += delivered;
            break;
        }
    }
    Ok((s, d))
}

/// Probe until two consecutive waves report identical, balanced totals
/// (see module docs for why that implies global quiescence).
fn wait_quiescent<S: SocketLike, L: Liveness>(
    ctrls: &mut [DriverCtrl<S, L>],
    wave: &mut u64,
) -> Result<u64, String> {
    let mut prev: Option<(u64, u64)> = None;
    loop {
        *wave += 1;
        let (s, d) = probe_wave(ctrls, *wave)?;
        if s == d && prev == Some((s, d)) {
            return Ok(s);
        }
        prev = Some((s, d));
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// Drive an already-seeded epoch to completion: quiescence → idle
/// rounds → re-quiescence, then broadcast Stop. Returns the number of
/// idle rounds executed (same schedule as the in-memory backends).
pub(crate) fn drive_to_stop<S: SocketLike, L: Liveness>(
    ctrls: &mut [DriverCtrl<S, L>],
) -> Result<u64, String> {
    let mut wave = 0u64;
    let mut idle_rounds = 0u64;
    loop {
        let sent_before = wait_quiescent(ctrls, &mut wave)?;
        idle_rounds += 1;
        wave += 1;
        for c in ctrls.iter_mut() {
            c.send(kind::IDLE, wave)?;
        }
        collect_reports(ctrls, wave)?;
        let sent_after = wait_quiescent(ctrls, &mut wave)?;
        if sent_after == sent_before {
            break;
        }
    }
    for c in ctrls.iter_mut() {
        c.send(kind::STOP, 0)?;
    }
    Ok(idle_rounds)
}

/// Receive one worker's STATE frame: fold its traffic counters into
/// `stats` and decode the result state into the driver's actor copy.
pub(crate) fn collect_state<A, S, L>(
    ctrl: &mut DriverCtrl<S, L>,
    actor: &mut A,
    stats: &mut CommStats,
    rank: usize,
) -> Result<(), String>
where
    A: WireActor,
    S: SocketLike,
    L: Liveness,
{
    let (k, _token, payload) = ctrl.recv(CTRL_DEADLINE)?;
    if k != kind::STATE {
        return Err(format!(
            "{}: sent frame kind {k} instead of state",
            ctrl.desc
        ));
    }
    let mut input = payload.as_slice();
    let err = |e: WireError| format!("{}: bad state frame: {e}", ctrl.desc);
    let delivered = get_u64(&mut input).map_err(err)?;
    let bytes_in = get_u64(&mut input).map_err(err)?;
    let frames_in = get_u64(&mut input).map_err(err)?;
    let _sent = get_u64(&mut input).map_err(err)?;
    stats.messages += delivered;
    stats.bytes += bytes_in;
    stats.flushes += frames_in;
    stats.per_rank[rank] = RankStats {
        messages: delivered,
        bytes: bytes_in,
        flushes: frames_in,
    };
    actor
        .read_state(&mut input)
        .map_err(|e| format!("{}: state decode failed: {e}", ctrl.desc))?;
    if !input.is_empty() {
        return Err(format!(
            "{}: left {} trailing state bytes",
            ctrl.desc,
            input.len()
        ));
    }
    Ok(())
}
